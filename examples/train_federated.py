"""End-to-end BFLN training driver: the paper's full protocol (Fig. 1) with
blockchain, incentives, checkpointing and resume.

    PYTHONPATH=src python examples/train_federated.py \
        --dataset synth10 --bias 0.1 --clients 20 --clusters 5 --rounds 50

The defaults reproduce the paper's Table I hyper-parameters (20 clients,
lr 1e-3, 5 local epochs, batch 64, ρ=2, stake 5, pool 20) at a round count
that fits the CPU container; pass --rounds 50 for the paper's full budget.
"""
import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_trainer_state, save_trainer_state
from repro.core import FederatedTrainer, ModelBundle, make_bfln
from repro.core.fl import evaluate
from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.models import classifier as clf
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth10",
                    choices=["synth10", "synth100", "synthdigits"])
    ap.add_argument("--bias", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--clusters", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--local-epochs", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--psi", type=int, default=32)
    ap.add_argument("--ckpt", default="experiments/fed_ckpt.npz")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    (xt, yt), (xe, ye) = make_classification_dataset(args.dataset, seed=0)
    parts = dirichlet_partition(yt, args.clients, args.bias, seed=0)
    cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=4,
                                  batch_size=args.batch_size)
    probe = jnp.asarray(sample_probe_batch(xt, yt, category=0, psi=args.psi))
    num_classes = int(yt.max()) + 1

    cfg = clf.MLPConfig(in_dim=xt.shape[1], hidden=(128,), rep_dim=64,
                        num_classes=num_classes)
    bundle = ModelBundle(functools.partial(clf.apply, cfg),
                         functools.partial(clf.embed, cfg), num_classes)
    strat = make_bfln(bundle, probe, args.clusters)
    tr = FederatedTrainer(bundle, strat, adam(args.lr),
                          local_epochs=args.local_epochs,
                          n_clusters=args.clusters)

    sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), args.clients)
    p, o = tr.init(sp)
    start = 0
    if args.resume and os.path.exists(args.ckpt):
        p, o, start, extra = restore_trainer_state(args.ckpt)
        print(f"resumed from round {start}")

    cx, cy = jnp.asarray(cx), jnp.asarray(cy)
    xe, ye = jnp.asarray(xe), jnp.asarray(ye)
    for r in range(start, args.rounds):
        p, o, rec = tr.run_round(r, p, o, cx, cy, xe, ye)
        print(f"round {r:3d} loss={rec.mean_loss:.4f} acc={rec.accuracy:.4f} "
              f"clusters={rec.cluster_sizes.tolist()} producer={rec.producer} "
              f"verified={rec.verified_frac:.2f}")
        if (r + 1) % 5 == 0:
            save_trainer_state(args.ckpt, p, o, r + 1,
                               {"dataset": args.dataset, "bias": args.bias})

    pacc = float(jnp.mean(evaluate(bundle.apply_fn, p, jnp.asarray(tx),
                                   jnp.asarray(ty))))
    print(f"\npersonalized accuracy: {pacc:.4f}")
    print(f"chain valid: {tr.chain.validate()}  "
          f"blocks: {len(tr.chain.blocks)}  "
          f"ledger conserved: {tr.ledger.conserved()}")
    top = np.argsort(-tr.ledger.balances)[:5]
    print("top balances:", [(int(i), round(float(tr.ledger.balances[i]), 2))
                            for i in top])


if __name__ == "__main__":
    main()

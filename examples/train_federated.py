"""End-to-end BFLN training driver: the paper's full protocol (Fig. 1) with
blockchain, incentives, checkpointing and resume.  ``--strategy`` swaps in
any registered baseline (the chain engages for bfln only — baselines are
the paper's chainless comparison points).

    PYTHONPATH=src python examples/train_federated.py \
        --dataset synth10 --bias 0.1 --clients 20 --clusters 5 --rounds 50

The defaults reproduce the paper's Table I hyper-parameters (20 clients,
lr 1e-3, 5 local epochs, batch 64, ρ=2, stake 5, pool 20) at a round count
that fits the CPU container; pass --rounds 50 for the paper's full budget.
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as api
from repro.checkpoint import restore_trainer_state, save_trainer_state
from repro.core import FederatedTrainer
from repro.core.fl import evaluate
from repro.models import classifier as clf
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth10",
                    choices=["synth10", "synth100", "synthdigits"])
    ap.add_argument("--bias", type=float, default=0.1)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--strategy", default="bfln", choices=api.strategy_names())
    ap.add_argument("--clusters", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--local-epochs", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--psi", type=int, default=32)
    ap.add_argument("--ckpt", default="experiments/fed_ckpt.npz")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    data = api.load_packed_clients(args.dataset, args.clients, args.bias,
                                   batch_size=args.batch_size, psi=args.psi)
    cfg, bundle = api.make_mlp_bundle(data.in_dim, data.num_classes)
    strat = api.build_strategy(args.strategy, bundle, probe=data.probe,
                               n_clusters=args.clusters)
    tr = FederatedTrainer(bundle, strat, adam(args.lr),
                          local_epochs=args.local_epochs,
                          n_clusters=args.clusters,
                          use_chain=(args.strategy == "bfln"))

    sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), args.clients)
    p, o = tr.init(sp)
    start = 0
    if args.resume and os.path.exists(args.ckpt):
        p, o, start, extra = restore_trainer_state(args.ckpt)
        print(f"resumed from round {start}")

    cx, cy = data.cx, data.cy
    xe, ye = data.test_x, data.test_y
    for r in range(start, args.rounds):
        p, o, rec = tr.run_round(r, p, o, cx, cy, xe, ye)
        chain = (f" clusters={rec.cluster_sizes.tolist()} "
                 f"producer={rec.producer} verified={rec.verified_frac:.2f}"
                 if rec.cluster_sizes is not None else "")
        print(f"round {r:3d} loss={rec.mean_loss:.4f} "
              f"acc={rec.accuracy:.4f}{chain}")
        if (r + 1) % 5 == 0:
            save_trainer_state(args.ckpt, p, o, r + 1,
                               {"dataset": args.dataset, "bias": args.bias})

    pacc = float(jnp.mean(evaluate(bundle.apply_fn, p, jnp.asarray(data.tx),
                                   jnp.asarray(data.ty))))
    print(f"\npersonalized accuracy: {pacc:.4f}")
    if tr.ledger is not None:
        print(f"chain valid: {tr.chain.validate()}  "
              f"blocks: {len(tr.chain.blocks)}  "
              f"ledger conserved: {tr.ledger.conserved()}")
        top = np.argsort(-tr.ledger.balances)[:5]
        print("top balances:",
              [(int(i), round(float(tr.ledger.balances[i]), 2)) for i in top])


if __name__ == "__main__":
    main()

"""Clustered serving: chain-verified personalized inference via `repro.serve`.

After BFLN training, each spectral cluster owns a personalized model (the
cluster FedAvg).  This example trains a real population with `repro.api.run`,
snapshots the per-cluster models into a fingerprinted model bank anchored to
the blockchain by a release block, then serves a mixed-cluster request batch
in ONE fused dispatch — and demonstrates the refuse-to-serve gate by
tampering with a model and watching verification fail.

    PYTHONPATH=src python examples/serve_clustered.py

Runs on CPU in well under a minute.
"""
import numpy as np

import repro.api as api
from repro.serve import (ProvenanceError, ServeConfig, ServeFrontend,
                         ServingEngine, snapshot, tampered, verify_bank)
from repro.sim.clock import VirtualClock


def main():
    # 1. train a small non-IID population (PAA clustering + chain incentive)
    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=60),
        train=api.TrainSpec(rounds=3, sample_frac=0.3, n_clusters=3),
        eval=api.EvalSpec(every=0, clients=16, examples=64),
        seed=0)
    result = api.run(spec)
    chain = result.sim.trainer.chain
    print(f"trained: {len(chain.blocks)} blocks on chain, "
          f"final accuracy {result.report.final_accuracy:.3f}")

    # 2. snapshot -> model bank; publishes a release block whose Merkle root
    #    commits every cluster model's Pallas fingerprint, then verifies it
    bank = snapshot(result)
    print(f"bank: {bank.n_models} cluster models x {bank.n_params} params "
          f"({bank.nbytes} bytes), anchored to block {bank.block_hash[:12]}, "
          f"round {bank.round_idx}")

    # 3. serve a mixed-cluster batch in one fused dispatch
    engine = ServingEngine(bank, chain)   # re-verifies provenance on load
    clock = VirtualClock()
    fe = ServeFrontend(engine, ServeConfig(buckets=(1, 2, 4, 8)), clock=clock)
    rng = np.random.default_rng(0)
    for i in range(8):
        fe.submit(i % bank.n_models,
                  rng.standard_normal(bank.mcfg.in_dim).astype(np.float32))
    fe.drain()
    for c in fe.take_completed():
        pred = int(np.argmax(c.logits))
        print(f"  req {c.req_id}: cluster {c.cluster_id} -> class {pred}")
    print(f"served 8 mixed-cluster requests, "
          f"compiles={engine.cache_sizes()}")

    # 4. tamper-refusal: perturb one model by 0.01% -> the recomputed
    #    fingerprint no longer matches the on-chain release and the gate
    #    refuses to serve
    bad = tampered(bank, cluster_id=1)
    try:
        verify_bank(bad, chain)
        raise AssertionError("tampered bank must not verify")
    except ProvenanceError as e:
        print(f"tampered bank refused: {e}")
    try:
        ServingEngine(bad, chain)
        raise AssertionError("engine must refuse a tampered bank")
    except ProvenanceError:
        print("engine load refused the tampered bank as well")


if __name__ == "__main__":
    main()

"""Clustered serving: batched greedy decoding against per-cluster
personalized LMs using the KV-cache serve path.

After BFLN training, each spectral cluster owns a personalized model (the
cluster FedAvg). This example trains a tiny LM briefly, forks per-cluster
variants, then serves batched requests routed to their cluster's model —
exercising `init_cache`/`decode_step` end to end on CPU.

    PYTHONPATH=src python examples/serve_clustered.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.lm import batch_stream, make_token_stream
from repro.models.lm import greedy_generate, make_train_step
from repro.models.transformer import init_params
from repro.optim import adamw


def main():
    cfg = ARCHS["h2o-danube-3-4b"].reduced(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # brief pre-training so generations are non-degenerate
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    toks = make_token_stream(cfg.vocab_size, 20000, seed=0)
    for x, y in batch_stream(toks, batch=8, seq_len=32, n_steps=30, seed=0):
        loss, params, opt_state = step(params, opt_state,
                                       {"tokens": jnp.asarray(x),
                                        "labels": jnp.asarray(y)})
    print(f"pre-trained tiny LM, final loss {float(loss):.3f}")

    # fork 3 "cluster" variants (stand-ins for per-cluster FedAvg outputs)
    clusters = [jax.tree.map(lambda p, s=s: p * (1.0 + 0.001 * s), params)
                for s in range(3)]

    # batched serving: route each request batch to its cluster's model
    prompts = jnp.asarray([[5, 17, 42, 7], [101, 3, 9, 55]])
    for cid, cparams in enumerate(clusters):
        t0 = time.time()
        out = greedy_generate(cfg, cparams, prompts, max_new=12, seq_len=64)
        dt = (time.time() - t0) * 1000
        print(f"cluster {cid}: generated {out.shape[1] - prompts.shape[1]} "
              f"tokens/req in {dt:.0f} ms -> {out[0].tolist()}")


if __name__ == "__main__":
    main()

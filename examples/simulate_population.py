"""Population-scale BFLN simulation: sampling, stragglers, dropouts, attacks.

Runs the event-driven simulator (`repro.sim`) over ≥1000 virtual clients with
partial participation — the production regime the paper's 20-always-on-client
protocol cannot express:

    PYTHONPATH=src python examples/simulate_population.py \
        --clients 1000 --sample-frac 0.10 --rounds 30 --byzantine-frac 0.05

Every run is deterministic: the printed event-log digest is a SHA-256 over
the full (virtual-time, kind, client) event stream — rerun with the same
seed and the digest, block hashes and final balances reproduce exactly.

Finishes in well under 2 minutes on CPU.  Scenario knobs:
  --straggler-frac / --straggler-slowdown   heavy-tailed client latency
  --dropout-rate                            mid-round client death
  --byzantine-frac                          freeriding hash commitments
  --sampler uniform|stake_weighted|cluster_stratified
  --mode sync|async  (async = FedBuff buffered aggregation + staleness)
  --mesh-shards N                           row-shard the parameter arena
                                            over an N-device client mesh
                                            (CPU devices self-forced)
"""
import argparse
import hashlib
import json
import time

if __name__ == "__main__":
    # mesh mode needs the forced CPU device count BEFORE jax initialises
    # (the repro.sim import below) — pre-parse and re-exec once
    from repro.launch.bootstrap import force_host_device_count
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--mesh-shards", type=int, default=1)
    force_host_device_count(_pre.parse_known_args()[0].mesh_shards)

import numpy as np

from repro.sim import ClientPopulation, PopulationSpec, SimConfig, SimulatedFederation


def event_log_digest(event_log) -> str:
    payload = json.dumps(event_log, sort_keys=False).encode()
    return hashlib.sha256(payload).hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--dataset", default="synth10")
    ap.add_argument("--bias", type=float, default=0.3)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--sample-frac", type=float, default=0.10)
    ap.add_argument("--clusters", type=int, default=5)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--deadline", type=float, default=30.0)
    ap.add_argument("--straggler-frac", type=float, default=0.10)
    ap.add_argument("--straggler-slowdown", type=float, default=8.0)
    ap.add_argument("--dropout-rate", type=float, default=0.03)
    ap.add_argument("--byzantine-frac", type=float, default=0.05)
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "stake_weighted", "cluster_stratified"])
    ap.add_argument("--mode", default="sync", choices=["sync", "async"])
    ap.add_argument("--buffer-size", type=int, default=16)
    ap.add_argument("--concurrency", type=int, default=64)
    ap.add_argument("--staleness-alpha", type=float, default=0.5)
    ap.add_argument("--mesh-shards", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-async-demo", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    spec = PopulationSpec(
        n_clients=args.clients, dataset=args.dataset, beta=args.bias,
        straggler_frac=args.straggler_frac,
        straggler_slowdown=args.straggler_slowdown,
        dropout_rate=args.dropout_rate, byzantine_frac=args.byzantine_frac,
        seed=args.seed)
    pop = ClientPopulation.from_spec(spec)
    print(f"population: {pop.n_clients} clients, "
          f"{int(pop.byzantine.sum())} byzantine, "
          f"{int((pop.latency.speed > 1.25).sum())} "   # non-straggler max is 1.25
          f"stragglers  ({time.time()-t0:.1f}s)")

    cfg = SimConfig(
        rounds=args.rounds, sample_frac=args.sample_frac,
        n_clusters=args.clusters, local_epochs=args.local_epochs,
        deadline=args.deadline, sampler=args.sampler, mode=args.mode,
        buffer_size=args.buffer_size, concurrency=args.concurrency,
        staleness_alpha=args.staleness_alpha, eval_every=5,
        mesh_shards=args.mesh_shards, seed=args.seed)
    sim = SimulatedFederation(pop, cfg)
    rep = sim.run()

    for r in rep.history:
        acc = f" acc={r.accuracy:.4f}" if np.isfinite(r.accuracy) else ""
        stale = (f" stale={r.staleness_mean:.2f}"
                 if args.mode == "async" else
                 f" strag={r.n_stragglers} drop={r.n_dropouts}")
        print(f"round {r.round_idx:3d} t={r.t_close:8.1f} "
              f"k={len(r.cohort):3d} arrived={int(r.arrived.sum()):3d}"
              f"{stale} byz={r.n_byzantine} prod={r.producer:4d} "
              f"verified={r.verified_frac:.2f} paid={r.reward_paid:5.1f} "
              f"burned={r.reward_burned:4.1f} loss={r.mean_loss:.4f}{acc}")

    print(f"\n{rep.summary()}")
    print(f"event-log digest: {event_log_digest(rep.event_log)}")
    top = np.argsort(-rep.balances)[:5]
    print("top balances:", [(int(i), round(float(rep.balances[i]), 2))
                            for i in top])
    byz_gain = rep.balances[pop.byzantine] - cfg.initial_stake
    if pop.byzantine.any():
        print(f"byzantine mean gain: {byz_gain.mean():+.3f}  "
              f"honest mean gain: "
              f"{(rep.balances[~pop.byzantine] - cfg.initial_stake).mean():+.3f}")
    print(f"wall time: {time.time()-t0:.1f}s")

    if args.mode == "sync" and not args.skip_async_demo:
        print("\n--- async (FedBuff) demo: same population, buffered "
              "staleness-weighted aggregation ---")
        acfg = SimConfig(rounds=8, mode="async", buffer_size=args.buffer_size,
                         concurrency=args.concurrency,
                         staleness_alpha=args.staleness_alpha,
                         sampler="stake_weighted", local_epochs=args.local_epochs,
                         n_clusters=args.clusters, eval_every=4, seed=args.seed)
        apop = ClientPopulation.from_spec(spec)
        asim = SimulatedFederation(apop, acfg)
        arep = asim.run()
        for r in arep.history:
            acc = f" acc={r.accuracy:.4f}" if np.isfinite(r.accuracy) else ""
            print(f"flush {r.round_idx:3d} t={r.t_close:8.1f} "
                  f"K={len(r.cohort):3d} stale={r.staleness_mean:.2f} "
                  f"byz={r.n_byzantine} verified={r.verified_frac:.2f} "
                  f"paid={r.reward_paid:5.1f} loss={r.mean_loss:.4f}{acc}")
        print(arep.summary())
        print(f"event-log digest: {event_log_digest(arep.event_log)}")
        print(f"total wall time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

"""Population-scale simulation: any strategy, sampling, stragglers, attacks.

Runs one declarative `repro.api.ExperimentSpec` through `repro.api.run` —
the event-driven simulator over ≥1000 virtual clients with partial
participation, with every strategy (BFLN or a Table II baseline) fused into
the arena-backed round engine:

    PYTHONPATH=src python examples/simulate_population.py \
        --clients 1000 --sample-frac 0.10 --rounds 30 --byzantine-frac 0.05 \
        --strategy bfln

Every run is deterministic and self-describing: the printed manifest stamps
the spec's config digest plus SHA-256 digests of the event log, block
hashes and balances — rerun with the same spec and every digest reproduces
exactly.  ``--spec-json out.json`` dumps the spec; ``--from-spec file``
replays one.

Finishes in well under 2 minutes on CPU.  Scenario knobs:
  --strategy bfln|fedavg|fedprox|fedproto|fedhkd
  --straggler-frac / --straggler-slowdown   heavy-tailed client latency
  --dropout-rate                            mid-round client death
  --byzantine-frac                          freeriding hash commitments
  --sampler uniform|stake_weighted|cluster_stratified
  --mode sync|async  (async = FedBuff buffered aggregation + staleness)
  --mesh-shards N                           row-shard the parameter arena
                                            over an N-device client mesh
                                            (CPU devices self-forced)
  --trace t.jsonl [--chrome-trace t.json]   flight-recorder trace (repro.obs):
                                            per-phase spans + metrics, digest
                                            stamped into the manifest
  --checkpoint-interval N --checkpoint-dir D   snapshot the complete state
                                            every N rounds/flushes (keep-last
                                            --keep-last); --resume continues
                                            from D's newest readable snapshot
                                            with bit-identical final digests
  --crash-round R [--crash-phase P --crash-mode M]   fault injection: die at
                                            boundary R (demo of the
                                            kill-and-resume workflow)
"""
import argparse
import time

if __name__ == "__main__":
    # mesh mode needs its runtime environment (forced CPU device count,
    # platform / x64 / extra XLA flags) resolved BEFORE jax initialises (the
    # repro.api import below) — pre-parse and bootstrap, re-execing once if
    # the environment had to change.  A replayed spec (--from-spec) carries
    # its mesh section inside the JSON, so peek at the file here (plain
    # json, no jax import) or the --mesh-shards flag would silently win
    # with its default of 1 and the mesh run could never replay.
    import json as _json

    from repro.launch.platform import bootstrap
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--mesh-shards", type=int, default=1)
    _pre.add_argument("--from-spec", default=None)
    _ns = _pre.parse_known_args()[0]
    _mesh = {"shards": _ns.mesh_shards}
    if _ns.from_spec:
        with open(_ns.from_spec) as _f:
            _mesh = dict(_json.load(_f).get("mesh", {}))
        _mesh["shards"] = max(_ns.mesh_shards, _mesh.get("shards", 1))
    bootstrap({"mesh": _mesh})

import numpy as np

import repro.api as api


def build_spec(args) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        data=api.DataSpec(
            n_clients=args.clients, dataset=args.dataset, beta=args.bias,
            straggler_frac=args.straggler_frac,
            straggler_slowdown=args.straggler_slowdown,
            dropout_rate=args.dropout_rate,
            byzantine_frac=args.byzantine_frac),
        train=api.TrainSpec(
            strategy=args.strategy, rounds=args.rounds,
            sample_frac=args.sample_frac, n_clusters=args.clusters,
            local_epochs=args.local_epochs, deadline=args.deadline,
            sampler=args.sampler, mode=args.mode),
        async_=api.AsyncSpec(
            buffer_size=args.buffer_size, concurrency=args.concurrency,
            staleness_alpha=args.staleness_alpha),
        eval=api.EvalSpec(every=5),
        mesh=api.MeshSpec(shards=args.mesh_shards),
        obs=api.ObsSpec(enabled=True, trace_path=args.trace,
                        chrome_path=args.chrome_trace, console=True)
        if args.trace else api.ObsSpec(),
        checkpoint=api.CheckpointSpec(interval=args.checkpoint_interval,
                                      dir=args.checkpoint_dir,
                                      keep_last=args.keep_last),
        faults=api.FaultSpec(crash_round=args.crash_round,
                             crash_phase=args.crash_phase,
                             crash_mode=args.crash_mode)
        if args.crash_round >= 0 else api.FaultSpec(),
        seed=args.seed)


def print_history(res: api.ExperimentResult, mode: str) -> None:
    for r in res.report.history:
        acc = f" acc={r.accuracy:.4f}" if np.isfinite(r.accuracy) else ""
        stale = (f" stale={r.staleness_mean:.2f}" if mode == "async" else
                 f" strag={r.n_stragglers} drop={r.n_dropouts}")
        print(f"round {r.round_idx:3d} t={r.t_close:8.1f} "
              f"k={len(r.cohort):3d} arrived={int(r.arrived.sum()):3d}"
              f"{stale} byz={r.n_byzantine} prod={r.producer:4d} "
              f"verified={r.verified_frac:.2f} paid={r.reward_paid:5.1f} "
              f"burned={r.reward_burned:4.1f} loss={r.mean_loss:.4f}{acc}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=1000)
    ap.add_argument("--dataset", default="synth10")
    ap.add_argument("--bias", type=float, default=0.3)
    ap.add_argument("--strategy", default="bfln",
                    choices=api.strategy_names())
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--sample-frac", type=float, default=0.10)
    ap.add_argument("--clusters", type=int, default=5)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--deadline", type=float, default=30.0)
    ap.add_argument("--straggler-frac", type=float, default=0.10)
    ap.add_argument("--straggler-slowdown", type=float, default=8.0)
    ap.add_argument("--dropout-rate", type=float, default=0.03)
    ap.add_argument("--byzantine-frac", type=float, default=0.05)
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "stake_weighted", "cluster_stratified"])
    ap.add_argument("--mode", default="sync", choices=["sync", "async"])
    ap.add_argument("--buffer-size", type=int, default=16)
    ap.add_argument("--concurrency", type=int, default=64)
    ap.add_argument("--staleness-alpha", type=float, default=0.5)
    ap.add_argument("--mesh-shards", type=int, default=1)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a flight-recorder trace (repro.obs): JSONL "
                         "to PATH, per-phase console table, trace sha256 "
                         "stamped into the manifest")
    ap.add_argument("--chrome-trace", default=None, metavar="PATH",
                    help="with --trace: also export a Chrome/Perfetto trace")
    ap.add_argument("--checkpoint-interval", type=int, default=0,
                    help="snapshot the complete experiment state every N "
                         "rounds/flushes (0 = off)")
    ap.add_argument("--checkpoint-dir", default="checkpoints",
                    help="snapshot directory (with --checkpoint-interval)")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="keep-last-K snapshot pruning window")
    ap.add_argument("--resume", default=None, metavar="PATH",
                    help="resume from a snapshot file or checkpoint dir "
                         "(newest readable snapshot); the finished run's "
                         "digests are bit-identical to an uninterrupted one")
    ap.add_argument("--crash-round", type=int, default=-1,
                    help="fault injection: crash at this round/flush "
                         "boundary (-1 = never)")
    ap.add_argument("--crash-phase", default="post_checkpoint",
                    choices=["round_start", "pre_chain", "post_checkpoint"])
    ap.add_argument("--crash-mode", default="sigkill",
                    choices=["exception", "sigkill"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-async-demo", action="store_true")
    ap.add_argument("--spec-json", default=None, metavar="PATH",
                    help="also dump the spec as JSON (reload via --from-spec)")
    ap.add_argument("--from-spec", default=None, metavar="PATH",
                    help="ignore the scenario flags and run this spec JSON")
    args = ap.parse_args()

    t0 = time.time()
    if args.from_spec:
        with open(args.from_spec) as f:
            spec = api.ExperimentSpec.from_json(f.read())
    else:
        spec = build_spec(args)
    if args.spec_json:
        with open(args.spec_json, "w") as f:
            f.write(spec.to_json(indent=1))
        print(f"spec -> {args.spec_json}")

    from repro.sim import ClientPopulation
    pop = ClientPopulation.from_spec(spec.population_spec())
    print(f"population: {pop.n_clients} clients, "
          f"{int(pop.byzantine.sum())} byzantine, "
          f"{int((pop.latency.speed > 1.25).sum())} "   # non-straggler max is 1.25
          f"stragglers, strategy={spec.train.strategy}  "
          f"({time.time()-t0:.1f}s)")

    if args.resume:
        print(f"resuming from {args.resume}")
    res = api.run(spec, population=pop, resume_from=args.resume)
    print_history(res, spec.train.mode)

    print(f"\n{res.report.summary()}")
    print("manifest:")
    print(api.format_manifest(res.manifest))
    balances = res.report.balances
    top = np.argsort(-balances)[:5]
    print("top balances:", [(int(i), round(float(balances[i]), 2))
                            for i in top])
    if pop.byzantine.any():
        stake = spec.chain.initial_stake
        print(f"byzantine mean gain: "
              f"{(balances[pop.byzantine] - stake).mean():+.3f}  "
              f"honest mean gain: "
              f"{(balances[~pop.byzantine] - stake).mean():+.3f}")
    print(f"wall time: {time.time()-t0:.1f}s")

    if spec.train.mode == "sync" and not args.skip_async_demo:
        print("\n--- async (FedBuff) demo: same population spec, buffered "
              "staleness-weighted aggregation ---")
        aspec = api.ExperimentSpec(
            data=spec.data,
            train=api.TrainSpec(
                strategy=spec.train.strategy, rounds=8, mode="async",
                sampler="stake_weighted", n_clusters=spec.train.n_clusters,
                local_epochs=spec.train.local_epochs),
            async_=api.AsyncSpec(buffer_size=args.buffer_size,
                                 concurrency=args.concurrency,
                                 staleness_alpha=args.staleness_alpha),
            eval=api.EvalSpec(every=4), seed=spec.seed)
        ares = api.run(aspec)
        for r in ares.report.history:
            acc = f" acc={r.accuracy:.4f}" if np.isfinite(r.accuracy) else ""
            print(f"flush {r.round_idx:3d} t={r.t_close:8.1f} "
                  f"K={len(r.cohort):3d} stale={r.staleness_mean:.2f} "
                  f"byz={r.n_byzantine} verified={r.verified_frac:.2f} "
                  f"paid={r.reward_paid:5.1f} loss={r.mean_loss:.4f}{acc}")
        print(ares.summary())
        print(f"total wall time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

"""Quickstart: BFLN vs FedAvg on skewed synthetic data in under a minute.

Two entry surfaces, one strategy registry:

  1. the legacy full-participation `FederatedTrainer` (the paper's 20-client
     protocol, shown below for bfln vs fedavg), and
  2. the declarative `repro.api.ExperimentSpec` → `run()` one-liner that
     drives the fused round engine + simulator (see
     examples/simulate_population.py for the full scenario surface).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.api as api
from repro.core import FederatedTrainer
from repro.core.fl import evaluate
from repro.models import classifier as clf
from repro.optim import adam


def main():
    n_clients, rounds, bias = 8, 5, 0.1
    data = api.load_packed_clients("synth10", n_clients, bias,
                                   probe_category=3, psi=16)
    cfg, bundle = api.make_mlp_bundle(data.in_dim, data.num_classes)

    for name in ["bfln", "fedavg"]:
        strat = api.build_strategy(name, bundle, probe=data.probe,
                                   n_clusters=3)
        sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), n_clients)
        tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=3,
                              n_clusters=3, use_chain=(name == "bfln"))
        p = tr.fit(sp, data.cx, data.cy, data.test_x, data.test_y,
                   rounds=rounds, log_every=1)
        pacc = float(jnp.mean(evaluate(bundle.apply_fn, p,
                                       jnp.asarray(data.tx),
                                       jnp.asarray(data.ty))))
        print(f"== {name}: personalized accuracy {pacc:.4f}")
        if name == "bfln":
            print(f"   chain valid={tr.chain.validate()} "
                  f"blocks={len(tr.chain.blocks)} "
                  f"ledger conserved={tr.ledger.conserved()} "
                  f"balances={tr.ledger.balances.round(2).tolist()}")

    # the same comparison as one declarative spec per strategy, through the
    # fused round engine + event-driven simulator
    print("\n== declarative API (fused engine + simulator) ==")
    for name in ["bfln", "fedavg"]:
        spec = api.ExperimentSpec(
            data=api.DataSpec(n_clients=64, dataset="synth10", beta=bias,
                              n_batches=2, batch_size=32),
            train=api.TrainSpec(strategy=name, rounds=5, sample_frac=0.5,
                                n_clusters=3, local_epochs=3),
            eval=api.EvalSpec(every=0))
        res = api.run(spec)
        print(f"   {name}: final_acc={res.report.final_accuracy:.4f} "
              f"config_digest={res.manifest['config_digest'][:12]}")


if __name__ == "__main__":
    main()

"""Quickstart: BFLN vs FedAvg on skewed synthetic data in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.core import FederatedTrainer, ModelBundle, make_bfln, make_fedavg
from repro.core.fl import evaluate
from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.models import classifier as clf
from repro.optim import adam


def main():
    n_clients, rounds, bias = 8, 5, 0.1
    (xt, yt), (xe, ye) = make_classification_dataset("synth10", seed=0)
    parts = dirichlet_partition(yt, n_clients, bias, seed=0)
    cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=4, batch_size=64)
    probe = jnp.asarray(sample_probe_batch(xt, yt, category=3, psi=16))

    cfg = clf.MLPConfig(in_dim=64, hidden=(128,), rep_dim=64, num_classes=10)
    bundle = ModelBundle(functools.partial(clf.apply, cfg),
                         functools.partial(clf.embed, cfg), 10)

    for name, make in [("bfln", lambda: make_bfln(bundle, probe, n_clusters=3)),
                       ("fedavg", lambda: make_fedavg(bundle))]:
        sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), n_clients)
        tr = FederatedTrainer(bundle, make(), adam(1e-3), local_epochs=3,
                              n_clusters=3, use_chain=(name == "bfln"))
        p = tr.fit(sp, jnp.asarray(cx), jnp.asarray(cy), jnp.asarray(xe),
                   jnp.asarray(ye), rounds=rounds, log_every=1)
        pacc = float(jnp.mean(evaluate(bundle.apply_fn, p, jnp.asarray(tx),
                                       jnp.asarray(ty))))
        print(f"== {name}: personalized accuracy {pacc:.4f}")
        if name == "bfln":
            print(f"   chain valid={tr.chain.validate()} "
                  f"blocks={len(tr.chain.blocks)} "
                  f"ledger conserved={tr.ledger.conserved()} "
                  f"balances={tr.ledger.balances.round(2).tolist()}")


if __name__ == "__main__":
    main()

"""§Roofline report: renders the dry-run artifacts into the per-(arch × mesh)
table required by the brief — three terms in seconds, dominant bottleneck,
MODEL_FLOPS ratio, and a one-line lever per row.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun).
"""
from __future__ import annotations

import glob
import json
import os

LEVERS = {
    "compute": "more chips / lower remat multiplier / skip masked attn chunks",
    "memory": "decode is weight/cache-streaming-bound: quantise KV or batch more queries",
    "collective": "pin attention layouts, shard_map EP (MoE), sequence-parallel TP",
}


def load(dir_: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        tag = os.path.basename(path).replace(".json", "")
        parts = tag.split("__")
        # arch__shape__mesh[__variant]; fl-round tags are fl-round__mesh[__variant]
        base_len = 2 if tag.startswith("fl-round") else 3
        variant = parts[base_len] if len(parts) > base_len else "baseline"
        rows.append((d, variant))
    return rows


def render(rows, include_variants: bool = True) -> str:
    lines = ["| arch | shape | mesh | variant | t_comp (ms) | t_mem (ms) | "
             "t_coll (ms) | bottleneck | useful FLOPs |",
             "|---|---|---|---|---|---|---|---|---|"]
    for d, variant in rows:
        if variant != "baseline" and not include_variants:
            continue
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {variant} "
            f"| {d['t_compute']*1e3:.2f} | {d['t_memory']*1e3:.2f} "
            f"| {d['t_collective']*1e3:.2f} | {d['bottleneck']} "
            f"| {d.get('model_flops_ratio', 0):.2f} |")
    return "\n".join(lines)


def main(dir_: str = "experiments/dryrun",
         out_path: str = "experiments/roofline.md"):
    rows = load(dir_)
    if not rows:
        print("roofline,none,0,no dry-run artifacts found (run repro.launch.dryrun)")
        return
    md = render(rows)
    with open(out_path, "w") as f:
        f.write("# Roofline table (from dry-run artifacts)\n\n" + md + "\n")
    n_base = sum(1 for _, v in rows if v == "baseline")
    for d, variant in rows:
        print(f"roofline,{d['arch']}|{d['shape']}|{d['mesh']}|{variant},"
              f"{d['t_collective']*1e3:.2f},"
              f"bottleneck={d['bottleneck']} useful={d.get('model_flops_ratio', 0):.2f}",
              flush=True)
    print(f"roofline,total,{len(rows)},{n_base} baselines -> {out_path}")


if __name__ == "__main__":
    main()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness:

  kernels_bench    — Pallas kernels vs oracles (µs/call)
  commit_bench     — chain commit+verify path: hash_params vs fingerprints
  round_bench      — sync-round hot path: legacy driver vs fused engine
  fig2_rewards     — paper Fig. 2 (reward trends vs cluster size)
  table2_accuracy  — paper Table II (accuracy under label skew)
  sim_bench        — event-driven federation simulator throughput
  roofline         — §Roofline table from the dry-run artifacts

``python -m benchmarks.run [--full] [--rounds N]``
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 3 datasets in table2 (slow on CPU)")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--skip-table2", action="store_true")
    ap.add_argument("--skip-sim", action="store_true")
    ap.add_argument("--skip-round", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    from benchmarks import (commit_bench, fig2_rewards, kernels_bench,
                            roofline, round_bench, sim_bench, table2_accuracy)

    print("# kernels")
    kernels_bench.main()
    print("# commit (chain commitment path)")
    commit_bench.main()
    if not args.skip_round:
        print("# round (legacy driver vs fused engine)")
        # only a --full run refreshes the tracked BENCH_round.json artifact
        round_bench.main(n_clients=1000 if args.full else 200,
                         rounds=50 if args.full else 10,
                         out="BENCH_round.json" if args.full
                         else "/tmp/BENCH_round_quick.json",
                         heavy_eval=args.full)
    print("# fig2 (reward trends)")
    fig2_rewards.main(rounds=min(args.rounds, 10))
    if not args.skip_table2:
        print("# table2 (accuracy)")
        table2_accuracy.main(args.full, args.rounds)
    if not args.skip_sim:
        print("# sim (federation simulator throughput)")
        sim_bench.main(quick=not args.full)
    print("# roofline")
    roofline.main()
    print(f"bench,total_wall_s,{time.time()-t0:.0f},done")


if __name__ == "__main__":
    main()

"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing, NOT TPU perf) vs the pure-jnp oracle (XLA:CPU compiled).

On TPU the Pallas kernels compile via Mosaic; here the numbers only show the
harness works end-to-end and give the oracle a CPU reference point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.kernels import ops, ref
from repro.kernels.cluster_agg import mixing_matrix


def main():
    rows = []
    key = jax.random.PRNGKey(0)

    protos = jax.random.normal(key, (20, 512))
    rows.append(("pearson_pallas_20x512", time_us(ops.pearson, protos),
                 "m=20 D=512 interpret"))
    rows.append(("pearson_ref_20x512",
                 time_us(jax.jit(ref.pearson_ref), protos), "oracle xla:cpu"))

    flat = jax.random.normal(key, (20, 65536))
    labels = jax.random.randint(key, (20,), 0, 5)
    mix = mixing_matrix(labels, 5)
    rows.append(("cluster_agg_pallas_20x64k",
                 time_us(lambda: ops.cluster_aggregate(flat, labels, 5)),
                 "interpret"))
    rows.append(("cluster_agg_ref_20x64k",
                 time_us(jax.jit(ref.cluster_agg_ref), flat, mix),
                 "oracle xla:cpu"))

    bits = jax.random.bits(key, (100, 8192), dtype=jnp.uint32)
    from repro.kernels.fingerprint import poly_weights
    fw = jnp.asarray(poly_weights(8192))
    rows.append(("fingerprint_pallas_100x8k",
                 time_us(ops.fingerprint, bits, iters=2),
                 "interpret (slow: python kernel body)"))
    rows.append(("fingerprint_ref_100x8k",
                 time_us(jax.jit(ref.fingerprint_ref), bits, fw),
                 "oracle xla:cpu"))

    q = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(key, (1, 512, 2, 64), jnp.float32)
    rows.append(("flash_attn_pallas_512", time_us(
        lambda: ops.attention(q, k, k, causal=True)), "interpret"))
    rows.append(("flash_attn_ref_512", time_us(
        jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True)),
        q, k, k), "oracle xla:cpu"))

    r = jax.random.normal(key, (1, 2, 128, 32))
    w = jax.nn.sigmoid(jax.random.normal(key, (1, 2, 128, 32))) * 0.4 + 0.55
    u = jax.random.normal(key, (2, 32)) * 0.1
    s0 = jnp.zeros((1, 2, 32, 32))
    rows.append(("rwkv6_scan_pallas_T128", time_us(
        lambda: ops.rwkv6_wkv(r, r, r, w, u, s0)), "interpret"))
    rows.append(("rwkv6_scan_ref_T128", time_us(
        jax.jit(ref.rwkv6_scan_ref), r, r, r, w, u, s0), "oracle xla:cpu"))

    for name, us, derived in rows:
        print(f"kernel,{name},{us:.1f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    main()

"""Commit-path throughput: legacy `hash_params` loop vs batched fingerprints.

Measures one full commit+verify round — client commitments, producer
aggregation record, block packing, consensus verification — over a
100-client cohort of the 1000-client sim population's model, two ways:

  * ``hash_params`` baseline (retired hot path): a Python loop that
    `device_get`s every cohort member's FULL params and SHA-256s them —
    `O(cohort · N_params)` host bytes per round;
  * batched fingerprint pipeline (`repro.kernels.fingerprint` +
    `repro.blockchain.commit`): ONE jitted device pass, `O(cohort)` digest
    bytes to the host, sender-bound Merkle commitments.

Also checks the two pipelines agree on every verification decision under
tamper, and that the new pipeline's block hashes replay identically.

Prints ``commit,<name>,<us_per_round>,<derived>`` CSV like the other benches.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.blockchain import (
    AGG_COMMIT_KIND,
    Blockchain,
    RoundCommitments,
    Transaction,
    TxPool,
    hash_params,
)
from repro.kernels.fingerprint import cohort_digests
from repro.models import classifier as clf
from repro.utils.tree import tree_bytes, tree_index

POPULATION = 1000
COHORT = 100


def _cohort_params():
    cfg = clf.MLPConfig(in_dim=64, hidden=(64,), rep_dim=32, num_classes=10)
    stacked = clf.init_stacked(cfg, jax.random.PRNGKey(0), POPULATION)
    return jax.tree.map(lambda x: x[:COHORT], stacked)


def _tamper_slots():
    return {3: "deadbeef" * 3, 42: "cafef00d" * 3}   # digest substitutions


def round_legacy(params, tamper) -> tuple[Blockchain, np.ndarray]:
    """Retired pipeline: per-client device_get + SHA-256, set-membership."""
    chain, pool = Blockchain(), TxPool()
    honest = []
    for slot in range(COHORT):
        h = hash_params(tree_index(params, slot))
        pool.submit(Transaction("model_hash", slot, tamper.get(slot, h), 0))
        honest.append(h)
    pool.submit(Transaction("agg_hash", 0, json.dumps(sorted(honest)), 0))
    block = chain.pack_block(0, 0, pool)
    return chain, chain.verify_round(block, COHORT)


def round_fingerprint(params, tamper) -> tuple[Blockchain, np.ndarray]:
    """Batched pipeline: one jitted fingerprint pass, sender-bound commit."""
    chain, pool = Blockchain(), TxPool()
    digests = cohort_digests(params)
    for slot in range(COHORT):
        pool.submit(Transaction("model_hash", slot,
                                tamper.get(slot, digests[slot]), 0))
    commits = RoundCommitments(0, tuple(enumerate(digests)))
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, commits.to_payload(), 0))
    block = chain.pack_block(0, 0, pool)
    return chain, chain.verify_round(block, COHORT)


def _time_rounds(fn, params, tamper, iters: int) -> float:
    fn(params, tamper)                               # warm (jit compile)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(params, tamper)
    return (time.perf_counter() - t0) / iters * 1e6


def main(iters: int = 5) -> None:
    params = _cohort_params()
    tamper = _tamper_slots()

    _, dec_legacy = round_legacy(params, tamper)
    _, dec_fast = round_fingerprint(params, tamper)
    assert (dec_legacy == dec_fast).all(), "verification decisions diverge"
    expected = np.array([s not in tamper for s in range(COHORT)])
    assert (dec_fast == expected).all()

    chain_a, _ = round_fingerprint(params, tamper)
    chain_b, _ = round_fingerprint(params, tamper)
    assert [b.block_hash() for b in chain_a.blocks] == \
        [b.block_hash() for b in chain_b.blocks], "block hashes not replayable"

    us_legacy = _time_rounds(round_legacy, params, tamper, iters)
    us_fast = _time_rounds(round_fingerprint, params, tamper, iters)
    speedup = us_legacy / us_fast

    host_bytes_legacy = tree_bytes(params)           # full cohort params
    host_bytes_fast = COHORT * 8                     # 2 × uint32 per client
    print(f"commit,hash_params_baseline,{us_legacy:.0f},"
          f"cohort={COHORT} host_bytes={host_bytes_legacy}")
    print(f"commit,fingerprint_pipeline,{us_fast:.0f},"
          f"cohort={COHORT} host_bytes={host_bytes_fast} "
          f"speedup={speedup:.1f}x decisions_match=True replay_identical=True")
    if speedup < 10:
        print(f"commit,WARNING,0,speedup {speedup:.1f}x below the 10x target")


if __name__ == "__main__":
    main()

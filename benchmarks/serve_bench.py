"""Serving-tier benchmark: latency + throughput through `repro.serve`.

Trains a small population (`repro.api.run`), snapshots it into a
chain-verified model bank, then drives the batched serving frontend with a
wall clock at several concurrency levels — open loop: each step submits
``concurrency`` mixed-cluster requests and pumps them through one fused
dispatch.  Reports per-request p50/p99 latency (submit -> completion,
including queue wait) and sustained requests/sec into ``BENCH_serve.json``,
plus snapshot/verify cost and the per-bucket compile counts.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \\
        [--out BENCH_serve.json] [--levels 1,8,32,64]

``--smoke`` shrinks the trained population and the request count for CI;
the output schema is identical.
"""
from __future__ import annotations

import argparse
import json
import time


def build_serving(n_clients: int, rounds: int, n_clusters: int, seed: int):
    """Train, snapshot, verify; returns (result, bank, engine, timings)."""
    import repro.api as api
    from repro.serve import ServingEngine, snapshot, verify_bank

    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=n_clients),
        train=api.TrainSpec(rounds=rounds, sample_frac=0.3,
                            n_clusters=n_clusters),
        eval=api.EvalSpec(every=0, clients=16, examples=64),
        seed=seed)
    t0 = time.perf_counter()
    result = api.run(spec)
    train_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    bank = snapshot(result, verify=False)
    snapshot_ms = (time.perf_counter() - t0) * 1e3
    chain = result.sim.trainer.chain
    t0 = time.perf_counter()
    verify_bank(bank, chain)
    verify_ms = (time.perf_counter() - t0) * 1e3
    engine = ServingEngine(bank, chain)
    return result, bank, engine, {
        "train_s": round(train_s, 2),
        "snapshot_ms": round(snapshot_ms, 2),
        "verify_ms": round(verify_ms, 2),
    }


def bench_level(engine, concurrency: int, n_requests: int, seed: int) -> dict:
    """Open-loop serving at one concurrency level, wall-clocked."""
    import numpy as np

    from repro.serve import ServeConfig, ServeFrontend

    bank = engine.bank
    buckets = tuple(b for b in (1, 2, 4, 8, 16, 32, 64, 128)
                    if b <= max(concurrency, 1)) or (1,)
    fe = ServeFrontend(
        engine, ServeConfig(buckets=buckets, max_wait=0.0,
                            max_pending=max(4 * concurrency, 64)),
        clock=time.perf_counter)
    rng = np.random.default_rng(seed)
    pool = rng.standard_normal((256, bank.mcfg.in_dim)).astype(np.float32)
    cids = rng.integers(0, bank.n_models, size=n_requests).astype(np.int32)

    # warm every bucket shape outside the timed region (compile happens
    # here); the engine is shared across levels so this cache size is
    # cumulative — it must equal the number of DISTINCT batch shapes seen
    # so far (1 compile per shape, never more)
    for b in buckets:
        for i in range(b):
            fe.submit(int(cids[i]), pool[i % 256])
        fe.pump()
    fe.take_completed()
    compiles = dict(engine.cache_sizes())

    latencies, served = [], 0
    t_start = time.perf_counter()
    i = 0
    while served < n_requests:
        burst = min(concurrency, n_requests - served)
        for _ in range(burst):
            fe.submit(int(cids[i]), pool[i % 256])
            i += 1
        fe.pump()
        fe.drain()
        for c in fe.take_completed():
            latencies.append((c.t_done - c.t_arrival) * 1e3)
            served += 1
    wall_s = time.perf_counter() - t_start

    lat = np.asarray(latencies)
    return {
        "concurrency": concurrency,
        "requests": int(served),
        "p50_ms": round(float(np.percentile(lat, 50)), 4),
        "p99_ms": round(float(np.percentile(lat, 99)), 4),
        "mean_ms": round(float(lat.mean()), 4),
        "req_per_s": round(served / wall_s, 1),
        "flushes": fe.n_flushes,
        "engine_cache_sizes": compiles,
    }


def routing_check(engine, seed: int) -> bool:
    """Self-check: one mixed batch bitwise-equal to per-request routing."""
    import numpy as np

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, engine.bank.mcfg.in_dim)).astype(np.float32)
    cids = rng.integers(0, engine.bank.n_models, size=8).astype(np.int32)
    fused = np.asarray(engine.forward(x, cids))
    oracle = np.asarray(engine.forward_per_request(x, cids))
    return bool(np.array_equal(fused, oracle))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small population, few requests)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--levels", default="1,8,32,64",
                    help="comma-separated concurrency levels")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per level (default 2048; smoke 256)")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.launch.platform import bootstrap
    bootstrap(None)

    n_clients = args.clients or (60 if args.smoke else 200)
    rounds = args.rounds or (2 if args.smoke else 5)
    n_requests = args.requests or (256 if args.smoke else 2048)
    levels = [int(s) for s in args.levels.split(",") if s]

    result, bank, engine, timings = build_serving(
        n_clients, rounds, args.clusters, args.seed)
    print(f"trained n={n_clients} rounds={rounds} in {timings['train_s']}s; "
          f"bank {bank.n_models}x{bank.n_params} params, snapshot "
          f"{timings['snapshot_ms']}ms, verify {timings['verify_ms']}ms")

    ok = routing_check(engine, args.seed)
    if not ok:
        raise SystemExit("routing check FAILED: fused mixed-batch dispatch "
                         "is not bitwise-identical to per-request routing")

    rows = [bench_level(engine, c, n_requests, args.seed + c)
            for c in levels]
    print(f"{'conc':>5} {'p50 ms':>9} {'p99 ms':>9} {'req/s':>10} "
          f"{'flushes':>8}")
    for r in rows:
        print(f"{r['concurrency']:>5} {r['p50_ms']:>9.3f} "
              f"{r['p99_ms']:>9.3f} {r['req_per_s']:>10.1f} "
              f"{r['flushes']:>8}")

    doc = {
        "bench": "serve",
        "smoke": bool(args.smoke),
        "n_clients": n_clients,
        "rounds": rounds,
        "n_clusters": bank.n_models,
        "n_params": bank.n_params,
        "bank_bytes": bank.nbytes,
        "release_block": bank.block_hash[:16],
        "routing_bitwise_ok": ok,
        **timings,
        "levels": rows,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

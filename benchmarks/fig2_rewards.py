"""Paper Figure 2: reward trends vs cluster membership.

Runs BFLN with 2 and 7 clusters, dumps per-client cumulative rewards and
per-round cluster sizes, and checks the paper's qualitative claims:
  * clients in larger clusters accumulate more tokens,
  * more clusters -> more dispersed reward distribution.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import run_fl


def main(rounds: int = 10, out_path: str = "experiments/fig2.json"):
    out = {}
    for n_clusters in (2, 7):
        tr, _ = run_fl("synth10", 0.1, "bfln", rounds=rounds,
                       n_clusters=n_clusters)
        rewards = np.stack([h.rewards for h in tr.history])          # (R, m)
        sizes = np.stack([h.cluster_sizes[h.labels] for h in tr.history])
        cum = rewards.sum(axis=0)
        mean_size = sizes.mean(axis=0)
        corr = float(np.corrcoef(cum, mean_size)[0, 1])
        spread = float(cum.std())
        out[f"clusters-{n_clusters}"] = {
            "cumulative_rewards": cum.tolist(),
            "mean_cluster_size": mean_size.tolist(),
            "reward_size_correlation": corr,
            "reward_spread": spread,
            "balances": tr.ledger.balances.tolist(),
            "chain_valid": tr.chain.validate(),
            "ledger_conserved": tr.ledger.conserved(),
        }
        print(f"fig2,clusters-{n_clusters},corr={corr:.3f},spread={spread:.3f}",
              flush=True)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()

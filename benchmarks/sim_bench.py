"""Simulator throughput: rounds/s and events/s across population scale,
sampling rate and mode.

Measures the event-driven federation simulator (`repro.sim`) end to end —
virtual-clock event processing + jitted cohort training + the host-side
blockchain protocol — on CPU.  The interesting scaling axes:

  * population size at fixed cohort (event machinery + ledger scale),
  * sampling rate at fixed population (cohort-training compile + run scale),
  * sync block slots vs async buffer flushes.

Prints ``sim,<name>,<us_per_round>,<derived>`` CSV like the other benches.
"""
from __future__ import annotations

import time

from repro.api import ExperimentSpec
from repro.sim import ClientPopulation, PopulationSpec, SimulatedFederation


def _warm(sim: SimulatedFederation) -> None:
    """Compile the jitted cohort program before timing (XLA compile is a
    one-time cost that would otherwise dominate a short run)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    cfg, pop = sim.cfg, sim.pop
    if cfg.mode == "sync":
        k = max(1, int(round(cfg.sample_frac * pop.n_clients)))
    else:
        k = cfg.buffer_size
    cohort = np.arange(k)
    cx, cy = pop.cohort_data(cohort)
    if sim.engine is not None:
        # arena engine: warm the fused step, then rebind (donated input)
        if cfg.mode == "sync":
            sim.arena.data, out = sim.engine.sync_step(
                sim.arena.data, jnp.asarray(cohort), cx, cy,
                jnp.zeros((k,), jnp.float32))   # zero mask: no-op scatter
            out = out.residues
        else:
            out, _, _ = sim.engine.async_step(sim.arena.data[:k], cx, cy)
    else:
        params = jax.tree.map(lambda x: x[:k], sim.params)
        if cfg.mode == "sync":
            out = sim._cohort_round(params, cx, cy, jnp.ones((k,), jnp.float32))
        else:
            out = sim._local_only(params, cx, cy)
    jax.block_until_ready(jax.tree.leaves(out)[0])


def _run_case(name: str, n_clients: int, rounds: int, **cfg_kw) -> tuple:
    spec = PopulationSpec(n_clients=n_clients, straggler_frac=0.1,
                          dropout_rate=0.03, byzantine_frac=0.05, seed=0)
    pop = ClientPopulation.from_spec(spec)
    cfg = ExperimentSpec.from_flat(rounds=rounds, eval_every=0, seed=0,
                                   **cfg_kw)
    sim = SimulatedFederation(pop, cfg)
    _warm(sim)
    t0 = time.perf_counter()
    rep = sim.run()
    wall = time.perf_counter() - t0
    us_per_round = wall / max(len(rep.history), 1) * 1e6
    ev_per_s = len(rep.event_log) / wall
    return (name, us_per_round,
            f"n={n_clients} rounds={len(rep.history)} "
            f"events={len(rep.event_log)} ev/s={ev_per_s:.0f} "
            f"acc={rep.final_accuracy:.3f}")


def main(quick: bool = True):
    rows = [
        _run_case("sync_n200_s10", 200, 6, sample_frac=0.10, n_clusters=3),
        _run_case("sync_n1000_s5", 1000, 5, sample_frac=0.05, n_clusters=5),
        _run_case("sync_n1000_s10", 1000, 5, sample_frac=0.10, n_clusters=5),
        _run_case("async_n1000_K16", 1000, 5, mode="async", buffer_size=16,
                  concurrency=64),
    ]
    if not quick:
        rows += [
            _run_case("sync_n2000_s10", 2000, 5, sample_frac=0.10,
                      n_clusters=5),
            _run_case("async_n2000_K32", 2000, 5, mode="async",
                      buffer_size=32, concurrency=128),
        ]
    for name, us, derived in rows:
        print(f"sim,{name},{us:.0f},{derived}", flush=True)
    return rows


if __name__ == "__main__":
    main(quick=False)

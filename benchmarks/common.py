"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core import FederatedTrainer, ModelBundle, make_bfln
from repro.core.baselines import STRATEGY_FACTORIES
from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.models import classifier as clf
from repro.optim import adam


def time_us(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run_fl(dataset: str, bias: float, strategy: str, *, n_clients: int = 20,
           rounds: int = 12, local_epochs: int = 2, n_batches: int = 4,
           batch_size: int = 64, n_clusters: int = 5, seed: int = 0,
           psi: int = 32):
    """One federated training run; returns (trainer, personalized_acc)."""
    (xt, yt), (xe, ye) = make_classification_dataset(dataset, seed=seed)
    parts = dirichlet_partition(yt, n_clients, bias, seed=seed)
    cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=n_batches,
                                  batch_size=batch_size, seed=seed)
    num_classes = int(yt.max()) + 1
    cfg = clf.MLPConfig(in_dim=xt.shape[1], hidden=(128,), rep_dim=64,
                        num_classes=num_classes)
    bundle = ModelBundle(functools.partial(clf.apply, cfg),
                         functools.partial(clf.embed, cfg), num_classes)
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(seed), n_clients)

    if strategy == "bfln":
        probe = jnp.asarray(sample_probe_batch(xt, yt, category=0, psi=psi,
                                               seed=seed))
        strat = make_bfln(bundle, probe, n_clusters)
        tr = FederatedTrainer(bundle, strat, adam(1e-3),
                              local_epochs=local_epochs, n_clusters=n_clusters)
    else:
        strat = STRATEGY_FACTORIES[strategy](bundle)
        tr = FederatedTrainer(bundle, strat, adam(1e-3),
                              local_epochs=local_epochs, use_chain=False)

    p, o = tr.init(sp)
    cx, cy = jnp.asarray(cx), jnp.asarray(cy)
    xe, ye = jnp.asarray(xe), jnp.asarray(ye)
    for r in range(rounds):
        p, o, _ = tr.run_round(r, p, o, cx, cy, xe, ye)

    from repro.core.fl import evaluate
    pacc = float(jnp.mean(evaluate(bundle.apply_fn, p, jnp.asarray(tx),
                                   jnp.asarray(ty))))
    return tr, pacc

"""Shared helpers for the benchmark harness.

Import-light on purpose: jax (and everything repro that pulls it in) is
imported inside the helpers, not at module scope, so a bench can ``import
common`` first, resolve its runtime environment with
``repro.launch.platform.bootstrap`` (device count / platform / XLA flags
must land before jax initialises), and only then call into these helpers.
"""
from __future__ import annotations

import time


def time_us(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run_fl(dataset: str, bias: float, strategy: str, *, n_clients: int = 20,
           rounds: int = 12, local_epochs: int = 2, n_batches: int = 4,
           batch_size: int = 64, n_clusters: int = 5, seed: int = 0,
           psi: int = 32):
    """One federated training run; returns (trainer, personalized_acc)."""
    import jax
    import jax.numpy as jnp

    from repro.api import build_strategy, load_packed_clients, make_mlp_bundle
    from repro.core import FederatedTrainer
    from repro.models import classifier as clf
    from repro.optim import adam

    data = load_packed_clients(dataset, n_clients, bias, n_batches=n_batches,
                               batch_size=batch_size, psi=psi, seed=seed)
    cfg, bundle = make_mlp_bundle(data.in_dim, data.num_classes)
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(seed), n_clients)

    strat = build_strategy(strategy, bundle, probe=data.probe,
                           n_clusters=n_clusters)
    tr = FederatedTrainer(bundle, strat, adam(1e-3),
                          local_epochs=local_epochs, n_clusters=n_clusters,
                          use_chain=(strategy == "bfln"))

    p, o = tr.init(sp)
    for r in range(rounds):
        p, o, _ = tr.run_round(r, p, o, data.cx, data.cy,
                               data.test_x, data.test_y)

    from repro.core.fl import evaluate
    pacc = float(jnp.mean(evaluate(bundle.apply_fn, p, jnp.asarray(data.tx),
                                   jnp.asarray(data.ty))))
    return tr, pacc

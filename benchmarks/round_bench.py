"""Sync-round hot path: legacy (pre-arena) driver vs the fused round engine.

Measures steady-state sync-round latency at population scale two ways on the
same seeded population:

  * ``engine=False`` — the retired hot path: eager per-leaf cohort gather,
    jitted train+PAA, a second jitted fingerprint pipeline, per-leaf scatter
    that reallocates the full (n_clients, N_params) stack every round, and a
    ``global_evaluate`` that jit-recompiles for every distinct arrived-client
    count;
  * ``engine=True`` — ONE donated fixed-shape jitted step per round
    (`repro.core.engine`): arena gather → train → PAA → digests → masked
    scatter-back, plus fixed-shape masked eval whose outputs stay on device
    until end of run.

The headline config evaluates every round on a 256-example shared test
slice: the eval recompile pathology this PR kills is *count*-dependent (one
compile per distinct arrival count), not eval-size-dependent, and a larger
metric batch only adds identical GEMM time to both paths, drowning the
round being measured.  A heavy-eval variant (the SimConfig default 1024
examples) is measured and reported alongside.

Also asserts the two paths replay identically (block hashes + balances) and
that the engine compiled each used entry exactly once, then emits
``BENCH_round.json`` (steady-state round ms, compile counts, peak host
bytes, per-round population realloc) so the perf trajectory is tracked PR
over PR.

Prints ``round,<name>,<us_per_round>,<derived>`` CSV like the other benches.
"""
from __future__ import annotations

import argparse
import json
import time
import tracemalloc

import numpy as np

from repro.sim import ClientPopulation, PopulationSpec, SimConfig, SimulatedFederation
from repro.utils.tree import tree_bytes

WARMUP = 3            # rounds excluded from the steady-state mean (compiles)


def _build(engine: bool, n_clients: int, sample_frac: float, rounds: int,
           eval_examples: int) -> SimulatedFederation:
    # fresh population per driver: LatencyModel draws advance an internal rng,
    # so sharing one instance would desynchronise the second run
    spec = PopulationSpec(n_clients=n_clients, straggler_frac=0.1,
                          dropout_rate=0.03, byzantine_frac=0.05, seed=0)
    pop = ClientPopulation.from_spec(spec)
    cfg = SimConfig(rounds=rounds, sample_frac=sample_frac, n_clusters=5,
                    eval_every=1, eval_examples=eval_examples, seed=0,
                    engine=engine)
    return SimulatedFederation(pop, cfg)


def _compile_counts(sim: SimulatedFederation) -> dict[str, int]:
    if sim.engine is not None:
        return sim.engine.cache_sizes()
    return {"_cohort_round": sim._cohort_round._cache_size(),
            "_eval": sim._eval._cache_size(),
            "_eval_final": sim._eval_final._cache_size()}


def _run(engine: bool, n_clients: int, sample_frac: float, rounds: int,
         eval_examples: int) -> dict:
    sim = _build(engine, n_clients, sample_frac, rounds, eval_examples)
    times_ms = []
    for r in range(rounds):
        t0 = time.perf_counter()
        sim.history.append(sim._run_sync_round(r))
        times_ms.append((time.perf_counter() - t0) * 1e3)
    sim._finalize_history()        # drain deferred (overlapped) eval outputs

    # population-allocation metric: the engine donates the arena (in-place
    # update, 0 bytes); the legacy scatter rebuilds the full stacked pytree
    if engine:
        ptr = sim.arena.data.unsafe_buffer_pointer()
        realloc = 0
    else:
        ptr = None
        realloc = tree_bytes(sim.params)
    # separate phase: tracemalloc slows every Python allocation, so host-byte
    # accounting runs over extra (untimed) steady-state rounds
    tracemalloc.start()
    for r in range(rounds, rounds + 5):
        sim.history.append(sim._run_sync_round(r))
    sim._finalize_history()
    _, peak_host = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    if engine:
        assert sim.arena.data.unsafe_buffer_pointer() == ptr, \
            "arena buffer was reallocated (donation regressed)"

    steady = times_ms[WARMUP:] or times_ms
    counts = sorted({int(rec.arrived.sum()) for rec in sim.history})
    return {
        "engine": engine,
        "rounds": rounds,
        "first_round_ms": round(times_ms[0], 2),
        "steady_ms": round(float(np.mean(steady)), 3),
        "steady_p50_ms": round(float(np.median(steady)), 3),
        "distinct_arrival_counts": len(counts),
        "compile_counts": _compile_counts(sim),
        "peak_host_bytes": int(peak_host),
        "population_realloc_bytes_per_round": int(realloc),
        "block_hashes": [b.block_hash() for b in sim.trainer.chain.blocks],
        "balances": sim.trainer.ledger.balances,
    }


def _case(n_clients: int, sample_frac: float, rounds: int,
          eval_examples: int) -> dict:
    legacy = _run(False, n_clients, sample_frac, rounds, eval_examples)
    engine = _run(True, n_clients, sample_frac, rounds, eval_examples)

    # correctness gates: identical replay, exactly one compile per used entry
    assert legacy["block_hashes"] == engine["block_hashes"], \
        "engine replay diverged from the legacy driver"
    assert np.array_equal(legacy["balances"], engine["balances"])
    used = {k: v for k, v in engine["compile_counts"].items() if v}
    assert all(v == 1 for v in used.values()), \
        f"engine entry recompiled: {engine['compile_counts']}"
    assert engine["distinct_arrival_counts"] > 1, \
        "benchmark population produced constant arrival counts"

    drop = ("block_hashes", "balances", "engine", "rounds")
    return {
        "eval_examples": eval_examples,
        "distinct_arrival_counts": engine["distinct_arrival_counts"],
        "legacy": {k: v for k, v in legacy.items() if k not in drop},
        "engine": {k: v for k, v in engine.items() if k not in drop},
        "steady_speedup": round(legacy["steady_ms"] / engine["steady_ms"], 2),
        "replay_identical": True,
    }


def main(n_clients: int = 1000, sample_frac: float = 0.10, rounds: int = 50,
         out: str = "BENCH_round.json", heavy_eval: bool = True) -> dict:
    cases = {"headline_eval256": _case(n_clients, sample_frac, rounds, 256)}
    if heavy_eval:
        cases["heavy_eval1024"] = _case(n_clients, sample_frac, rounds, 1024)

    result = {
        "bench": "round",
        "n_clients": n_clients,
        "cohort": max(1, int(round(sample_frac * n_clients))),
        "rounds": rounds,
        **cases,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=2)

    for cname, case in cases.items():
        for side in ("legacy", "engine"):
            row = case[side]
            print(f"round,{cname}_{side},{row['steady_ms'] * 1e3:.0f},"
                  f"n={n_clients} cohort={result['cohort']} rounds={rounds} "
                  f"first_ms={row['first_round_ms']} "
                  f"compiles={sum(row['compile_counts'].values())} "
                  f"realloc_mb_per_round="
                  f"{row['population_realloc_bytes_per_round'] / 1e6:.1f}")
        print(f"round,{cname}_speedup,{case['steady_speedup']:.2f},"
              f"replay_identical=True "
              f"arrival_counts={case['distinct_arrival_counts']} "
              f"engine_compiles_per_entry=1")
    headline = cases["headline_eval256"]["steady_speedup"]
    print(f"round,result,{headline:.2f},-> {out}")
    if headline < 5:
        print(f"round,WARNING,0,headline speedup {headline:.2f}x below the "
              f"5x target")
    return result


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: small population, few rounds, no heavy case")
    p.add_argument("--n-clients", type=int, default=None)
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--out", default="BENCH_round.json")
    args = p.parse_args()
    n = args.n_clients or (200 if args.quick else 1000)
    r = args.rounds or (10 if args.quick else 50)
    main(n_clients=n, rounds=r, out=args.out, heavy_eval=not args.quick)

"""Sync-round hot path: legacy (pre-arena) driver vs the fused round engine.

Measures steady-state sync-round latency at population scale two ways on the
same seeded population:

  * ``engine=False`` — the retired hot path: eager per-leaf cohort gather,
    jitted train+PAA, a second jitted fingerprint pipeline, per-leaf scatter
    that reallocates the full (n_clients, N_params) stack every round, and a
    ``global_evaluate`` that jit-recompiles for every distinct arrived-client
    count;
  * ``engine=True`` — ONE donated fixed-shape jitted step per round
    (`repro.core.engine`): arena gather → train → PAA → digests → masked
    scatter-back, plus fixed-shape masked eval whose outputs stay on device
    until end of run.

The headline config evaluates every round on a 256-example shared test
slice: the eval recompile pathology this PR kills is *count*-dependent (one
compile per distinct arrival count), not eval-size-dependent, and a larger
metric batch only adds identical GEMM time to both paths, drowning the
round being measured.  A heavy-eval variant (the SimConfig default 1024
examples) is measured and reported alongside.

A third variant — ``mesh_shards=8`` — runs the SAME fused engine with the
parameter arena row-sharded over an 8-device client mesh
(`repro.runtime.arena.ShardedParamArena`): per-device population state drops
to n/8 rows while replay stays bit-identical (asserted).  The sharded run
executes in a SUBPROCESS that self-forces
``--xla_force_host_platform_device_count`` — forcing the device count in the
main process would split the CPU thread pool and skew the legacy/engine
timings this file has tracked since PR 3.  The cross-process block-hash /
balance comparison therefore doubles as a replay gate across device
topologies.  The cohort axis is sharded end-to-end (PR 7): each device
trains its slice of the cohort and aggregation combines shard-local
partials with a fixed-order tree, so the sharded latency column measures
real cohort-parallel execution on a forced CPU mesh (8 logical devices on
one physical CPU); ``per_device_arena_bytes`` is the scaling headline.

``--mesh-shards`` also drives a shard-count sweep (1/2/4/8, capped at the
flag) of the steady engine round — every width replaying bit-identically —
recorded as the ``sharded_sweep`` section; ``--sweep-only`` refreshes just
that section, merging into an existing ``BENCH_round.json``.

Also asserts the paths replay identically (block hashes + balances) and
that the engine compiled each used entry exactly once, then emits
``BENCH_round.json`` (steady-state round ms, compile counts, peak host
bytes, per-round population realloc, per-device arena bytes) so the perf
trajectory is tracked PR over PR.

The engine is strategy-generic (PR 5): ``--strategy`` picks the strategy
for the headline legacy-vs-engine case, and a per-strategy sweep records
the steady engine round latency of EVERY registered strategy (bfln,
fedavg, fedprox, fedproto, fedhkd) into ``per_strategy_steady_ms`` —
each asserted at 1 compile per entry.

``--mode async`` (or ``both``) adds the FedBuff lane: legacy vs engine
buffered-flush latency through ``async_step``, steady state past warmup,
with flush timings and staleness / staleness-weight distributions pulled
from the `repro.obs` flight recorder, the same cross-driver replay gate
(block hashes + balances identical), and a 1-compile assert on
``async_step``.  Results land in the ``"async"`` section of
``BENCH_round.json`` (merged into an existing file when run async-only).

``--trace`` re-runs the headline engine case with the flight recorder on
(JSONL trace ``round_bench_trace.jsonl`` + per-phase console table), so
the per-phase round breakdown and the trace-on vs trace-off steady delta
are visible next to the bench numbers.

``--checkpoint-interval N`` (default 10) adds the checkpoint-overhead lane
(`repro.checkpoint`): the steady engine round with a full-state snapshot
every N rounds vs without — amortised overhead (<10% budget at N=10),
snapshot size, and isolated save/restore latency, recorded as the
``"checkpoint"`` section with replay asserted bit-identical.

Prints ``round,<name>,<us_per_round>,<derived>`` CSV like the other benches.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import tracemalloc

if __name__ == "__main__":
    # sharded worker mode: needs the multi-device CPU platform, and XLA_FLAGS
    # must be set before jax initialises (the repro.sim import below) —
    # pre-parse the shard count and re-exec once with the forced device count
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--mesh-shards", type=int, default=8)
    _pre.add_argument("--sharded-only", default=None)
    _ns = _pre.parse_known_args()[0]
    if _ns.sharded_only is not None:
        from repro.launch.bootstrap import force_host_device_count
        force_host_device_count(_ns.mesh_shards)

import jax
import numpy as np

from repro.sim import ClientPopulation, PopulationSpec, SimulatedFederation
from repro.utils.tree import tree_bytes

WARMUP = 3            # rounds excluded from the steady-state mean (compiles)


def _build(engine: bool, n_clients: int, sample_frac: float, rounds: int,
           eval_examples: int, mesh_shards: int = 1,
           strategy: str = "bfln", mode: str = "sync",
           trace: bool = False, ckpt_interval: int = 0,
           ckpt_dir: str = "checkpoints") -> SimulatedFederation:
    import repro.api as api

    # fresh population per driver: LatencyModel draws advance an internal rng,
    # so sharing one instance would desynchronise the second run
    pspec = PopulationSpec(n_clients=n_clients, straggler_frac=0.1,
                           dropout_rate=0.03, byzantine_frac=0.05, seed=0)
    pop = ClientPopulation.from_spec(pspec)
    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=n_clients, straggler_frac=0.1,
                          dropout_rate=0.03, byzantine_frac=0.05),
        train=api.TrainSpec(strategy=strategy, rounds=rounds,
                            sample_frac=sample_frac, n_clusters=5,
                            mode=mode),
        async_=api.AsyncSpec(
            buffer_size=max(1, int(round(sample_frac * n_clients))),
            concurrency=min(256, max(2, n_clients // 4))),
        eval=api.EvalSpec(every=1, examples=eval_examples),
        mesh=api.MeshSpec(shards=mesh_shards),
        obs=api.ObsSpec(enabled=True, trace_path="round_bench_trace.jsonl")
        if trace else api.ObsSpec(),
        checkpoint=api.CheckpointSpec(interval=ckpt_interval, dir=ckpt_dir),
        engine=engine, seed=0)
    return SimulatedFederation(pop, spec)


def _compile_counts(sim: SimulatedFederation) -> dict[str, int]:
    if sim.engine is not None:
        return sim.engine.cache_sizes()
    return {"_cohort_round": sim._cohort_round._cache_size(),
            "_eval": sim._eval._cache_size(),
            "_eval_final": sim._eval_final._cache_size()}


def _arena_ptrs(sim: SimulatedFederation) -> list[int]:
    """Per-shard device buffer pointers (1 entry when unsharded)."""
    if sim.cfg.mesh_shards > 1:
        return [s.data.unsafe_buffer_pointer()
                for s in sim.arena.data.addressable_shards]
    return [sim.arena.data.unsafe_buffer_pointer()]


def _run(engine: bool, n_clients: int, sample_frac: float, rounds: int,
         eval_examples: int, mesh_shards: int = 1,
         strategy: str = "bfln") -> dict:
    sim = _build(engine, n_clients, sample_frac, rounds, eval_examples,
                 mesh_shards, strategy)
    times_ms = []
    for r in range(rounds):
        t0 = time.perf_counter()
        sim.history.append(sim._run_sync_round(r))
        times_ms.append((time.perf_counter() - t0) * 1e3)
    sim._finalize_history()        # drain deferred (overlapped) eval outputs

    # population-allocation metric: the engine donates the arena (in-place
    # update, 0 bytes); the legacy scatter rebuilds the full stacked pytree
    if engine:
        ptrs = _arena_ptrs(sim)
        realloc = 0
    else:
        ptrs = None
        realloc = tree_bytes(sim.params)
    # separate phase: tracemalloc slows every Python allocation, so host-byte
    # accounting runs over extra (untimed) steady-state rounds
    tracemalloc.start()
    for r in range(rounds, rounds + 5):
        sim.history.append(sim._run_sync_round(r))
    sim._finalize_history()
    _, peak_host = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    if engine:
        assert _arena_ptrs(sim) == ptrs, \
            "arena buffer was reallocated (donation regressed)"

    steady = times_ms[WARMUP:] or times_ms
    counts = sorted({int(rec.arrived.sum()) for rec in sim.history})
    out = {
        "engine": engine,
        "strategy": strategy,
        "rounds": rounds,
        "first_round_ms": round(times_ms[0], 2),
        "steady_ms": round(float(np.mean(steady)), 3),
        "steady_p50_ms": round(float(np.median(steady)), 3),
        "distinct_arrival_counts": len(counts),
        "compile_counts": _compile_counts(sim),
        "peak_host_bytes": int(peak_host),
        "population_realloc_bytes_per_round": int(realloc),
        "block_hashes": [b.block_hash() for b in sim.trainer.chain.blocks],
        "balances": sim.trainer.ledger.balances,
    }
    if mesh_shards > 1:
        out["mesh_shards"] = mesh_shards
        out["per_device_arena_bytes"] = sim.arena.per_device_bytes()
        out["arena_total_bytes"] = int(sim.arena.data.nbytes)
    elif engine:
        out["per_device_arena_bytes"] = int(sim.arena.data.nbytes)
    return out


def _sharded_run(n_clients: int, sample_frac: float, rounds: int,
                 eval_examples: int, mesh_shards: int,
                 strategy: str = "bfln") -> dict:
    """The mesh-sharded engine run — in-process when enough devices already
    exist, otherwise via a ``--sharded-only`` subprocess that self-forces the
    CPU device count (keeping THIS process single-device so the legacy and
    engine timings stay comparable with the pre-mesh trajectory)."""
    import jax
    if mesh_shards <= len(jax.devices()):
        return _run(True, n_clients, sample_frac, rounds, eval_examples,
                    mesh_shards, strategy)
    payload = json.dumps({"n_clients": n_clients, "sample_frac": sample_frac,
                          "rounds": rounds, "eval_examples": eval_examples,
                          "strategy": strategy})
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--sharded-only", payload,
         "--mesh-shards", str(mesh_shards)],
        capture_output=True, text=True, env=dict(os.environ), timeout=7200)
    if out.returncode != 0:
        raise RuntimeError(f"sharded worker failed:\n{out.stderr[-4000:]}")
    return json.loads(out.stdout.splitlines()[-1])


def _case(n_clients: int, sample_frac: float, rounds: int,
          eval_examples: int, mesh_shards: int = 1,
          strategy: str = "bfln") -> dict:
    legacy = _run(False, n_clients, sample_frac, rounds, eval_examples,
                  strategy=strategy)
    engine = _run(True, n_clients, sample_frac, rounds, eval_examples,
                  strategy=strategy)

    # correctness gates: identical replay, exactly one compile per used entry
    assert legacy["block_hashes"] == engine["block_hashes"], \
        "engine replay diverged from the legacy driver"
    assert np.array_equal(legacy["balances"], engine["balances"])
    used = {k: v for k, v in engine["compile_counts"].items() if v}
    assert all(v == 1 for v in used.values()), \
        f"engine entry recompiled: {engine['compile_counts']}"
    assert engine["distinct_arrival_counts"] > 1, \
        "benchmark population produced constant arrival counts"

    drop = ("block_hashes", "balances", "engine", "rounds")
    case = {
        "strategy": strategy,
        "eval_examples": eval_examples,
        "distinct_arrival_counts": engine["distinct_arrival_counts"],
        "legacy": {k: v for k, v in legacy.items() if k not in drop},
        "engine": {k: v for k, v in engine.items() if k not in drop},
        "steady_speedup": round(legacy["steady_ms"] / engine["steady_ms"], 2),
        "replay_identical": True,
    }
    if mesh_shards > 1:
        sharded = _sharded_run(n_clients, sample_frac, rounds, eval_examples,
                               mesh_shards, strategy)
        # the sharded engine must replay bit-identically to both others
        assert sharded["block_hashes"] == engine["block_hashes"], \
            "sharded replay diverged from the single-device engine"
        assert np.array_equal(np.asarray(sharded["balances"]),
                              np.asarray(engine["balances"]))
        used = {k: v for k, v in sharded["compile_counts"].items() if v}
        assert all(v == 1 for v in used.values()), \
            f"sharded entry recompiled: {sharded['compile_counts']}"
        case["sharded"] = {k: v for k, v in sharded.items() if k not in drop}
        case["sharded_round_overhead"] = round(
            sharded["steady_ms"] / engine["steady_ms"], 2)
        case["arena_bytes_per_device_reduction"] = round(
            engine["per_device_arena_bytes"]
            / sharded["per_device_arena_bytes"], 2)
    return case


def _async_run(engine: bool, n_clients: int, sample_frac: float,
               flushes: int, eval_examples: int,
               strategy: str = "bfln") -> dict:
    """One FedBuff async lane: run ``flushes`` buffer flushes with the flight
    recorder on and report steady flush latency + staleness metrics straight
    from the obs registry (`repro.obs`)."""
    sim = _build(engine, n_clients, sample_frac, flushes, eval_examples,
                 strategy=strategy, mode="async", trace=True)
    t0 = time.perf_counter()
    sim._run_async()
    wall_s = time.perf_counter() - t0
    sim._finalize_history()

    flush_ms = [r["dur_us"] / 1e3 for r in sim.obs.records
                if r["kind"] == "span" and r["name"] == "flush.total"]
    steady = flush_ms[WARMUP:] or flush_ms
    snap = sim.obs.metrics.snapshot()
    out = {
        "engine": engine,
        "strategy": strategy,
        "flushes_run": len(flush_ms),
        "first_flush_ms": round(flush_ms[0], 2) if flush_ms else None,
        "steady_flush_ms": round(float(np.mean(steady)), 3),
        "steady_flush_p50_ms": round(float(np.median(steady)), 3),
        "wall_s": round(wall_s, 2),
        "staleness": snap["summaries"].get("async.staleness"),
        "staleness_weight": snap["summaries"].get("async.staleness_weight"),
        "compile_counts": _compile_counts(sim) if engine else None,
        "block_hashes": [b.block_hash() for b in sim.trainer.chain.blocks],
        "balances": sim.trainer.ledger.balances,
    }
    return out


def _async_case(n_clients: int, sample_frac: float, flushes: int,
                eval_examples: int, strategy: str = "bfln") -> dict:
    """The async lane: engine vs legacy FedBuff flushes on the same seeded
    population — replay gate (block hashes + balances) plus the engine's
    1-compile ``async_step`` contract."""
    legacy = _async_run(False, n_clients, sample_frac, flushes, eval_examples,
                        strategy=strategy)
    engine = _async_run(True, n_clients, sample_frac, flushes, eval_examples,
                        strategy=strategy)
    assert legacy["block_hashes"] == engine["block_hashes"], \
        "async engine replay diverged from the legacy driver"
    assert np.array_equal(legacy["balances"], engine["balances"])
    used = {k: v for k, v in engine["compile_counts"].items() if v}
    assert all(v == 1 for v in used.values()), \
        f"async engine entry recompiled: {engine['compile_counts']}"
    assert used.get("async_step") == 1, \
        f"async_step not exercised/compiled once: {engine['compile_counts']}"
    drop = ("block_hashes", "balances", "engine")
    return {
        "strategy": strategy,
        "buffer_size": max(1, int(round(sample_frac * n_clients))),
        "legacy": {k: v for k, v in legacy.items() if k not in drop},
        "engine": {k: v for k, v in engine.items() if k not in drop},
        "steady_flush_speedup": round(
            legacy["steady_flush_ms"] / engine["steady_flush_ms"], 2),
        "replay_identical": True,
    }


def _sharded_sweep(n_clients: int, sample_frac: float, rounds: int,
                   eval_examples: int, shard_counts: list[int],
                   strategy: str = "bfln") -> dict:
    """Steady engine round latency at each client-mesh width.

    Every width must replay bit-identically to the 1-device engine (block
    hashes + balances) and compile each used entry exactly once; widths
    beyond the available device count run via the self-forcing
    ``--sharded-only`` subprocess so THIS process stays single-device."""
    rows = {}
    base = None
    for s in shard_counts:
        row = (_run(True, n_clients, sample_frac, rounds, eval_examples,
                    strategy=strategy)
               if s == 1 else
               _sharded_run(n_clients, sample_frac, rounds, eval_examples,
                            s, strategy))
        if base is None:
            base = row
        else:
            assert row["block_hashes"] == base["block_hashes"], \
                f"sharded sweep: shards={s} replay diverged"
            assert np.array_equal(np.asarray(row["balances"]),
                                  np.asarray(base["balances"]))
        used = {k: v for k, v in row["compile_counts"].items() if v}
        assert all(v == 1 for v in used.values()), \
            f"sharded sweep: shards={s} recompiled: {row['compile_counts']}"
        rows[str(s)] = {
            "steady_ms": row["steady_ms"],
            "steady_p50_ms": row["steady_p50_ms"],
            "first_round_ms": row["first_round_ms"],
            "per_device_arena_bytes": row["per_device_arena_bytes"],
            "speedup_vs_1": round(base["steady_ms"] / row["steady_ms"], 2),
        }
    return {"shard_counts": shard_counts, "eval_examples": eval_examples,
            "rounds": rounds, "strategy": strategy,
            "replay_identical": True, "per_shards": rows}


def _checkpoint_case(n_clients: int, sample_frac: float, rounds: int,
                     eval_examples: int, interval: int) -> dict:
    """Checkpoint-overhead lane: the steady engine round with snapshots every
    ``interval`` rounds vs without, plus the snapshot's own save/restore
    latency and on-disk size.  The amortised overhead at the default
    interval=10 is the <10% acceptance headline; replay is asserted
    bit-identical (checkpointing is a pure observer)."""
    import shutil
    import tempfile

    # Each timed round blocks on its own device work (arena rows + deferred
    # eval outputs).  The engine normally leaves those async so rounds
    # pipeline — but a snapshot capture is a full sync point, so without
    # per-round blocking the boundary round would be billed every OTHER
    # round's deferred compute and the overhead number would be fiction.
    def _settle(sim):
        rec = sim.history[-1]
        if not isinstance(rec.accuracy, float):
            jax.block_until_ready(rec.accuracy)
        jax.block_until_ready(sim.arena.data if sim.arena is not None
                              else sim._params)

    off = _build(True, n_clients, sample_frac, rounds, eval_examples)
    times_off = []
    for r in range(rounds):
        t0 = time.perf_counter()
        off.history.append(off._run_sync_round(r))
        _settle(off)
        times_off.append((time.perf_counter() - t0) * 1e3)
    off._finalize_history()

    tmp = tempfile.mkdtemp(prefix="round_bench_ckpt_")
    try:
        on = _build(True, n_clients, sample_frac, rounds, eval_examples,
                    ckpt_interval=interval, ckpt_dir=tmp)
        times_on = []
        for r in range(rounds):
            t0 = time.perf_counter()
            on.history.append(on._run_sync_round(r))
            on._maybe_checkpoint(r + 1)
            _settle(on)
            times_on.append((time.perf_counter() - t0) * 1e3)
        # retire the last in-flight background write inside the accounting —
        # the lane must charge every millisecond the writer blocked us for
        t0 = time.perf_counter()
        on._ckpt_wait()
        times_on[-1] += (time.perf_counter() - t0) * 1e3
        on._finalize_history()

        assert ([b.block_hash() for b in on.trainer.chain.blocks]
                == [b.block_hash() for b in off.trainer.chain.blocks]), \
            "checkpointing perturbed the replay"
        assert np.array_equal(on.trainer.ledger.balances,
                              off.trainer.ledger.balances)

        # isolated snapshot save/restore latency (outside the round timing)
        from repro.checkpoint import load_latest, save_checkpoint
        from repro.checkpoint.state import (
            capture_experiment_state,
            restore_experiment_state,
        )
        t0 = time.perf_counter()
        tree = capture_experiment_state(on, rounds)
        _, snap_bytes = save_checkpoint(tmp, rounds, tree)
        save_ms = (time.perf_counter() - t0) * 1e3
        fresh = _build(True, n_clients, sample_frac, rounds, eval_examples,
                       ckpt_interval=interval, ckpt_dir=tmp)
        t0 = time.perf_counter()
        _, tree = load_latest(tmp)
        restore_experiment_state(fresh, tree)
        restore_ms = (time.perf_counter() - t0) * 1e3
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    steady_off = float(np.mean(times_off[WARMUP:] or times_off))
    steady_on = float(np.mean(times_on[WARMUP:] or times_on))
    return {
        "interval": interval,
        "rounds": rounds,
        "steady_ms_off": round(steady_off, 3),
        "steady_ms_on": round(steady_on, 3),
        "overhead_pct": round(100.0 * (steady_on - steady_off) / steady_off,
                              2),
        "snapshot_bytes": int(snap_bytes),
        "save_ms": round(save_ms, 2),
        "restore_ms": round(restore_ms, 2),
        "replay_identical": True,
    }


def _strategy_sweep(n_clients: int, sample_frac: float, rounds: int,
                    eval_examples: int) -> dict:
    """Steady-round engine latency for EVERY registered strategy — the
    strategy-generic fused engine's per-strategy cost (1 compile per entry
    asserted for each)."""
    from repro.api import strategy_names
    sweep = {}
    for name in strategy_names():
        row = _run(True, n_clients, sample_frac, rounds, eval_examples,
                   strategy=name)
        used = {k: v for k, v in row["compile_counts"].items() if v}
        assert all(v == 1 for v in used.values()), \
            f"{name} engine entry recompiled: {row['compile_counts']}"
        sweep[name] = {"steady_ms": row["steady_ms"],
                       "steady_p50_ms": row["steady_p50_ms"],
                       "first_round_ms": row["first_round_ms"]}
    return sweep


def main(n_clients: int = 1000, sample_frac: float = 0.10, rounds: int = 50,
         out: str = "BENCH_round.json", heavy_eval: bool = True,
         mesh_shards: int = 8, strategy: str = "bfln", mode: str = "sync",
         trace: bool = False, sweep_only: bool = False,
         checkpoint_interval: int = 10, checkpoint_only: bool = False) -> dict:
    cases = {}
    per_strategy = None
    ckpt_case = None
    sweep_rounds = max(WARMUP + 2, rounds // 5)
    if mode in ("sync", "both") and not sweep_only:
        if not checkpoint_only:
            cases["headline_eval256"] = _case(n_clients, sample_frac, rounds,
                                              256, mesh_shards, strategy)
            if heavy_eval:
                cases["heavy_eval1024"] = _case(n_clients, sample_frac,
                                                rounds, 1024, mesh_shards,
                                                strategy)
            per_strategy = _strategy_sweep(n_clients, sample_frac,
                                           sweep_rounds, 256)
        if checkpoint_interval > 0:
            ckpt_case = _checkpoint_case(n_clients, sample_frac, rounds, 256,
                                         checkpoint_interval)

    sharded_sweep = None
    if mode in ("sync", "both") and mesh_shards > 1 and not checkpoint_only:
        widths = [s for s in (1, 2, 4, 8) if s <= mesh_shards]
        sharded_sweep = _sharded_sweep(n_clients, sample_frac, sweep_rounds,
                                       256, widths, strategy)

    async_case = None
    if mode in ("async", "both") and not sweep_only and not checkpoint_only:
        flushes = max(WARMUP + 2, rounds // 2)
        async_case = _async_case(n_clients, sample_frac, flushes, 256,
                                 strategy)

    result = {
        "bench": "round",
        "n_clients": n_clients,
        "cohort": max(1, int(round(sample_frac * n_clients))),
        "rounds": rounds,
        "mesh_shards": mesh_shards,
        "strategy": strategy,
        **({"per_strategy_steady_ms": per_strategy} if per_strategy else {}),
        **cases,
        **({"checkpoint": ckpt_case} if ckpt_case else {}),
        **({"sharded_sweep": sharded_sweep} if sharded_sweep else {}),
        **({"async": async_case} if async_case else {}),
    }
    if (mode == "async" or sweep_only or checkpoint_only) \
            and os.path.exists(out):
        # async-only / sweep-only / checkpoint-only runs merge into the
        # existing results instead of clobbering them
        with open(out) as f:
            prev = json.load(f)
        if async_case is not None:
            prev["async"] = async_case
        if sweep_only and sharded_sweep is not None:
            prev["sharded_sweep"] = sharded_sweep
        if checkpoint_only and ckpt_case is not None:
            prev["checkpoint"] = ckpt_case
        result = prev
    with open(out, "w") as f:
        json.dump(result, f, indent=2)

    if trace:
        from repro.obs import console_summary
        sim = _build(True, n_clients, sample_frac,
                     max(WARMUP + 2, rounds // 5), 256,
                     strategy=strategy, trace=True)
        for r in range(max(WARMUP + 2, rounds // 5)):
            sim.history.append(sim._run_sync_round(r))
        sim._finalize_history()
        print(console_summary(sim.obs.metrics,
                              title=f"traced engine rounds ({strategy})"))

    if async_case is not None:
        for side in ("legacy", "engine"):
            row = async_case[side]
            st = row.get("staleness") or {}
            print(f"round,async_{side},{row['steady_flush_ms'] * 1e3:.0f},"
                  f"steady flush ms (buffer={async_case['buffer_size']}) "
                  f"first_ms={row['first_flush_ms']} "
                  f"staleness_p50={st.get('p50', 0):.1f} "
                  f"staleness_p99={st.get('p99', 0):.1f}")
        print(f"round,async_speedup,{async_case['steady_flush_speedup']:.2f},"
              f"replay_identical=True async_step_compiles=1")

    for cname, case in cases.items():
        for side in ("legacy", "engine", "sharded"):
            row = case.get(side)
            if row is None:
                continue
            print(f"round,{cname}_{side},{row['steady_ms'] * 1e3:.0f},"
                  f"n={n_clients} cohort={result['cohort']} rounds={rounds} "
                  f"first_ms={row['first_round_ms']} "
                  f"compiles={sum(row['compile_counts'].values())} "
                  f"realloc_mb_per_round="
                  f"{row['population_realloc_bytes_per_round'] / 1e6:.1f}"
                  + (f" arena_mb_per_device="
                     f"{row['per_device_arena_bytes'] / 1e6:.1f}"
                     if "per_device_arena_bytes" in row else ""))
        print(f"round,{cname}_speedup,{case['steady_speedup']:.2f},"
              f"replay_identical=True "
              f"arrival_counts={case['distinct_arrival_counts']} "
              f"engine_compiles_per_entry=1")
        if "sharded" in case:
            print(f"round,{cname}_sharded,"
                  f"{case['arena_bytes_per_device_reduction']:.2f},"
                  f"arena_bytes_per_device_reduction over {mesh_shards} "
                  f"shards, round_overhead="
                  f"{case['sharded_round_overhead']:.2f}x, replay_identical")
    for name, row in (per_strategy or {}).items():
        print(f"round,strategy_{name},{row['steady_ms'] * 1e3:.0f},"
              f"engine steady round (1 compile per entry) "
              f"first_ms={row['first_round_ms']}")
    if ckpt_case is not None:
        print(f"round,checkpoint,{ckpt_case['overhead_pct']:.2f},"
              f"steady overhead pct at interval={ckpt_case['interval']} "
              f"({ckpt_case['steady_ms_off']:.1f} -> "
              f"{ckpt_case['steady_ms_on']:.1f} ms) "
              f"snapshot_mb={ckpt_case['snapshot_bytes'] / 1e6:.1f} "
              f"save_ms={ckpt_case['save_ms']} "
              f"restore_ms={ckpt_case['restore_ms']} replay_identical")
        if ckpt_case["overhead_pct"] >= 10:
            print(f"round,WARNING,0,checkpoint overhead "
                  f"{ckpt_case['overhead_pct']:.1f}% at interval="
                  f"{ckpt_case['interval']} exceeds the 10% budget")
    if sharded_sweep is not None:
        for s, row in sharded_sweep["per_shards"].items():
            print(f"round,sweep_shards{s},{row['steady_ms'] * 1e3:.0f},"
                  f"steady engine round at {s} shard(s) "
                  f"speedup_vs_1={row['speedup_vs_1']:.2f} "
                  f"arena_mb_per_device="
                  f"{row['per_device_arena_bytes'] / 1e6:.1f}")
    if "headline_eval256" in cases:
        headline = cases["headline_eval256"]["steady_speedup"]
        print(f"round,result,{headline:.2f},-> {out}")
        if headline < 5:
            print(f"round,WARNING,0,headline speedup {headline:.2f}x below "
                  f"the 5x target")
    elif async_case is not None:
        print(f"round,result,{async_case['steady_flush_speedup']:.2f},"
              f"-> {out}")
    elif sharded_sweep is not None:
        widest = max(sharded_sweep["per_shards"], key=int)
        print(f"round,result,"
              f"{sharded_sweep['per_shards'][widest]['speedup_vs_1']:.2f},"
              f"sweep speedup at {widest} shards -> {out}")
    else:
        print(f"round,result,{ckpt_case['overhead_pct']:.2f},"
              f"checkpoint overhead pct -> {out}")
    return result


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: small population, few rounds, no heavy case")
    p.add_argument("--strategy", default="bfln",
                   help="strategy for the headline legacy-vs-engine case "
                        "(the per-strategy sweep always covers all of them)")
    p.add_argument("--mode", choices=("sync", "async", "both"),
                   default="sync",
                   help="async: FedBuff flush lane (engine vs legacy, steady "
                        "flush latency + staleness metrics via repro.obs); "
                        "async-only runs merge into an existing out file")
    p.add_argument("--trace", action="store_true",
                   help="after the bench, run a traced engine case and print "
                        "the per-phase console summary (repro.obs)")
    p.add_argument("--n-clients", type=int, default=None)
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--mesh-shards", type=int, default=8,
                   help="client-mesh width for the sharded case (1 disables; "
                        "the needed CPU devices are forced in a subprocess)")
    p.add_argument("--sharded-only", default=None, metavar="JSON",
                   help="internal worker mode: run ONLY the sharded case for "
                        "the given case params and print its metrics as JSON")
    p.add_argument("--sweep-only", action="store_true",
                   help="run ONLY the shard-count sweep (1/2/4/8 up to "
                        "--mesh-shards) and merge its sharded_sweep section "
                        "into an existing --out file")
    p.add_argument("--checkpoint-interval", type=int, default=10,
                   help="checkpoint-overhead lane: steady engine round with "
                        "a full-state snapshot every N rounds vs without "
                        "(<10%% amortised budget at the default 10; 0 skips "
                        "the lane)")
    p.add_argument("--checkpoint-only", action="store_true",
                   help="run ONLY the checkpoint-overhead lane and merge its "
                        "checkpoint section into an existing --out file")
    p.add_argument("--out", default="BENCH_round.json")
    args = p.parse_args()
    if args.sharded_only is not None:
        kw = json.loads(args.sharded_only)
        row = _run(True, kw["n_clients"], kw["sample_frac"], kw["rounds"],
                   kw["eval_examples"], args.mesh_shards,
                   kw.get("strategy", "bfln"))
        row["balances"] = row["balances"].tolist()    # exact: repr round-trip
        print(json.dumps(row))
        sys.exit(0)
    n = args.n_clients or (200 if args.quick else 1000)
    r = args.rounds or (10 if args.quick else 50)
    main(n_clients=n, rounds=r, out=args.out, heavy_eval=not args.quick,
         mesh_shards=args.mesh_shards, strategy=args.strategy,
         mode=args.mode, trace=args.trace, sweep_only=args.sweep_only,
         checkpoint_interval=args.checkpoint_interval,
         checkpoint_only=args.checkpoint_only)

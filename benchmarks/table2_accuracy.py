"""Paper Table II: accuracy under non-IID label skew.

BFLN (cluster counts 2/5/7) vs FedAvg / FedHKD / FedProto / FedProx on the
synthetic stand-in datasets at bias β ∈ {0.1, 0.3, 0.5} (20 clients, the
paper's protocol at reduced round count — CPU container).  The validated
claims are the paper's *relative* ones; see EXPERIMENTS.md §Accuracy.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import run_fl

STRATEGIES = ["bfln-2", "bfln-5", "bfln-7", "fedavg", "fedprox", "fedproto",
              "fedhkd"]


def run(datasets, biases, rounds, out_path):
    results = {}
    for ds in datasets:
        for bias in biases:
            for strat in STRATEGIES:
                t0 = time.time()
                if strat.startswith("bfln"):
                    _, acc = run_fl(ds, bias, "bfln", rounds=rounds,
                                    n_clusters=int(strat.split("-")[1]))
                else:
                    _, acc = run_fl(ds, bias, strat, rounds=rounds)
                key = f"{ds}-{bias}-{strat}"
                results[key] = acc
                print(f"table2,{key},{acc:.4f},{time.time()-t0:.0f}s", flush=True)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    return results


def main(full: bool = False, rounds: int = 12,
         out_path: str = "experiments/table2.json"):
    # synth100 is the informative regime (100 classes — the global model
    # can't cover every client's skew, like CIFAR100 in the paper);
    # synth10/synthdigits saturate quickly, mirroring the paper's
    # "SVHN improvements are less pronounced" observation.
    datasets = (["synth10", "synth100", "synthdigits"] if full
                else ["synth10", "synth100"])
    biases = [0.1, 0.3, 0.5]
    return run(datasets, biases, rounds, out_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=12)
    args = ap.parse_args()
    main(args.full, args.rounds)

"""Layer 1 of the invariant auditor: AST source rules over ``src/repro``.

Stdlib-only (``ast`` + ``importlib``); deliberately importable and runnable
on a box without jax.  Each rule is a named check over the parsed tree —
the catalog with rationale and worked examples is ``docs/ANALYSIS.md``.

Rule ids (stable; used in baseline entries and CI output):

========================  ====================================================
``det-wallclock``         no wall-clock reads in replay-relevant modules
``det-global-rng``        no global/module-level RNG outside seeded Generators
``hot-host-sync``         no host syncs reachable from the engine's jit entries
``jit-donation``          every ``jax.jit`` in core/engine.py states a donation
                          decision (``donate_argnums`` present, or baselined)
``tree-order``            dict iteration feeding a reduction must be
                          order-fixed in core/baselines.py / utils/tree.py
``trace-schema``          recorder names ⊆ obs/names.py registry ⊆ doc, and
                          doc names resolve back against the registry
========================  ====================================================

Paths inside findings are ``prefix + path-relative-to-src-root`` so the repo
run reports ``src/repro/core/engine.py`` while test fixtures can use bare
relative trees.
"""
from __future__ import annotations

import ast
import importlib.util
import os
import re
from dataclasses import dataclass, field
from typing import Callable

from repro.analysis.callgraph import (
    CallGraph,
    _dotted,
    build_graph,
    jit_roots,
    reachable,
)
from repro.analysis.findings import Finding

ENGINE_MODULE = "core/engine.py"
TREE_ORDER_MODULES = ("core/baselines.py", "utils/tree.py")
NAMES_MODULE = "obs/names.py"

# modules whose execution must be bit-identical under replay (serve/: the
# frontend replays request schedules on an injected clock — no wall time)
REPLAY_DIR_PREFIXES = ("sim/", "core/", "blockchain/", "serve/")
REPLAY_FILES = ("checkpoint/state.py",)
REPLAY_EXEMPT_PREFIXES = ("obs/",)

WALLCLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "date.today", "datetime.date.today",
})

# np.random.<these> build seeded generators — the sanctioned plumbing
SEEDED_RNG_OK = frozenset({
    "default_rng", "Generator", "PCG64", "Philox", "SeedSequence",
    "BitGenerator", "MT19937",
})
STDLIB_RNG_OK = frozenset({"Random", "SystemRandom"})

HOST_TRANSFER_CALLS = frozenset({
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "jax.device_get", "device_get",
})
DEBUG_CALLS = frozenset({
    "jax.debug.print", "jax.debug.callback", "debug.print", "debug.callback",
})
# attribute access that makes a float()/int() cast static (shape arithmetic)
_STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "itemsize", "nbytes"})
_STATIC_CALLS = frozenset({"len", "prod", "np.prod", "numpy.prod",
                           "math.prod", "tree_size"})

_TRACE_DOC_FAMILIES = frozenset({
    "round", "flush", "chain", "ckpt", "run", "fault", "async", "ledger",
    "engine", "arena", "rounds", "serve",
})
_TRACE_DOC_BARE = frozenset({"compile", "compiles"})
_RECORDER_RECEIVERS = frozenset({"obs", "rec", "recorder", "_obs", "_rec"})


@dataclass
class RuleContext:
    src_root: str
    prefix: str
    files: dict[str, ast.Module]
    sources: dict[str, str]
    graph: CallGraph
    hot: set[tuple[str, str]] = field(default_factory=set)
    trace_doc_path: str | None = None      # filesystem path to TRACE_SCHEMA.md
    trace_doc_report_path: str = "docs/TRACE_SCHEMA.md"

    def p(self, rel: str) -> str:
        return self.prefix + rel


@dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    run: Callable[[RuleContext], list[Finding]]


def _walk_shallow(node: ast.AST):
    """Yield descendants of ``node`` without entering nested function/class
    bodies (those are separate FunctionNodes and are audited on their own)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(child))


def _is_replay_module(rel: str) -> bool:
    if rel.startswith(REPLAY_EXEMPT_PREFIXES):
        return False
    return rel.startswith(REPLAY_DIR_PREFIXES) or rel in REPLAY_FILES


# --------------------------------------------------------------------------- #
# det-wallclock
# --------------------------------------------------------------------------- #
def _rule_det_wallclock(ctx: RuleContext) -> list[Finding]:
    out = []
    for rel, tree in ctx.files.items():
        if not _is_replay_module(rel):
            continue
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted in WALLCLOCK_CALLS:
                out.append(Finding(
                    "det-wallclock", ctx.p(rel), node.lineno,
                    f"wall-clock read `{dotted}()` in replay-relevant module"))
    return out


# --------------------------------------------------------------------------- #
# det-global-rng
# --------------------------------------------------------------------------- #
def _rule_det_global_rng(ctx: RuleContext) -> list[Finding]:
    out = []
    for rel, tree in ctx.files.items():
        # does this module `import random` (the stdlib module)?
        has_stdlib_random = any(
            isinstance(n, ast.Import)
            and any(a.name == "random" for a in n.names)
            for n in ast.walk(tree))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if not dotted:
                continue
            for np_prefix in ("np.random.", "numpy.random.",
                              "jnp.random."):
                if dotted.startswith(np_prefix):
                    fn = dotted[len(np_prefix):]
                    if fn not in SEEDED_RNG_OK:
                        out.append(Finding(
                            "det-global-rng", ctx.p(rel), node.lineno,
                            f"global RNG call `{dotted}` (use a seeded "
                            f"np.random.Generator)"))
            if has_stdlib_random and dotted.startswith("random.") \
                    and dotted.count(".") == 1:
                fn = dotted.split(".", 1)[1]
                if fn not in STDLIB_RNG_OK:
                    out.append(Finding(
                        "det-global-rng", ctx.p(rel), node.lineno,
                        f"global RNG call `{dotted}` (use a seeded "
                        f"random.Random instance)"))
    return out


# --------------------------------------------------------------------------- #
# hot-host-sync
# --------------------------------------------------------------------------- #
def _param_names(node) -> set[str]:
    a = node.args
    names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    for extra in (a.vararg, a.kwarg):
        if extra is not None:
            names.add(extra.arg)
    return names - {"self", "cls"}


def _cast_is_dynamic(call: ast.Call, params: set[str]) -> bool:
    """A ``float(x)``/``int(x)`` cast is a host sync only when ``x`` can be a
    traced array: it mentions a function parameter and no static attribute
    (``.shape``/``.dtype``/…) or size helper (``len``/``prod``)."""
    if not call.args:
        return False
    arg = call.args[0]
    mentions_param = any(
        isinstance(n, ast.Name) and n.id in params for n in ast.walk(arg))
    if not mentions_param:
        return False
    for n in ast.walk(arg):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            return False
        if isinstance(n, ast.Call) and _dotted(n.func) in _STATIC_CALLS:
            return False
    return True


def _rule_hot_host_sync(ctx: RuleContext) -> list[Finding]:
    out = []
    for rel, fns in ctx.graph.by_module.items():
        for fn in fns:
            if (fn.module, fn.qualname) not in ctx.hot:
                continue
            params = _param_names(fn.node)
            for node in _walk_shallow(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                dotted = _dotted(node.func)
                msg = None
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    msg = f"`.item()` host sync in jit-reachable " \
                          f"`{fn.qualname}`"
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "block_until_ready":
                    msg = f"`.block_until_ready()` in jit-reachable " \
                          f"`{fn.qualname}`"
                elif dotted in HOST_TRANSFER_CALLS:
                    msg = f"`{dotted}` host transfer in jit-reachable " \
                          f"`{fn.qualname}`"
                elif dotted in DEBUG_CALLS:
                    msg = f"`{dotted}` in jit-reachable `{fn.qualname}`"
                elif dotted in ("float", "int", "bool") \
                        and _cast_is_dynamic(node, params):
                    msg = f"`{dotted}()` cast of a possibly-traced value " \
                          f"in jit-reachable `{fn.qualname}`"
                if msg:
                    out.append(Finding("hot-host-sync", ctx.p(rel),
                                       node.lineno, msg))
    return out


# --------------------------------------------------------------------------- #
# jit-donation
# --------------------------------------------------------------------------- #
def _rule_jit_donation(ctx: RuleContext) -> list[Finding]:
    tree = ctx.files.get(ENGINE_MODULE)
    if tree is None:
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func) not in ("jax.jit", "jit"):
            continue
        kwargs = {k.arg for k in node.keywords}
        if kwargs & {"donate_argnums", "donate_argnames"}:
            continue
        target = "<expr>"
        if node.args:
            if isinstance(node.args[0], ast.Name):
                target = node.args[0].id
            elif isinstance(node.args[0], ast.Lambda):
                target = "<lambda>"
        out.append(Finding(
            "jit-donation", ctx.p(ENGINE_MODULE), node.lineno,
            f"jax.jit(`{target}`) without donate_argnums — entry keeps "
            f"input buffers alive"))
    return out


# --------------------------------------------------------------------------- #
# tree-order
# --------------------------------------------------------------------------- #
def _unordered_dict_iter(iter_node: ast.AST) -> str | None:
    """Return ``values``/``items`` if the iterable is an unsorted dict view."""
    if isinstance(iter_node, ast.Call) \
            and isinstance(iter_node.func, ast.Name) \
            and iter_node.func.id in ("sorted", "list", "tuple") \
            and iter_node.func.id == "sorted":
        return None
    for n in ast.walk(iter_node):
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and n.func.attr in ("values", "items") and not n.args:
            return n.func.attr
    return None


def _rule_tree_order(ctx: RuleContext) -> list[Finding]:
    out = []
    for rel in TREE_ORDER_MODULES:
        tree = ctx.files.get(rel)
        if tree is None:
            continue
        for node in ast.walk(tree):
            iters = []
            if isinstance(node, ast.For):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters += [g.iter for g in node.generators]
            elif isinstance(node, ast.Call) \
                    and _dotted(node.func) in ("sum", "min", "max", "reduce",
                                               "functools.reduce"):
                iters += node.args
            for it in iters:
                attr = _unordered_dict_iter(it)
                if attr:
                    out.append(Finding(
                        "tree-order", ctx.p(rel), node.lineno,
                        f"unordered dict iteration `.{attr}()` feeding a "
                        f"reduction — wrap in sorted() or iterate "
                        f"jax.tree leaves"))
    return out


# --------------------------------------------------------------------------- #
# trace-schema
# --------------------------------------------------------------------------- #
def _load_names_registry(path: str):
    spec = importlib.util.spec_from_file_location("_repro_obs_names", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _literal_name(arg: ast.AST) -> tuple[str, bool] | None:
    """(name, is_prefix) for a string literal or f-string literal prefix."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value, False
    if isinstance(arg, ast.JoinedStr) and arg.values:
        head = arg.values[0]
        if isinstance(head, ast.Constant) and isinstance(head.value, str):
            return head.value, True
    return None


def _receiver_is_recorder(func: ast.Attribute) -> bool:
    chain = _dotted(func.value)
    if chain is None:
        return False
    return chain.split(".")[-1] in _RECORDER_RECEIVERS


def _doc_tokens(doc_text: str) -> set[str]:
    """Backticked dotted names in the schema doc, normalized: ``<...>`` and
    ``*`` placeholders become prefixes (``engine.calls.<entry>`` ->
    ``engine.calls.``)."""
    toks = set()
    for raw in re.findall(r"`([A-Za-z0-9_.<>*]+)`", doc_text):
        tok = re.split(r"[<*]", raw)[0]
        if not tok:
            continue
        fam = tok.split(".")[0]
        # bare family words (`round`, `chain`) are prose references to a
        # category, not metric names — only dotted tokens (or the known
        # dotless metrics) participate in the cross-check
        if (fam in _TRACE_DOC_FAMILIES and "." in tok) \
                or tok in _TRACE_DOC_BARE:
            toks.add(tok)
    return toks


def _rule_trace_schema(ctx: RuleContext) -> list[Finding]:
    names_path = os.path.join(ctx.src_root, NAMES_MODULE)
    if not os.path.exists(names_path):
        return []
    reg = _load_names_registry(names_path)
    out = []

    # 1. every recorder call site uses a registered name
    for rel, tree in ctx.files.items():
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in reg.METHOD_NAME_SETS
                    and _receiver_is_recorder(node.func)
                    and node.args):
                continue
            lit = _literal_name(node.args[0])
            if lit is None:
                continue
            name, is_prefix = lit
            allowed = reg.METHOD_NAME_SETS[node.func.attr]
            if is_prefix:
                ok = any(n.startswith(name) for n in allowed) \
                    or reg.is_registered(name)
            else:
                ok = reg.is_registered(name, allowed)
            if not ok:
                out.append(Finding(
                    "trace-schema", ctx.p(rel), node.lineno,
                    f"unregistered {node.func.attr}() name `{name}` — add "
                    f"to obs/names.py and docs/TRACE_SCHEMA.md"))

    # 2 & 3. registry <-> schema doc cross-check
    if ctx.trace_doc_path and os.path.exists(ctx.trace_doc_path):
        with open(ctx.trace_doc_path) as f:
            toks = _doc_tokens(f.read())
        prefixes = {t for t in toks if t.endswith(".")}
        doc_rel = ctx.trace_doc_report_path
        for name in sorted(reg.ALL_NAMES):
            if name in toks or any(name.startswith(p) for p in prefixes):
                continue
            out.append(Finding(
                "trace-schema", doc_rel, 0,
                f"registered name `{name}` is not documented in "
                f"TRACE_SCHEMA.md"))
        for tok in sorted(toks):
            if tok.endswith("."):
                ok = tok in reg.DYNAMIC_PREFIXES \
                    or any(n.startswith(tok) for n in reg.ALL_NAMES)
            else:
                ok = reg.is_registered(tok)
            if not ok:
                out.append(Finding(
                    "trace-schema", doc_rel, 0,
                    f"TRACE_SCHEMA.md names `{tok}` which is not in the "
                    f"obs/names.py registry"))
    return out


RULES: list[Rule] = [
    Rule("det-wallclock",
         "no wall-clock reads in replay-relevant modules",
         _rule_det_wallclock),
    Rule("det-global-rng",
         "no global/module-level RNG outside seeded-Generator plumbing",
         _rule_det_global_rng),
    Rule("hot-host-sync",
         "no host syncs in functions reachable from the engine's jit entries",
         _rule_hot_host_sync),
    Rule("jit-donation",
         "every jax.jit in core/engine.py states a donation decision",
         _rule_jit_donation),
    Rule("tree-order",
         "dict iteration feeding a reduction must be order-fixed",
         _rule_tree_order),
    Rule("trace-schema",
         "recorder names, obs/names.py registry, and TRACE_SCHEMA.md agree",
         _rule_trace_schema),
]


def collect_sources(src_root: str) -> tuple[dict[str, ast.Module],
                                            dict[str, str]]:
    files: dict[str, ast.Module] = {}
    sources: dict[str, str] = {}
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            full = os.path.join(dirpath, fname)
            rel = os.path.relpath(full, src_root).replace(os.sep, "/")
            with open(full) as f:
                src = f.read()
            files[rel] = ast.parse(src, filename=rel)
            sources[rel] = src
    return files, sources


def build_context(src_root: str, *, prefix: str = "",
                  trace_doc: str | None = None,
                  trace_doc_report_path: str = "docs/TRACE_SCHEMA.md"
                  ) -> RuleContext:
    files, sources = collect_sources(src_root)
    graph = build_graph(files)
    roots = jit_roots(graph, ENGINE_MODULE, files[ENGINE_MODULE]) \
        if ENGINE_MODULE in files else []
    hot = reachable(graph, roots)
    return RuleContext(src_root=src_root, prefix=prefix, files=files,
                       sources=sources, graph=graph, hot=hot,
                       trace_doc_path=trace_doc,
                       trace_doc_report_path=trace_doc_report_path)


def run_source_rules(src_root: str, *, prefix: str = "",
                     trace_doc: str | None = None,
                     rule_ids: list[str] | None = None) -> list[Finding]:
    """Run all (or the selected) Layer-1 rules over ``src_root``."""
    ctx = build_context(src_root, prefix=prefix, trace_doc=trace_doc)
    out: list[Finding] = []
    for rule in RULES:
        if rule_ids is not None and rule.id not in rule_ids:
            continue
        out.extend(rule.run(ctx))
    return sorted(set(out))

"""``python -m repro.analysis`` — run the invariant auditor.

Layer 1 (AST source rules) runs in-process and needs nothing beyond the
stdlib.  Layer 2 (compiled-artifact audit) runs in SUBPROCESSES, one per
requested mesh width, because ``--xla_force_host_platform_device_count``
must be set before jax imports — this is how a 1-device box audits the
forced 8-device mesh (same pattern as the sharded-engine tests).

Exit status: 0 when every finding is baselined, 1 otherwise (CI gate).

Examples::

    PYTHONPATH=src python -m repro.analysis                  # full audit
    PYTHONPATH=src python -m repro.analysis --no-hlo         # Layer 1 only
    PYTHONPATH=src python -m repro.analysis --baseline write # grandfather
    PYTHONPATH=src python -m repro.analysis --json report.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.analysis.baseline import (
    check_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.findings import (
    Finding,
    build_report,
    render_table,
    write_report,
)
from repro.analysis.rules import RULES, run_source_rules

HLO_RULE_IDS = ("hlo-donation", "hlo-combine-collective", "hlo-f64",
                "hlo-cache-stability", "hlo-selftest")


def _find_root(start: str) -> str:
    """Walk up from ``start`` to the directory containing ``src/repro``."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            raise SystemExit(f"no src/repro found above {start}")
        cur = parent


def _run_hlo_subprocess(root: str, shards: int
                        ) -> tuple[list[Finding], dict]:
    """One mesh width = one subprocess (jax device count is import-time)."""
    env = dict(os.environ)
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if shards > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{shards}").strip()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.hlo_audit",
         "--shards", str(shards), "--json", "-"],
        capture_output=True, text=True, cwd=root, env=env)
    try:
        doc = json.loads(proc.stdout)
    except json.JSONDecodeError:
        raise SystemExit(
            f"hlo audit subprocess (shards={shards}) failed:\n"
            f"{proc.stdout}\n{proc.stderr}")
    findings = [Finding(d["rule"], d["path"], d["line"], d["message"],
                        d.get("detail", {}))
                for d in doc["findings"]]
    return findings, doc["info"]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="invariant auditor: AST source rules + compiled-HLO "
                    "audit (catalog: docs/ANALYSIS.md)")
    ap.add_argument("--root", default=".",
                    help="repo root (or any dir beneath it)")
    ap.add_argument("--baseline", choices=("check", "write"),
                    default="check",
                    help="check findings against .analysis-baseline.json "
                         "(default) or grandfather the current ones")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the digest-stamped JSON report here")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip the compiled-artifact audit (Layer 2)")
    ap.add_argument("--mesh-shards", default="1,8",
                    help="comma-separated mesh widths for the HLO audit")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (Layer 1 only)")
    args = ap.parse_args(argv)

    root = _find_root(args.root)
    src_root = os.path.join(root, "src", "repro")
    trace_doc = os.path.join(root, "docs", "TRACE_SCHEMA.md")
    rule_ids = args.rules.split(",") if args.rules else None

    findings = run_source_rules(src_root, prefix="src/repro/",
                                trace_doc=trace_doc, rule_ids=rule_ids)

    hlo_info: dict | None = None
    if not args.no_hlo and rule_ids is None:
        hlo_info = {}
        for shards in (int(s) for s in args.mesh_shards.split(",") if s):
            hlo_findings, info = _run_hlo_subprocess(root, shards)
            findings += hlo_findings
            hlo_info[f"mesh_shards={shards}"] = info

    if args.baseline == "write":
        path = write_baseline(root, findings)
        print(f"baseline written: {path} ({len(findings)} finding(s) — "
              f"fill in every 'reason')")
        return 0

    entries = load_baseline(root)
    fresh, grandfathered, stale = check_baseline(findings, entries)

    all_rules = [r.id for r in RULES] + list(HLO_RULE_IDS)
    report = build_report(fresh, grandfathered, stale, rules=all_rules,
                          hlo_info=hlo_info)
    if args.json:
        write_report(report, args.json)

    print(render_table(fresh, grandfathered, stale))
    print(f"report digest: {report['report_digest']}")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())

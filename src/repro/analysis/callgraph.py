"""Lightweight static call graph over ``src/repro`` (stdlib ``ast`` only).

Purpose-built for ONE question: *which functions can execute inside the
round engine's jitted entries?*  Those functions must never touch the host
(``.item()``, ``np.asarray``, ``jax.debug.print``, …) — a host sync inside
the donated step either breaks tracing or serialises the round.

The graph deliberately **over-approximates** reachability (a false edge
costs a baseline entry with a written reason; a missed edge hides a real
host sync):

* bare calls resolve within the defining module first, then through
  ``from x import y`` (module- or function-local), then via
  :data:`ALIASES`, then globally by name;
* attribute calls and loads (``strategy.cohort_combine(...)``,
  ``opt.update``, a function passed to ``vmap``/``tree.map`` by reference)
  resolve to EVERY analyzed function with that bare name — this is how the
  dynamic Strategy/Optimizer dispatch stays visible to a static pass;
* a handful of callback parameter names (:data:`ALIASES`) map onto their
  real implementations (``apply_fn`` → ``classifier.apply``, …).

Roots are discovered, not hardcoded: any ``jax.jit(fn, ...)`` call in the
root module (``core/engine.py``) marks ``fn`` as a jitted entry.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

# callback parameter name -> bare names of the functions actually bound
# there at runtime (see RoundEngine.__init__ / SimulatedFederation)
ALIASES: dict[str, tuple[str, ...]] = {
    "apply_fn": ("apply",),
    "embed_fn": ("embed",),
    "stacked_apply_fn": ("apply_stacked",),
    "predict_fn": ("apply", "apply_stacked"),
    "loss_fn": ("local_loss",),
    "grad_fn": ("local_loss",),
    "partial_fn": ("cohort_partial",),
    "combine_fn": ("cohort_combine",),
}

# attribute names never worth resolving (container/ndarray noise)
_ATTR_STOPLIST = frozenset({
    "append", "extend", "insert", "remove", "clear", "keys", "values",
    "items", "get", "pop", "setdefault", "copy", "join", "split", "strip",
    "format", "startswith", "endswith", "encode", "decode", "astype",
    "reshape", "ravel", "transpose", "sum", "mean", "max", "min", "shape",
    "dtype", "ndim", "size", "at", "set", "add", "push",
})


@dataclass
class FunctionNode:
    """One function/method definition (possibly nested)."""

    module: str                       # repo-relative path
    qualname: str
    name: str
    lineno: int
    node: ast.AST = field(repr=False)
    # outgoing references: ("name" | "attr" | "alias", identifier)
    refs: list[tuple[str, str]] = field(default_factory=list)


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute/name chain -> "a.b.c" (None if not a chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FunctionCollector(ast.NodeVisitor):
    """Collect every function def in a module with its outgoing refs."""

    def __init__(self, module: str):
        self.module = module
        self.stack: list[str] = []
        self.functions: list[FunctionNode] = []
        self.imports_from: dict[str, str] = {}   # local name -> source module

    def visit_Import(self, node: ast.Import) -> None:
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module:
            for alias in node.names:
                self.imports_from[alias.asname or alias.name] = node.module
        self.generic_visit(node)

    def _visit_function(self, node) -> None:
        qual = ".".join(self.stack + [node.name]) if self.stack else node.name
        fn = FunctionNode(self.module, qual, node.name, node.lineno, node)
        for child in ast.walk(node):
            if child is node:
                continue
            if isinstance(child, ast.Name) and isinstance(child.ctx, ast.Load):
                fn.refs.append(("name", child.id))
                if child.id in ALIASES:
                    fn.refs += [("alias", a) for a in ALIASES[child.id]]
            elif isinstance(child, ast.Attribute) \
                    and isinstance(child.ctx, ast.Load) \
                    and child.attr not in _ATTR_STOPLIST:
                fn.refs.append(("attr", child.attr))
                if child.attr in ALIASES:
                    fn.refs += [("alias", a) for a in ALIASES[child.attr]]
        self.functions.append(fn)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()


@dataclass
class CallGraph:
    by_name: dict[str, list[FunctionNode]]
    by_module: dict[str, list[FunctionNode]]
    imports: dict[str, dict[str, str]]          # module -> local -> source

    def resolve(self, fn: FunctionNode) -> list[FunctionNode]:
        """All functions ``fn`` may reference (over-approximate)."""
        out: list[FunctionNode] = []
        same_module = {f.name: [] for f in self.by_module.get(fn.module, [])}
        for f in self.by_module.get(fn.module, []):
            same_module[f.name].append(f)
        mod_imports = self.imports.get(fn.module, {})
        for kind, name in fn.refs:
            if kind == "name":
                if name in same_module:
                    out += same_module[name]
                elif name in mod_imports or name in self.by_name:
                    # from-import or global fallback: match by bare name
                    out += self.by_name.get(name, [])
            else:   # attr / alias: global dynamic-dispatch match
                out += self.by_name.get(name, [])
        return out


def build_graph(py_files: dict[str, ast.Module]) -> CallGraph:
    """``py_files``: repo-relative path -> parsed module."""
    by_name: dict[str, list[FunctionNode]] = {}
    by_module: dict[str, list[FunctionNode]] = {}
    imports: dict[str, dict[str, str]] = {}
    for path, tree in py_files.items():
        col = _FunctionCollector(path)
        col.visit(tree)
        by_module[path] = col.functions
        imports[path] = col.imports_from
        for fn in col.functions:
            by_name.setdefault(fn.name, []).append(fn)
    return CallGraph(by_name, by_module, imports)


def jit_roots(graph: CallGraph, root_module: str, tree: ast.Module
              ) -> list[FunctionNode]:
    """Functions passed to ``jax.jit(...)`` anywhere in ``root_module``
    (module level or inside a method) — the engine's jitted entry points."""
    roots: list[FunctionNode] = []
    mod_fns = {f.name: f for f in graph.by_module.get(root_module, [])}
    for child in ast.walk(tree):
        if not isinstance(child, ast.Call):
            continue
        if _dotted(child.func) not in ("jax.jit", "jit"):
            continue
        for arg in child.args[:1]:
            target = None
            if isinstance(arg, ast.Name):
                target = arg.id
            elif isinstance(arg, ast.Call):           # functools.partial(f,…)
                inner = arg.args[0] if arg.args else None
                if isinstance(inner, ast.Name):
                    target = inner.id
            if target and target in mod_fns:
                roots.append(mod_fns[target])
    # @jax.jit decorated functions are entries too
    for fn in graph.by_module.get(root_module, []):
        decos = getattr(fn.node, "decorator_list", [])
        if any(_dotted(d) in ("jax.jit", "jit") for d in decos):
            roots.append(fn)
    return roots


def reachable(graph: CallGraph, roots: list[FunctionNode]
              ) -> set[tuple[str, str]]:
    """Transitive closure from the roots; returns {(module, qualname)}."""
    seen: set[tuple[str, str]] = set()
    frontier = list(roots)
    while frontier:
        fn = frontier.pop()
        key = (fn.module, fn.qualname)
        if key in seen:
            continue
        seen.add(key)
        frontier += graph.resolve(fn)
    return seen

"""Layer 2 of the invariant auditor: checks on the COMPILED artifacts.

Source rules can only see what the code says; this module lowers the round
engine's *actual* jitted entries (the exact programs ``repro.sim`` runs)
on a small real probe federation and audits the post-SPMD HLO text:

``hlo-donation``
    the donated sync entry's compiled module must alias its arena
    parameter to an output (``input_output_alias`` header) — a silent
    donation failure doubles peak arena memory;
``hlo-combine-collective``
    zero REDUCTION collectives (all-reduce / reduce-scatter) whose
    ``op_name`` metadata lies inside the ``cohort_combine`` named scope —
    an all-reduce there is exactly the PR 7 class of bug (GSPMD rewriting
    the replicated fixed-order combine into partial sums, 1-ULP replay
    drift).  All-gathers materialising the scope's replication pins are
    bit-preserving data movement and allowed (reported as info);
``hlo-f64``
    no op producing ``f64`` with jax x64 disabled (a hit means a python
    float silently widened through numpy);
``hlo-cache-stability``
    executing every entry twice with varying arrival masks / labels / ids
    (same shapes) leaves each jit cache at exactly one executable — the
    1-compile-per-entry contract, reused from ``RoundEngine.cache_sizes``;
``hlo-selftest``
    the detector must NOT be vacuous: a deliberately partition-unsafe toy
    (a cohort-sharded reduction inside a ``cohort_combine`` scope) must
    produce at least one attributed collective at mesh width > 1.

Run directly (the CLI uses this as a subprocess so a 1-device box can
audit a forced 8-device mesh)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.analysis.hlo_audit --shards 8

jax is imported lazily inside :func:`run_audit` — ``repro.analysis``
Layer 1 stays importable without it.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.findings import Finding

ENGINE_PATH = "src/repro/core/engine.py"
SERVE_ENGINE_PATH = "src/repro/serve/engine.py"
HLO_RULES = ("hlo-donation", "hlo-combine-collective", "hlo-f64",
             "hlo-cache-stability", "hlo-selftest")

# entries whose jax.jit declares donate_argnums -> the donated param indices
# (the serve engine's forward states donate_argnums=() — nothing expected)
DONATING_ENTRIES = {"sync_step": (0,)}


def _build_probe(mesh_shards: int, n_clients: int = 32, cohort_k: int = 8):
    """A small but REAL federation: the audit lowers the same entry
    programs the driver runs, not hand-built lookalikes."""
    import warnings

    import jax.numpy as jnp
    import numpy as np

    from repro.sim import (ClientPopulation, PopulationSpec, SimConfig,
                           SimulatedFederation)

    pop = ClientPopulation.from_spec(PopulationSpec(
        n_clients=n_clients, dataset="synth10", beta=0.3, n_batches=1,
        batch_size=16, seed=7))
    with warnings.catch_warnings():
        # the SimConfig shim is the stable probe surface; the audit doesn't
        # care about the ExperimentSpec migration
        warnings.simplefilter("ignore", DeprecationWarning)
        sim = SimulatedFederation(pop, SimConfig(
            rounds=1, sample_frac=cohort_k / n_clients, n_clusters=2,
            seed=7, engine=True, mesh_shards=mesh_shards))

    k = cohort_k
    cohort = jnp.arange(k)
    cx, cy = pop.cohort_data(np.arange(k))
    arrived = jnp.ones((k,), jnp.float32)
    ex, ey = pop.test_x[:32], pop.test_y[:32]
    # replicated (k, N) rows, exactly like the driver's flush snapshots
    rows = jnp.asarray(np.asarray(sim.arena.data[:k]))
    labels = jnp.zeros((k,), jnp.int32)

    entry_args = {
        "sync_step": (sim.arena.data, cohort, cx, cy, arrived),
        "async_step": (rows, cx, cy),
        "eval_cohort": (rows, arrived, labels, ex, ey),
        "eval_global": (rows[0], ex, ey),
        "eval_population": (sim.arena.data, cohort, ex, ey),
    }
    # same shapes, different values — must NOT retrace
    varied = {
        "sync_step": (sim.arena.data, cohort,
                      cx, cy, arrived.at[0].set(0.0)),
        "async_step": (rows, cx, cy),
        "eval_cohort": (rows, arrived.at[0].set(0.0),
                        labels.at[0].set(1), ex, ey),
        "eval_global": (rows[1], ex, ey),
        "eval_population": (sim.arena.data, cohort[::-1], ex, ey),
    }
    return sim, entry_args, varied


def _audit_entry(name: str, hlo_text: str, mesh_shards: int,
                 findings: list[Finding], path: str = ENGINE_PATH) -> dict:
    from repro.launch.hlo import (collective_counts, collective_lines,
                                  donated_params, f64_op_count)

    donated = sorted(donated_params(hlo_text))
    combine_all = [(comp, kind, op) for comp, kind, op
                   in collective_lines(hlo_text)
                   if "cohort_combine" in op]
    # the drift-bug class is REDUCTION collectives (partial sums whose
    # rounding path diverges from the single-device op sequence); the
    # all-gathers/all-to-alls that materialise the scope's replication
    # pins are bit-preserving data movement and expected
    combine_hits = [h for h in combine_all
                    if h[1] in ("all-reduce", "reduce-scatter")]
    f64 = f64_op_count(hlo_text)

    for idx in DONATING_ENTRIES.get(name, ()):
        if idx not in donated:
            findings.append(Finding(
                "hlo-donation", path, 0,
                f"entry `{name}` declares donate_argnums but the compiled "
                f"module does not alias param {idx} to an output "
                f"(mesh_shards={mesh_shards})",
                detail={"entry": name, "mesh_shards": mesh_shards}))
    if combine_hits:
        findings.append(Finding(
            "hlo-combine-collective", path, 0,
            f"entry `{name}` compiles {len(combine_hits)} reduction "
            f"collective(s) inside the cohort_combine scope at mesh_shards="
            f"{mesh_shards} — the combine must run replicated "
            f"(replicate-before-combine)",
            detail={"entry": name, "mesh_shards": mesh_shards,
                    "collectives": [kind for _, kind, _ in combine_hits]}))
    if f64:
        findings.append(Finding(
            "hlo-f64", path, 0,
            f"entry `{name}` compiles {f64} f64-producing op(s) with jax "
            f"x64 disabled (mesh_shards={mesh_shards})",
            detail={"entry": name, "mesh_shards": mesh_shards}))

    return {
        "donated_params": donated,
        "combine_reductions": len(combine_hits),
        "combine_data_movement": len(combine_all) - len(combine_hits),
        "f64_ops": f64,
        "collective_counts": collective_counts(hlo_text),
    }


def _audit_serve(sim, mesh_shards: int, findings: list[Finding],
                 cache_check: bool) -> dict:
    """The same compiled-artifact checks on the serving tier's mixed-batch
    forward (`repro.serve.engine`), through the REAL provenance gate: the
    probe snapshot publishes a release block on the probe chain and the
    engine refuses to build unless verification passes.  No donation is
    expected (the bank is persistent serving state); f64 leaks and the
    1-compile-per-batch-shape contract are audited like the round engine."""
    import jax
    import jax.numpy as jnp

    from repro.serve import ServingEngine, snapshot

    bank = snapshot(sim)                      # publishes + verifies
    eng = ServingEngine(bank, sim.trainer.chain)
    batch = 8
    x = jnp.linspace(-1.0, 1.0, batch * bank.mcfg.in_dim,
                     dtype=jnp.float32).reshape(batch, bank.mcfg.in_dim)
    cids = jnp.arange(batch, dtype=jnp.int32) % bank.n_models
    text = eng.lower_entry("forward", bank.data, x, cids).compile().as_text()
    info = {"forward": _audit_entry("serve_forward", text, mesh_shards,
                                    findings, path=SERVE_ENGINE_PATH)}
    if cache_check:
        # same batch shape, different values/routing — must NOT retrace
        jax.block_until_ready(eng.forward(x, cids))
        jax.block_until_ready(eng.forward(x + 1.0, cids[::-1]))
        sizes = eng.cache_sizes()
        info["cache_sizes"] = sizes
        for name, size in sizes.items():
            if size != 1:
                findings.append(Finding(
                    "hlo-cache-stability", SERVE_ENGINE_PATH, 0,
                    f"serve entry `{name}` compiled {size} executables "
                    f"across same-shape calls (mesh_shards={mesh_shards}) — "
                    f"the 1-compile-per-batch-shape contract is broken",
                    detail={"entry": name, "mesh_shards": mesh_shards}))
    return info


def _selftest(mesh_shards: int, findings: list[Finding]) -> dict:
    """Compile a deliberately partition-unsafe combine (cohort-sharded
    reduction) and prove the detector sees its collective."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.launch.hlo import collective_lines
    from repro.launch.mesh import CLIENT_AXIS, make_client_mesh

    mesh = make_client_mesh(mesh_shards)
    sharded = NamedSharding(mesh, PartitionSpec(CLIENT_AXIS))

    def unsafe_combine(x):
        x = jax.lax.with_sharding_constraint(x, sharded)
        with jax.named_scope("cohort_combine"):
            return jnp.sum(x, axis=0)

    x = jnp.ones((mesh_shards * 4, 64), jnp.float32)
    text = jax.jit(unsafe_combine).lower(x).compile().as_text()
    hits = collective_lines(text)
    attributed = [h for h in hits if "cohort_combine" in h[2]]
    if not hits:
        findings.append(Finding(
            "hlo-selftest", "src/repro/analysis/hlo_audit.py", 0,
            f"seeded partition-unsafe reduction compiled with NO detectable "
            f"collective at mesh_shards={mesh_shards} — the combine "
            f"detector is blind",
            detail={"mesh_shards": mesh_shards}))
    return {"collectives": len(hits), "attributed": len(attributed)}


def run_audit(mesh_shards: int = 1, *, cache_check: bool = True
              ) -> tuple[list[Finding], dict]:
    """Lower + audit every engine entry at ``mesh_shards``.

    Returns ``(findings, info)``; ``info`` is the per-entry summary that
    lands in the JSON report.  Requires ``len(jax.devices()) >=
    mesh_shards`` — the CLI dispatches a subprocess with forced host
    devices when it isn't.
    """
    import jax

    if len(jax.devices()) < mesh_shards:
        raise RuntimeError(
            f"audit at mesh_shards={mesh_shards} needs that many devices "
            f"(have {len(jax.devices())}); run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={mesh_shards}")

    findings: list[Finding] = []
    sim, entry_args, varied = _build_probe(mesh_shards)
    eng = sim.engine
    info: dict = {"mesh_shards": mesh_shards, "entries": {}}

    for name in eng.entry_names():
        text = eng.lower_entry(name, *entry_args[name]).compile().as_text()
        info["entries"][name] = _audit_entry(name, text, mesh_shards,
                                             findings)

    # serve audit first: the engine cache check below EXECUTES sync_step,
    # whose donation deletes the probe arena the snapshot reads
    info["serve"] = _audit_serve(sim, mesh_shards, findings, cache_check)

    if cache_check:
        # run order matters: sync_step donates the arena, and
        # eval_population reads it — exercise the donating entry last,
        # chaining its returned arena into the second call
        raw = eng._entries
        for name in eng.entry_names():
            if name in DONATING_ENTRIES:
                continue
            jax.block_until_ready(raw[name](*entry_args[name]))
            jax.block_until_ready(raw[name](*varied[name]))
        arena, _ = raw["sync_step"](*entry_args["sync_step"])
        _, idx, cx, cy, arrived = varied["sync_step"]
        arena, _ = raw["sync_step"](arena, idx, cx, cy, arrived)
        jax.block_until_ready(arena)
        sizes = eng.cache_sizes()
        info["cache_sizes"] = sizes
        for name, size in sizes.items():
            if size != 1:
                findings.append(Finding(
                    "hlo-cache-stability", ENGINE_PATH, 0,
                    f"entry `{name}` compiled {size} executables across "
                    f"same-shape calls (mesh_shards={mesh_shards}) — the "
                    f"1-compile-per-entry contract is broken",
                    detail={"entry": name, "mesh_shards": mesh_shards}))

    if mesh_shards > 1:
        info["selftest"] = _selftest(mesh_shards, findings)

    return findings, info


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.hlo_audit",
        description="compiled-artifact audit of the round engine's entries")
    ap.add_argument("--shards", type=int, default=1,
                    help="mesh width to audit (needs that many devices)")
    ap.add_argument("--no-cache-check", action="store_true",
                    help="skip the execute-twice jit-cache stability check")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results ('-' for stdout)")
    args = ap.parse_args(argv)

    findings, info = run_audit(args.shards,
                               cache_check=not args.no_cache_check)
    doc = {
        "findings": [{"rule": f.rule, "path": f.path, "line": f.line,
                      "message": f.message, "detail": f.detail}
                     for f in sorted(findings)],
        "info": info,
    }
    if args.json == "-":
        json.dump(doc, sys.stdout, sort_keys=True)
        sys.stdout.write("\n")
    else:
        if args.json:
            with open(args.json, "w") as f:
                json.dump(doc, f, sort_keys=True, indent=1)
                f.write("\n")
        for f_ in findings:
            print(f_.format())
        print(f"hlo audit @ mesh_shards={args.shards}: "
              f"{len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

"""`repro.analysis` — the invariant auditor: machine-checked forms of the
repo's load-bearing determinism / donation / partition-safety invariants.

Six PRs of engine work rest on properties that used to live only in prose
and example-based tests: replicate-before-combine (the GSPMD partial-sum
1-ULP drift class), donated fixed-shape entries at exactly one compile
each, bit-identical replay with obs/faults/checkpoint off, and no
wall-clock or global-RNG reads on replay paths.  This package turns them
into a two-layer static gate:

**Layer 1 — source rules** (`repro.analysis.rules`): an AST rule engine
(stdlib-only, no jax import) walking ``src/repro`` with per-rule findings
and a committed baseline (``.analysis-baseline.json``) for grandfathered
cases.  Rules: ``det-wallclock``, ``det-global-rng``, ``hot-host-sync``,
``jit-donation``, ``tree-order``, ``trace-schema`` — the catalog with
rationale and examples lives in ``docs/ANALYSIS.md``.

**Layer 2 — compiled-artifact audit** (`repro.analysis.hlo_audit`): lowers
the round engine's REAL jitted entries (sync + async, mesh 1 and forced-8)
and verifies the post-SPMD HLO — input/output buffer aliasing for the
donated arena, zero collectives in the replicated ``cohort_combine``
program (an inserted all-reduce there is exactly the PR 7 drift bug), no
f64 leaks with x64 off, and jit-cache stability under varying arrival
masks.

CLI: ``python -m repro.analysis`` (see ``--help``); exits nonzero on any
unbaselined finding.  CI runs it with ``--baseline check`` and archives
the digest-stamped JSON report.
"""
from repro.analysis.baseline import (  # noqa: F401
    BASELINE_FILENAME,
    check_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.findings import (  # noqa: F401
    Finding,
    build_report,
    render_table,
)
from repro.analysis.rules import RULES, run_source_rules  # noqa: F401

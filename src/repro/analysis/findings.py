"""Finding records + the digest-stamped JSON report / human table.

A :class:`Finding` is one rule violation at one source (or HLO) location.
Messages are written to be *stable across unrelated edits* — they name the
offending construct, never the line number — so baseline entries keyed on
``(rule, path, message)`` survive code motion (the line is still recorded
for humans and editors).

The JSON report follows the manifest convention (`repro.api.runner`): a
flat, sorted-key record stamped with the sha256 of its own canonical
payload (``report_digest``), so two runs over identical trees produce
byte-identical reports and any diff is a real drift.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field

REPORT_SCHEMA = 1


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation: ``rule`` id, repo-relative ``path``, 1-based
    ``line`` (0 for file/tree-level findings), human ``message``."""

    rule: str
    path: str
    line: int
    message: str
    # extra context (e.g. the engine entry or mesh width for HLO findings);
    # excluded from baseline matching
    detail: dict = field(default_factory=dict, compare=False)

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


def build_report(findings: list[Finding], baselined: list[Finding],
                 stale_baseline: list[dict], *, rules: list[str],
                 hlo_info: dict | None = None) -> dict:
    """The machine-readable audit record (sorted keys, digest-stamped)."""
    report = {
        "schema": REPORT_SCHEMA,
        "rules": sorted(rules),
        "findings": [asdict(f) for f in sorted(findings)],
        "baselined": [asdict(f) for f in sorted(baselined)],
        "stale_baseline": stale_baseline,
        "counts": {
            "findings": len(findings),
            "baselined": len(baselined),
            "stale_baseline": len(stale_baseline),
        },
    }
    if hlo_info is not None:
        report["hlo"] = hlo_info
    payload = json.dumps(report, sort_keys=True,
                         separators=(",", ":")).encode()
    report["report_digest"] = hashlib.sha256(payload).hexdigest()
    return report


def write_report(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, sort_keys=True, indent=1)
        f.write("\n")


def render_table(findings: list[Finding], baselined: list[Finding],
                 stale_baseline: list[dict]) -> str:
    """The human half of the CLI output."""
    lines: list[str] = []
    if findings:
        lines.append(f"UNBASELINED FINDINGS ({len(findings)}):")
        lines += [f"  {f.format()}" for f in sorted(findings)]
    else:
        lines.append("no unbaselined findings")
    if baselined:
        lines.append(f"baselined (grandfathered) findings: {len(baselined)}")
        lines += [f"  {f.format()}" for f in sorted(baselined)]
    if stale_baseline:
        lines.append(f"stale baseline entries (no longer firing): "
                     f"{len(stale_baseline)}")
        lines += [f"  [{e['rule']}] {e['path']}: {e['match']}"
                  for e in stale_baseline]
    return "\n".join(lines)

"""The committed baseline: grandfathered findings, each with a written reason.

``.analysis-baseline.json`` at the repo root holds the findings the team
has explicitly accepted (the JSON ``reason`` field is the mandatory
"comment" — an entry without one is rejected).  Matching is by
``(rule, path, message)``, never line number, so baseline entries survive
unrelated edits; any baselined finding that stops firing is reported as
*stale* so the file cannot silently rot.
"""
from __future__ import annotations

import json
import os

from repro.analysis.findings import Finding

BASELINE_FILENAME = ".analysis-baseline.json"
BASELINE_SCHEMA = 1


def baseline_path(root: str) -> str:
    return os.path.join(root, BASELINE_FILENAME)


def load_baseline(root: str) -> list[dict]:
    path = baseline_path(root)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"baseline schema {doc.get('schema')!r} != "
                         f"{BASELINE_SCHEMA} in {path}")
    entries = doc.get("findings", [])
    for e in entries:
        for field in ("rule", "path", "match", "reason"):
            if not isinstance(e.get(field), str) or not e[field].strip():
                raise ValueError(
                    f"baseline entry missing non-empty {field!r} (every "
                    f"grandfathered finding needs a written reason): {e}")
    return entries


def write_baseline(root: str, findings: list[Finding]) -> str:
    """``--baseline write``: grandfather the current findings.  Reasons are
    stamped ``TODO`` so the checker still forces a human to write one."""
    entries = [{"rule": f.rule, "path": f.path, "match": f.message,
                "reason": "TODO: justify or fix"}
               for f in sorted(findings)]
    # keep reasons already written for findings that still fire
    try:
        old = {(e["rule"], e["path"], e["match"]): e["reason"]
               for e in load_baseline(root)}
    except ValueError:
        old = {}
    for e in entries:
        e["reason"] = old.get((e["rule"], e["path"], e["match"]), e["reason"])
    path = baseline_path(root)
    with open(path, "w") as f:
        json.dump({"schema": BASELINE_SCHEMA, "findings": entries}, f,
                  indent=1, sort_keys=True)
        f.write("\n")
    return path


def check_baseline(findings: list[Finding], entries: list[dict]
                   ) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Split findings into (unbaselined, baselined) and report stale
    baseline entries that matched nothing."""
    index = {(e["rule"], e["path"], e["match"]): e for e in entries}
    used: set[tuple[str, str, str]] = set()
    fresh, grandfathered = [], []
    for f in findings:
        if f.key() in index:
            used.add(f.key())
            grandfathered.append(f)
        else:
            fresh.append(f)
    stale = [e for k, e in index.items() if k not in used]
    return fresh, grandfathered, stale

from repro.blockchain.chain import Block, Blockchain, hash_params  # noqa: F401
from repro.blockchain.commit import (  # noqa: F401
    AGG_COMMIT_KIND,
    MerkleProof,
    RoundCommitments,
    commitment_leaf,
    verify_membership,
)
from repro.blockchain.ledger import TokenLedger  # noqa: F401
from repro.blockchain.txpool import Transaction, TxPool  # noqa: F401

from repro.blockchain.chain import Block, Blockchain, hash_params  # noqa: F401
from repro.blockchain.ledger import TokenLedger  # noqa: F401
from repro.blockchain.txpool import Transaction, TxPool  # noqa: F401

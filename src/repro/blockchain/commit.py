"""Sender-bound model commitments (paper Fig. 1 steps 2/5/6, done right).

The original consensus check (`Blockchain.verify_round` over ``agg_hash``)
tested bare *set membership*: "client i's committed hash appears among the
hashes the producer aggregated".  That is exactly the anti-freeriding check
the paper claims — and it is broken: a freerider that commits a **copy of an
honest peer's hash** is inside the set and gets paid, and duplicate hashes
(two honest clients with identical params) collapse under set semantics.

This module binds every commitment to its sender:

  * a *leaf* is ``SHA-256(sender | round | digest)`` — the digest itself is
    the device-computed fingerprint (`repro.kernels.fingerprint`), so the
    host only ever handles `O(cohort)` digest bytes;
  * the producer's aggregation record is an **ordered per-sender list** —
    one entry per arrived client, duplicates preserved — plus the Merkle
    root over the leaves;
  * verification compares client i's committed digest against the digest
    the producer recorded *for sender i* (copying a peer's digest now fails,
    because the producer's entry for the copier holds the digest of the
    params the copier actually delivered);
  * Merkle membership proofs let any client audit its own inclusion in
    `O(log cohort)` hashes without replaying the block.

Everything is canonical-JSON + SHA-256 over strings, so block hashes stay
deterministic and replayable across runs and validators.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from functools import cached_property

AGG_COMMIT_KIND = "agg_commit"      # sender-bound producer record
MODEL_COMMIT_KIND = "model_hash"    # client-side commitment (Fig. 1 step 2)

# Serving-tier release commitments (repro.serve.snapshot): the "sender" of a
# release entry is a CLUSTER id, not a client id — the released artifact is
# the cluster-personalized model, and the same (sender, round, digest) leaf /
# Merkle-proof machinery gives each served model an O(log K) provenance check
# against the release block.
MODEL_RELEASE_KIND = "model_release"      # one per released cluster model
RELEASE_COMMIT_KIND = "release_commit"    # producer's sender-bound release record


def commitment_leaf(sender: int, round_idx: int, digest: str) -> str:
    """SHA-256 leaf binding (sender, round, digest) — the unit the Merkle
    tree is built over.  Including the round prevents cross-round replay of
    a stale commitment."""
    body = json.dumps({"sender": int(sender), "round": int(round_idx),
                       "digest": digest}, sort_keys=True)
    return hashlib.sha256(body.encode()).hexdigest()


def _parent(a: str, b: str) -> str:
    return hashlib.sha256((a + b).encode()).hexdigest()


@dataclass(frozen=True)
class MerkleProof:
    """Membership proof: sibling hashes bottom-up with their side."""
    leaf: str
    path: tuple[tuple[str, str], ...]   # (sibling_hash, "L" | "R")

    def root(self) -> str:
        h = self.leaf
        for sibling, side in self.path:
            h = _parent(sibling, h) if side == "L" else _parent(h, sibling)
        return h


@dataclass(frozen=True)
class RoundCommitments:
    """The producer's sender-bound aggregation record for one round.

    ``entries`` preserves arrival order and multiplicity — one ``(sender,
    digest)`` pair per client whose update the producer actually aggregated.
    """
    round_idx: int
    entries: tuple[tuple[int, str], ...]

    @cached_property
    def _levels(self) -> list[list[str]]:
        level = [commitment_leaf(s, self.round_idx, d)
                 for s, d in self.entries]
        if not level:
            level = [hashlib.sha256(b"empty").hexdigest()]
        levels = [level]
        while len(level) > 1:
            if len(level) % 2:
                level = level + [level[-1]]
            level = [_parent(a, b) for a, b in zip(level[::2], level[1::2])]
            levels.append(level)
        return levels

    @property
    def root(self) -> str:
        return self._levels[-1][0]

    def digest_for(self, sender: int) -> str | None:
        """The digest the producer recorded for ``sender`` (None if the
        sender's update never reached the producer)."""
        for s, d in self.entries:
            if s == sender:
                return d
        return None

    def proof(self, sender: int) -> MerkleProof:
        """Membership proof for ``sender``'s entry (first occurrence)."""
        idx = next(i for i, (s, _) in enumerate(self.entries) if s == sender)
        leaf = self._levels[0][idx]
        path = []
        for level in self._levels[:-1]:
            level = level + [level[-1]] if len(level) % 2 else level
            sib = idx ^ 1
            path.append((level[sib], "L" if sib < idx else "R"))
            idx //= 2
        return MerkleProof(leaf, tuple(path))

    def to_payload(self) -> str:
        """Canonical JSON payload for the producer's ``agg_commit`` tx."""
        return json.dumps({"root": self.root,
                           "entries": [[s, d] for s, d in self.entries]},
                          sort_keys=True)

    @classmethod
    def from_payload(cls, round_idx: int, payload: str) -> "RoundCommitments":
        body = json.loads(payload)
        rc = cls(round_idx, tuple((int(s), str(d)) for s, d in body["entries"]))
        if rc.root != body["root"]:
            raise ValueError("agg_commit root does not match its entries")
        return rc


def verify_membership(root: str, sender: int, round_idx: int, digest: str,
                      proof: MerkleProof) -> bool:
    """Audit path: does ``proof`` place (sender, round, digest) under
    ``root``?  `O(log cohort)` hashes, no block replay."""
    return (proof.leaf == commitment_leaf(sender, round_idx, digest)
            and proof.root() == root)

"""Transaction pool for the BFLN chain."""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Transaction:
    kind: str        # "model_hash" | "agg_commit" | "agg_hash" (legacy)
                     # | "reward" | "fee" | "stake"
    sender: int      # client id (-1 = network)
    payload: str     # hash hex / JSON body
    round_idx: int

    def tx_hash(self) -> str:
        # memoised: computed at submit, reused by merkle build + validation
        # (frozen dataclass -> write through __dict__; not a compared field)
        h = self.__dict__.get("_tx_hash")
        if h is None:
            body = json.dumps(
                {"kind": self.kind, "sender": self.sender,
                 "payload": self.payload, "round": self.round_idx},
                sort_keys=True)
            h = hashlib.sha256(body.encode()).hexdigest()
            object.__setattr__(self, "_tx_hash", h)
        return h


@dataclass
class TxPool:
    pending: list[Transaction] = field(default_factory=list)

    def submit(self, tx: Transaction) -> str:
        self.pending.append(tx)
        return tx.tx_hash()

    def drain(self) -> list[Transaction]:
        txs, self.pending = self.pending, []
        return txs

    def __len__(self) -> int:
        return len(self.pending)

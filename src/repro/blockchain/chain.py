"""Deterministic in-process blockchain (paper §IV-C + Fig. 1 steps 2/5/6).

Permissioned DPoS-style chain: block producers come from CACC's packing queue
(cluster-centroid clients) and take turns; there is no PoW.  Blocks carry two
transaction kinds:

  * ``model_hash`` — a training client commits the SHA-256 of its local model
    before aggregation (Fig. 1 step 2),
  * ``agg_hash``   — the producer (aggregation client) records the hashes of
    every model it actually aggregated (Fig. 1 step 5).

Consensus (Fig. 1 step 6) — :meth:`Blockchain.verify_round` — rewards a client
iff its committed hash appears in the producer's aggregation transaction.
Everything is deterministic and replayable: hashing is canonical over leaf
paths + raw bytes, so any validator reproduces identical block hashes.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.blockchain.txpool import Transaction, TxPool

Pytree = Any


def hash_params(params: Pytree) -> str:
    """Canonical SHA-256 of a parameter pytree (path-sorted leaf bytes)."""
    h = hashlib.sha256()
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in sorted(leaves, key=lambda kv: jax.tree_util.keystr(kv[0])):
        arr = np.asarray(jax.device_get(leaf))
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _merkle_root(tx_hashes: list[str]) -> str:
    """Pairwise SHA-256 merkle root (duplicate last on odd levels)."""
    if not tx_hashes:
        return hashlib.sha256(b"empty").hexdigest()
    level = list(tx_hashes)
    while len(level) > 1:
        if len(level) % 2:
            level.append(level[-1])
        level = [hashlib.sha256((a + b).encode()).hexdigest()
                 for a, b in zip(level[::2], level[1::2])]
    return level[0]


@dataclass(frozen=True)
class Block:
    index: int
    round_idx: int
    producer: int                  # client id of the packing (aggregation) client
    prev_hash: str
    merkle_root: str
    transactions: tuple[Transaction, ...]

    def header(self) -> dict:
        return {"index": self.index, "round": self.round_idx,
                "producer": self.producer, "prev": self.prev_hash,
                "merkle": self.merkle_root}

    def block_hash(self) -> str:
        return hashlib.sha256(
            json.dumps(self.header(), sort_keys=True).encode()).hexdigest()


@dataclass
class Blockchain:
    blocks: list[Block] = field(default_factory=list)

    def __post_init__(self):
        if not self.blocks:
            genesis = Block(0, -1, -1, "0" * 64, _merkle_root([]), ())
            self.blocks.append(genesis)

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def pack_block(self, round_idx: int, producer: int, pool: TxPool) -> Block:
        """Producer drains the tx pool into a new block (DPoS slot)."""
        txs = tuple(pool.drain())
        block = Block(
            index=len(self.blocks),
            round_idx=round_idx,
            producer=producer,
            prev_hash=self.head.block_hash(),
            merkle_root=_merkle_root([t.tx_hash() for t in txs]),
            transactions=txs,
        )
        self.blocks.append(block)
        return block

    def validate(self) -> bool:
        """Full-chain validation: hash links + merkle roots."""
        for prev, cur in zip(self.blocks, self.blocks[1:]):
            if cur.prev_hash != prev.block_hash():
                return False
            if cur.merkle_root != _merkle_root([t.tx_hash() for t in cur.transactions]):
                return False
        return True

    # ------------------------------------------------------------------ #
    # Consensus verification (Fig. 1 step 6)
    # ------------------------------------------------------------------ #

    def verify_round(self, block: Block, n_clients: int) -> np.ndarray:
        """Boolean mask (n_clients,): client i's committed ``model_hash``
        appears among the producer's ``agg_hash`` entries in ``block``."""
        committed: dict[int, str] = {}
        aggregated: set[str] = set()
        for tx in block.transactions:
            if tx.kind == "model_hash":
                committed[tx.sender] = tx.payload
            elif tx.kind == "agg_hash":
                aggregated.update(json.loads(tx.payload))
        ok = np.zeros((n_clients,), dtype=bool)
        for cid, h in committed.items():
            if 0 <= cid < n_clients and h in aggregated:
                ok[cid] = True
        return ok

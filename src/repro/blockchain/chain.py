"""Deterministic in-process blockchain (paper §IV-C + Fig. 1 steps 2/5/6).

Permissioned DPoS-style chain: block producers come from CACC's packing queue
(cluster-centroid clients) and take turns; there is no PoW.  Blocks carry two
commitment transaction kinds:

  * ``model_hash``  — a training client commits the fingerprint digest of its
    local model before aggregation (Fig. 1 step 2),
  * ``agg_commit``  — the producer (aggregation client) records a
    **sender-bound** list of the digests it actually aggregated — one entry
    per arrived client — plus a Merkle root over the (sender, round, digest)
    leaves (Fig. 1 step 5; see ``repro.blockchain.commit``).

Consensus (Fig. 1 step 6) — :meth:`Blockchain.verify_round` — rewards client
i iff its committed digest equals the digest the producer recorded *for
sender i*.  The retired ``agg_hash`` transaction kind (bare hash set, no
sender binding) is still parsed so old chains replay and so tests can
demonstrate the hash-copy freeriding attack it permitted.

Everything is deterministic and replayable: hashing is canonical over
strings/JSON, so any validator reproduces identical block hashes.
``hash_params`` (host-side SHA-256 over full param bytes) remains as the
reference digest for tests and the commit-path benchmark baseline; the hot
path uses the device-side batched fingerprint (`repro.kernels.fingerprint`).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.blockchain.commit import AGG_COMMIT_KIND, RoundCommitments
from repro.blockchain.txpool import Transaction, TxPool

Pytree = Any


def hash_params(params: Pytree) -> str:
    """Canonical SHA-256 of a parameter pytree (path-sorted leaf bytes)."""
    h = hashlib.sha256()
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in sorted(leaves, key=lambda kv: jax.tree_util.keystr(kv[0])):
        arr = np.asarray(jax.device_get(leaf))
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _merkle_root(tx_hashes: list[str]) -> str:
    """Pairwise SHA-256 merkle root (duplicate last on odd levels)."""
    if not tx_hashes:
        return hashlib.sha256(b"empty").hexdigest()
    level = list(tx_hashes)
    while len(level) > 1:
        if len(level) % 2:
            level.append(level[-1])
        level = [hashlib.sha256((a + b).encode()).hexdigest()
                 for a, b in zip(level[::2], level[1::2])]
    return level[0]


@dataclass(frozen=True)
class Block:
    index: int
    round_idx: int
    producer: int                  # client id of the packing (aggregation) client
    prev_hash: str
    merkle_root: str
    transactions: tuple[Transaction, ...]

    def header(self) -> dict:
        return {"index": self.index, "round": self.round_idx,
                "producer": self.producer, "prev": self.prev_hash,
                "merkle": self.merkle_root}

    def block_hash(self) -> str:
        return hashlib.sha256(
            json.dumps(self.header(), sort_keys=True).encode()).hexdigest()


@dataclass
class Blockchain:
    blocks: list[Block] = field(default_factory=list)

    def __post_init__(self):
        if not self.blocks:
            genesis = Block(0, -1, -1, "0" * 64, _merkle_root([]), ())
            self.blocks.append(genesis)

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def pack_block(self, round_idx: int, producer: int, pool: TxPool) -> Block:
        """Producer drains the tx pool into a new block (DPoS slot)."""
        txs = tuple(pool.drain())
        block = Block(
            index=len(self.blocks),
            round_idx=round_idx,
            producer=producer,
            prev_hash=self.head.block_hash(),
            merkle_root=_merkle_root([t.tx_hash() for t in txs]),
            transactions=txs,
        )
        self.blocks.append(block)
        return block

    def validate(self) -> bool:
        """Full-chain validation: hash links + merkle roots."""
        for prev, cur in zip(self.blocks, self.blocks[1:]):
            if cur.prev_hash != prev.block_hash():
                return False
            if cur.merkle_root != _merkle_root([t.tx_hash() for t in cur.transactions]):
                return False
        return True

    # ------------------------------------------------------------------ #
    # Consensus verification (Fig. 1 step 6)
    # ------------------------------------------------------------------ #

    def verify_round(self, block: Block, n_clients: int) -> np.ndarray:
        """Boolean mask (n_clients,): client i's committed ``model_hash``
        digest matches the digest the producer's ``agg_commit`` records for
        sender i (identity-bound — copying a peer's digest fails, because
        the producer's entry for the copier holds what the copier actually
        delivered).

        Legacy ``agg_hash`` blocks (pre-sender-binding) fall back to the old
        set-membership rule so historic chains replay; new blocks never mix
        the two kinds."""
        committed: dict[int, str] = {}
        bound: dict[int, str] | None = None
        legacy: set[str] = set()
        for tx in block.transactions:
            if tx.kind == "model_hash":
                committed[tx.sender] = tx.payload
            elif tx.kind == AGG_COMMIT_KIND:
                try:
                    commits = RoundCommitments.from_payload(block.round_idx,
                                                            tx.payload)
                except (ValueError, KeyError, TypeError):
                    bound = {}          # malformed record: nobody verifies
                else:
                    # first occurrence wins, matching RoundCommitments.proof
                    bound = {}
                    for s, d in commits.entries:
                        bound.setdefault(s, d)
            elif tx.kind == "agg_hash":
                legacy.update(json.loads(tx.payload))
        ok = np.zeros((n_clients,), dtype=bool)
        for cid, h in committed.items():
            if not 0 <= cid < n_clients:
                continue
            if bound is not None:
                ok[cid] = bound.get(cid) == h
            else:
                ok[cid] = h in legacy
        return ok

"""Deterministic in-process blockchain (paper §IV-C + Fig. 1 steps 2/5/6).

Permissioned DPoS-style chain: block producers come from CACC's packing queue
(cluster-centroid clients) and take turns; there is no PoW.  Blocks carry two
commitment transaction kinds:

  * ``model_hash``  — a training client commits the fingerprint digest of its
    local model before aggregation (Fig. 1 step 2),
  * ``agg_commit``  — the producer (aggregation client) records a
    **sender-bound** list of the digests it actually aggregated — one entry
    per arrived client — plus a Merkle root over the (sender, round, digest)
    leaves (Fig. 1 step 5; see ``repro.blockchain.commit``).

Consensus (Fig. 1 step 6) — :meth:`Blockchain.verify_round` — rewards client
i iff its committed digest equals the digest the producer recorded *for
sender i*.  The retired ``agg_hash`` transaction kind (bare hash set, no
sender binding) is still parsed so old chains replay and so tests can
demonstrate the hash-copy freeriding attack it permitted.

Everything is deterministic and replayable: hashing is canonical over
strings/JSON, so any validator reproduces identical block hashes.
``hash_params`` (host-side SHA-256 over full param bytes) remains as the
reference digest for tests and the commit-path benchmark baseline; the hot
path uses the device-side batched fingerprint (`repro.kernels.fingerprint`).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.blockchain.commit import AGG_COMMIT_KIND, RoundCommitments
from repro.blockchain.txpool import Transaction, TxPool
from repro.obs import NULL_RECORDER

Pytree = Any


def hash_params(params: Pytree) -> str:
    """Canonical SHA-256 of a parameter pytree (path-sorted leaf bytes)."""
    h = hashlib.sha256()
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in sorted(leaves, key=lambda kv: jax.tree_util.keystr(kv[0])):
        arr = np.asarray(jax.device_get(leaf))
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _merkle_root(tx_hashes: list[str]) -> str:
    """Domain-separated pairwise SHA-256 merkle root.

    Leaf and interior hashes live in disjoint domains (RFC-6962 style) and an
    odd node is *promoted* to the next level instead of paired with itself —
    so appending a duplicate of the last transaction always changes the root.
    The retired scheme (bare pairwise hashing, duplicate-last padding) allowed
    the Bitcoin CVE-2012-2459 mutation: ``root([a, b, c]) == root([a, b, c,
    c])``, letting ``validate()`` accept a chain whose block had its last tx
    duplicated.  Old blocks built with that scheme still validate through
    :func:`_legacy_merkle_root`'s explicit-self-pair check.
    """
    if not tx_hashes:
        return hashlib.sha256(b"empty").hexdigest()
    level = [hashlib.sha256(b"leaf:" + h.encode()).hexdigest()
             for h in tx_hashes]
    while len(level) > 1:
        nxt = [hashlib.sha256(b"node:" + (a + b).encode()).hexdigest()
               for a, b in zip(level[::2], level[1::2])]
        if len(level) % 2:
            nxt.append(level[-1])                   # promote, never self-pair
        level = nxt
    return level[0]


def _legacy_merkle_root(tx_hashes: list[str]) -> tuple[str, bool]:
    """The retired duplicate-last-padding root, plus a mutation flag.

    Returns ``(root, mutated)`` where ``mutated`` is True iff some level
    hashes two *explicit* identical adjacent nodes together (Bitcoin's
    CVE-2012-2459 detector): padding self-pairs an odd level's last node
    implicitly, so an honest odd-length block never trips the flag, while
    the duplicated-last-tx mutation — which produces the identical root —
    always does.  Like Bitcoin, the detector cannot tell a mutation from a
    legacy block that *legitimately* carried identical adjacent
    transactions; such duplicates are treated as invalid (a commitment is
    idempotent — re-submitting the identical tx carries no information, and
    in-repo legacy chains never contained one).  Blocks packed after the
    domain separation never consult this fallback, so duplicate txs in NEW
    blocks validate fine."""
    if not tx_hashes:
        return hashlib.sha256(b"empty").hexdigest(), False
    level = list(tx_hashes)
    mutated = False
    while len(level) > 1:
        mutated |= any(level[i] == level[i + 1]
                       for i in range(0, len(level) - 1, 2))
        if len(level) % 2:
            level.append(level[-1])
        level = [hashlib.sha256((a + b).encode()).hexdigest()
                 for a, b in zip(level[::2], level[1::2])]
    return level[0], mutated


@dataclass(frozen=True)
class Block:
    index: int
    round_idx: int
    producer: int                  # client id of the packing (aggregation) client
    prev_hash: str
    merkle_root: str
    transactions: tuple[Transaction, ...]

    def header(self) -> dict:
        return {"index": self.index, "round": self.round_idx,
                "producer": self.producer, "prev": self.prev_hash,
                "merkle": self.merkle_root}

    def block_hash(self) -> str:
        return hashlib.sha256(
            json.dumps(self.header(), sort_keys=True).encode()).hexdigest()


@dataclass
class Blockchain:
    blocks: list[Block] = field(default_factory=list)
    quarantined: list[Block] = field(default_factory=list)  # rejected blocks

    def __post_init__(self):
        if not self.blocks:
            genesis = Block(0, -1, -1, "0" * 64, _merkle_root([]), ())
            self.blocks.append(genesis)
        self.obs = NULL_RECORDER    # flight recorder (repro.obs), rebindable

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def block_ok(self, block: Block) -> bool:
        """Structural admission check for a candidate head block: correct
        hash link to the current head and a merkle root that matches its own
        transactions.  This is what :meth:`validate` enforces per link —
        running it at admission time lets a malformed or digest-mismatched
        block be quarantined instead of poisoning the chain."""
        return (block.prev_hash == self.head.block_hash()
                and block.merkle_root == _merkle_root(
                    [t.tx_hash() for t in block.transactions]))

    def pack_block(self, round_idx: int, producer: int, pool: TxPool,
                   faults=None) -> Block:
        """Producer drains the tx pool into a new block (DPoS slot).

        ``faults`` (`repro.faults`) may inject a digest-mismatched candidate
        first; the admission check rejects it into ``quarantined`` and the
        round continues with an honestly re-packed block — the
        quarantine-and-continue degradation path."""
        with self.obs.span("chain.pack", cat="chain", round=round_idx) as sp:
            txs = tuple(pool.drain())
            if faults is not None and faults.bad_block(round_idx):
                bad = Block(
                    index=len(self.blocks), round_idx=round_idx,
                    producer=producer, prev_hash=self.head.block_hash(),
                    merkle_root=hashlib.sha256(
                        b"corrupt:" + str(round_idx).encode()).hexdigest(),
                    transactions=txs)
                assert not self.block_ok(bad)
                self.quarantined.append(bad)
                self.obs.event("fault.block_quarantined", round=round_idx,
                               block_hash=bad.block_hash())
                self.obs.inc("fault.block_quarantined")
            block = Block(
                index=len(self.blocks),
                round_idx=round_idx,
                producer=producer,
                prev_hash=self.head.block_hash(),
                merkle_root=_merkle_root([t.tx_hash() for t in txs]),
                transactions=txs,
            )
            self.blocks.append(block)
            sp.set(n_tx=len(txs))
        self.obs.inc("chain.blocks")
        self.obs.inc("chain.tx", len(txs))
        return block

    def validate(self) -> bool:
        """Full-chain validation: hash links + merkle roots.

        A block's recorded root must match the domain-separated scheme; a
        block packed before the domain separation (legacy duplicate-last
        padding) is still accepted on its legacy root, but only when the
        legacy computation saw no explicit self-paired nodes — the
        CVE-2012-2459 duplicated-tx mutation reproduces the legacy root yet
        always trips that flag, so the mutated chain is rejected under both
        schemes."""
        with self.obs.span("chain.validate", cat="chain") as sp:
            sp.set(n_blocks=len(self.blocks))
            return self._validate()

    def _validate(self) -> bool:
        for prev, cur in zip(self.blocks, self.blocks[1:]):
            if cur.prev_hash != prev.block_hash():
                return False
            hashes = [t.tx_hash() for t in cur.transactions]
            if cur.merkle_root != _merkle_root(hashes):
                legacy_root, mutated = _legacy_merkle_root(hashes)
                if mutated or cur.merkle_root != legacy_root:
                    return False
        return True

    # ------------------------------------------------------------------ #
    # Consensus verification (Fig. 1 step 6)
    # ------------------------------------------------------------------ #

    def verify_round(self, block: Block, n_clients: int) -> np.ndarray:
        """Boolean mask (n_clients,): client i's committed ``model_hash``
        digest matches the digest the producer's ``agg_commit`` records for
        sender i (identity-bound — copying a peer's digest fails, because
        the producer's entry for the copier holds what the copier actually
        delivered).

        Duplicates resolve first-wins on BOTH sides: a client's first
        ``model_hash`` is the digest the producer actually saw, and only the
        first ``agg_commit`` *sent by the block's producer* is consulted —
        any other sender's record is ignored (a client must not be able to
        front-run the producer and control the round's verification basis).

        Legacy ``agg_hash`` blocks (pre-sender-binding) fall back to the old
        set-membership rule so historic chains replay; new blocks never mix
        the two kinds."""
        with self.obs.span("chain.verify", cat="chain",
                           round=block.round_idx):
            return self._verify_round(block, n_clients)

    def _verify_round(self, block: Block, n_clients: int) -> np.ndarray:
        committed: dict[int, str] = {}
        bound: dict[int, str] | None = None
        legacy: set[str] = set()
        for tx in block.transactions:
            if tx.kind == "model_hash":
                if tx.round_idx != block.round_idx:
                    # a commit delivered late (e.g. a delayed-delivery fault)
                    # lands in a later round's block: it is recorded there
                    # but carries no verification weight — commitments bind
                    # to the round they were made for
                    continue
                # FIRST commit wins — the digest the producer actually saw
                # and aggregated.  Last-wins let a client re-submit after the
                # producer recorded it and be judged against the wrong digest
                # (honest clients punished, or a freerider aligning its late
                # commit with the producer's entry for it).
                committed.setdefault(tx.sender, tx.payload)
            elif tx.kind == AGG_COMMIT_KIND:
                if tx.sender != block.producer:
                    continue            # only the packing producer's record
                                        # counts: a client must not front-run
                                        # the round's verification basis
                if bound is not None:
                    continue            # first agg_commit wins, like commits
                try:
                    commits = RoundCommitments.from_payload(block.round_idx,
                                                            tx.payload)
                except (ValueError, KeyError, TypeError):
                    bound = {}          # malformed record: nobody verifies
                else:
                    # first occurrence wins, matching RoundCommitments.proof
                    bound = {}
                    for s, d in commits.entries:
                        bound.setdefault(s, d)
            elif tx.kind == "agg_hash":
                legacy.update(json.loads(tx.payload))
        ok = np.zeros((n_clients,), dtype=bool)
        for cid, h in committed.items():
            if not 0 <= cid < n_clients:
                continue
            if bound is not None:
                ok[cid] = bound.get(cid) == h
            else:
                ok[cid] = h in legacy
        return ok

"""Token ledger for the BFLN incentive mechanism.

Authoritative host-side balances; the jittable mirror lives in
``repro.core.incentives.apply_round_settlement``.  Conservation invariant:
tokens only enter via ``mint`` (initial stake + round reward pool) and total
supply equals Σ balances at all times (property-tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.obs import NULL_RECORDER


@dataclass
class TokenLedger:
    n_clients: int
    initial_stake: float = 5.0
    balances: np.ndarray = field(init=False)
    minted: float = field(init=False)

    def __post_init__(self):
        self.balances = np.full((self.n_clients,), float(self.initial_stake))
        self.minted = float(self.initial_stake) * self.n_clients
        self.obs = NULL_RECORDER    # flight recorder (repro.obs), rebindable

    def mint_reward_pool(self, amount: float) -> float:
        self.minted += float(amount)
        return float(amount)

    def settle_round(self, client_reward: np.ndarray, fee: float,
                     producer: int, verified: np.ndarray) -> None:
        """Verified clients receive their reward and pay the aggregation fee;
        the producer collects the fees only if its OWN commitment verified —
        a producer that failed verification (freeriding aggregator) earns
        nothing and the fees are burned alongside the unverified rewards (the
        unclaimed part of the pool never enters balances)."""
        client_reward = np.asarray(client_reward, dtype=np.float64)
        verified = np.asarray(verified, dtype=bool)
        paid = np.where(verified, client_reward, 0.0)
        fees = np.where(verified, fee, 0.0)
        self.balances = self.balances + paid - fees
        if verified[producer]:
            self.balances[producer] += fees.sum()
        else:
            self.minted -= float(fees.sum())        # forfeited fees leave supply
        # burned tokens leave supply
        burned = float(np.where(~verified, client_reward, 0.0).sum())
        self.minted -= burned
        obs = self.obs
        if obs.enabled:
            obs.observe("ledger.paid", float(paid.sum()))
            obs.observe("ledger.fees", float(fees.sum()))
            obs.observe("ledger.burned", burned)

    def total_supply(self) -> float:
        return float(self.balances.sum())

    def conserved(self, rtol: float = 1e-6) -> bool:
        tol = rtol * max(1.0, abs(self.minted))
        return abs(self.total_supply() - self.minted) <= tol

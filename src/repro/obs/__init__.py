"""`repro.obs` — flight recorder: structured tracing + metrics for BFLN runs.

Low-overhead, always-out-of-band observability across the round engine, the
event-driven simulator, the blockchain layer, and the experiment runner:

    spec = ExperimentSpec(obs=ObsSpec(enabled=True, trace_path="run.jsonl"))
    result = run(spec)            # manifest carries the trace file's sha256
    print(result.summary())       # ... | round p50=82.1ms chain=7% compiles=4

The recorder captures wall-clock *and* sim virtual-clock spans per round
phase (sample, gather, donated step, digests, chain, eval, async flush),
explicit compile events from `RoundEngine.cache_sizes()` deltas, and a
metrics registry of per-round counters/gauges with streaming p50/p99
summaries.  Sinks: a schema-validated JSONL trace (digest stamped into the
run manifest), a console summary table, and a Chrome/Perfetto export.

Hard invariant: tracing on vs. off leaves event logs, block hashes, ledger
balances and final accuracy bit-identical — observability may time and
count, never perturb (pinned by ``tests/test_obs_invariance.py``).
"""
from repro.obs.metrics import MetricsRegistry, Summary  # noqa: F401
from repro.obs.names import (  # noqa: F401
    ALL_NAMES,
    COUNTER_NAMES,
    DYNAMIC_PREFIXES,
    EVENT_NAMES,
    GAUGE_NAMES,
    SERIES_NAMES,
    SPAN_NAMES,
)
from repro.obs.recorder import (  # noqa: F401
    NULL_RECORDER,
    FlightRecorder,
    NullRecorder,
)
from repro.obs.schema import (  # noqa: F401
    SCHEMA_VERSION,
    validate_record,
    validate_trace_lines,
)
from repro.obs.sinks import (  # noqa: F401
    console_summary,
    file_sha256,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.spec import ObsSpec  # noqa: F401

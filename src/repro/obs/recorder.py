"""`FlightRecorder` — the always-out-of-band span tracer + metrics hub.

One recorder instance rides along one experiment run.  Instrumented code
never checks whether tracing is on: it calls ``obs.span(...)`` /
``obs.inc(...)`` unconditionally, and when observability is disabled those
calls land on the module-level :data:`NULL_RECORDER` whose methods are
no-ops (a few hundred nanoseconds per round — the < 2% trace-off overhead
budget the round bench pins).

Hard invariant (tested): the recorder only *times and counts*.  It never
draws from a seeded generator, never mutates simulation state, and never
forces a value that wasn't already being materialised — ``ready()`` may
block on device work (so a span's wall time covers the computation it
launched) but blocking changes no bits.

Span records carry two clocks: host wall time (``ts_us``/``dur_us``,
microseconds since trace start) and the simulator's *virtual* clock (``vt``
at span close, plus a ``vt_dur`` attr when virtual time advanced inside the
span) — so a trace shows both where a round's milliseconds go and where its
simulated seconds go.

Compile events are sourced from ``RoundEngine.cache_sizes()`` deltas
(:meth:`FlightRecorder.compile_delta`): the engine's jit caches are the
ground truth for "this round paid a compile", and the delta shows up as an
explicit ``compile`` event in the trace instead of an anonymous latency
spike.
"""
from __future__ import annotations

import time
from typing import Any, Callable

from repro.obs.metrics import MetricsRegistry
from repro.obs.spec import ObsSpec


class _Span:
    """A timed phase.  ``with rec.span("round.step", round=r) as sp: ...``;
    ``sp.set(k=v)`` attaches attributes before close."""

    __slots__ = ("_rec", "name", "cat", "round", "attrs", "_t0", "_vt0")

    def __init__(self, rec: "FlightRecorder", name: str, cat: str,
                 round_idx: int | None, attrs: dict):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.round = round_idx
        self.attrs = attrs

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        self._vt0 = self._rec._vt()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        rec = self._rec
        vt1 = rec._vt()
        if self._vt0 is not None and vt1 is not None and vt1 != self._vt0:
            self.attrs["vt_dur"] = vt1 - self._vt0
        dur_us = (t1 - self._t0) / 1e3
        record = {"kind": "span", "name": self.name, "cat": self.cat,
                  "round": self.round,
                  "ts_us": round((self._t0 - rec._t0) / 1e3, 3),
                  "dur_us": round(dur_us, 3), "vt": vt1}
        if self.attrs:
            record["attrs"] = self.attrs
        rec.records.append(record)
        rec.metrics.observe(self.name, dur_us / 1e3)      # summary in ms
        return False


class _NullSpan:
    __slots__ = ()

    def set(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """Shared no-op recorder bound when observability is disabled.  Keeps the
    exact `FlightRecorder` surface so instrumented code never branches."""

    __slots__ = ()
    enabled = False
    spec = ObsSpec()

    def span(self, name: str, *, cat: str = "round",
             round: int | None = None, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, *, round: int | None = None, **attrs) -> None:
        pass

    def point(self, name: str, value: float,
              round: int | None = None) -> None:
        pass

    def inc(self, name: str, value: float = 1.0) -> None:
        pass

    def set_gauge(self, name: str, value: float) -> None:
        pass

    def observe(self, name: str, value: float) -> None:
        pass

    def compile_delta(self, cache_sizes: dict,
                      round_idx: int | None = None) -> None:
        pass

    def ready(self, x: Any) -> Any:
        return x


NULL_RECORDER = NullRecorder()


class FlightRecorder:
    """Live recorder: spans/events/points into an in-memory record list,
    scalars into a :class:`MetricsRegistry`.  Sinks (`repro.obs.sinks`)
    serialise both at end of run."""

    enabled = True

    def __init__(self, spec: ObsSpec | None = None, *,
                 clock: Callable[[], float] | None = None):
        self.spec = spec if spec is not None else ObsSpec(enabled=True)
        self.records: list[dict] = []
        self.metrics = MetricsRegistry(sample_cap=self.spec.sample_cap)
        self._clock = clock
        self._t0 = time.perf_counter_ns()
        self._cache_prev: dict[str, int] = {}

    # -------------------------------------------------------------- #
    # clock plumbing
    # -------------------------------------------------------------- #

    def bind_clock(self, clock: Callable[[], float] | None) -> None:
        """Attach the simulator's virtual-clock reader (``lambda:
        clock.now``); spans then carry virtual time alongside wall time."""
        self._clock = clock

    def _vt(self) -> float | None:
        return self._clock() if self._clock is not None else None

    def _ts_us(self) -> float:
        return round((time.perf_counter_ns() - self._t0) / 1e3, 3)

    # -------------------------------------------------------------- #
    # recording surface (mirrored by NullRecorder)
    # -------------------------------------------------------------- #

    def span(self, name: str, *, cat: str = "round",
             round: int | None = None, **attrs) -> _Span:
        return _Span(self, name, cat, round, attrs)

    def event(self, name: str, *, round: int | None = None, **attrs) -> None:
        record = {"kind": "event", "name": name, "round": round,
                  "ts_us": self._ts_us()}
        if attrs:
            record["attrs"] = attrs
        self.records.append(record)

    def point(self, name: str, value: float,
              round: int | None = None) -> None:
        """One per-round metric observation, both recorded verbatim in the
        trace and folded into the streaming summary."""
        v = float(value)
        self.records.append({"kind": "point", "name": name, "round": round,
                             "value": v})
        self.metrics.observe(name, v)

    def inc(self, name: str, value: float = 1.0) -> None:
        self.metrics.inc(name, value)

    def set_gauge(self, name: str, value: float) -> None:
        self.metrics.set_gauge(name, value)

    def observe(self, name: str, value: float) -> None:
        self.metrics.observe(name, value)

    def compile_delta(self, cache_sizes: dict,
                      round_idx: int | None = None) -> None:
        """Emit a ``compile`` event per engine entry whose jit-cache size
        grew since the last snapshot (`RoundEngine.cache_sizes()`)."""
        for entry, size in cache_sizes.items():
            d = size - self._cache_prev.get(entry, 0)
            if d > 0:
                self.event("compile", round=round_idx, entry=entry, n=d)
                self.inc("compiles", d)
        self._cache_prev = dict(cache_sizes)

    def ready(self, x: Any) -> Any:
        """Block until device work backing ``x`` finishes (when configured)
        so the enclosing span measures compute, not dispatch.  Values are
        untouched — replay invariance is indifferent to blocking."""
        if self.spec.block_until_ready:
            import jax
            jax.block_until_ready(x)
        return x

    # -------------------------------------------------------------- #
    # derived readouts
    # -------------------------------------------------------------- #

    def timing_summary(self) -> dict:
        """The one-line readout: steady round latency, chain-overhead share,
        compile count — sourced purely from the metrics registry."""
        s = self.metrics.summaries
        total = s.get("round.total") or s.get("flush.total")
        chain = s.get("round.chain") or s.get("flush.chain")
        out = {"compiles": int(self.metrics.counters.get("compiles", 0))}
        if total is not None and total.count:
            out["rounds"] = total.count
            out["round_ms_p50"] = round(total.quantile(0.5), 3)
            out["round_ms_p99"] = round(total.quantile(0.99), 3)
            out["round_ms_mean"] = round(total.mean, 3)
        if chain is not None and total is not None and total.total > 0:
            out["chain_overhead_pct"] = round(
                100.0 * chain.total / total.total, 2)
        return out

"""Trace sinks: JSONL file (digest-stamped), console summary, Chrome trace.

The JSONL sink is the canonical artifact: every record the flight recorder
captured, one JSON object per line (schema: `repro.obs.schema`), written
with sorted keys and compact separators so the file — and therefore its
sha256, which `repro.api.run` stamps into the manifest — is deterministic
given the same records.

The Chrome export rewrites the same spans into the Trace Event Format
(``chrome://tracing`` / https://ui.perfetto.dev): spans become complete
("X") events on one track per category, compile events become instant
markers.  For device-level detail, ``ObsSpec.profile_dir`` additionally
wraps the run in ``jax.profiler.trace`` — the recorder's spans then line up
with XLA's own timeline in the same Perfetto UI.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Mapping

from repro.obs.metrics import MetricsRegistry
from repro.obs.schema import SCHEMA_VERSION


def _dumps(obj: Mapping[str, Any]) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def write_jsonl(path: str, meta: Mapping[str, Any], records: list[dict],
                metrics: MetricsRegistry) -> str:
    """Write the trace file and return its sha256 hexdigest.

    Layout: one ``meta`` header, every span/event/point record in emission
    order, then the end-of-run ``summary``/``counter``/``gauge`` records
    from the metrics registry.
    """
    h = hashlib.sha256()
    snap = metrics.snapshot()
    with open(path, "w") as f:
        def emit(obj: Mapping[str, Any]) -> None:
            line = _dumps(obj) + "\n"
            f.write(line)
            h.update(line.encode())

        emit({"kind": "meta", "schema": SCHEMA_VERSION, **meta})
        for rec in records:
            emit(rec)
        for name, body in snap["summaries"].items():
            emit({"kind": "summary", "name": name, **body})
        for name, value in sorted(snap["counters"].items()):
            emit({"kind": "counter", "name": name, "value": value})
        for name, value in sorted(snap["gauges"].items()):
            emit({"kind": "gauge", "name": name, "value": value})
    return h.hexdigest()


def file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def write_chrome_trace(path: str, records: list[dict]) -> int:
    """Export spans/events as a Chrome Trace Event Format file; returns the
    number of trace events written.  One ``tid`` per span category keeps
    driver phases, chain internals, and ledger flows on separate tracks."""
    events: list[dict] = []
    tids: dict[str, int] = {}
    for rec in records:
        kind = rec.get("kind")
        if kind == "span":
            tid = tids.setdefault(rec["cat"], len(tids) + 1)
            args = dict(rec.get("attrs", {}))
            if rec.get("round") is not None:
                args["round"] = rec["round"]
            if rec.get("vt") is not None:
                args["vt"] = rec["vt"]
            events.append({"name": rec["name"], "cat": rec["cat"], "ph": "X",
                           "ts": rec["ts_us"], "dur": rec["dur_us"],
                           "pid": 1, "tid": tid, "args": args})
        elif kind == "event":
            args = dict(rec.get("attrs", {}))
            if rec.get("round") is not None:
                args["round"] = rec["round"]
            events.append({"name": rec["name"], "cat": "event", "ph": "i",
                           "s": "g", "ts": rec["ts_us"], "pid": 1, "tid": 0,
                           "args": args})
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return len(events)


def console_summary(metrics: MetricsRegistry, *, title: str = "trace") -> str:
    """The ``--trace`` table: per-phase latency breakdown with share of the
    round total, then counters and gauges."""
    snap = metrics.snapshot()
    summaries = snap["summaries"]
    total_key = ("round.total" if "round.total" in summaries
                 else "flush.total" if "flush.total" in summaries else None)
    total_sum = summaries[total_key]["sum"] if total_key else None

    lines = [f"=== {title} ===",
             f"{'phase':<28}{'count':>7}{'mean_ms':>10}{'p50_ms':>10}"
             f"{'p99_ms':>10}{'total_s':>10}{'share':>8}"]
    for name, s in summaries.items():
        # share of round time is only meaningful for phase (span) summaries —
        # ledger.* / async.* series are token amounts and weights, not ms
        is_phase = name.startswith(("round.", "flush.", "chain."))
        share = (f"{100.0 * s['sum'] / total_sum:6.1f}%"
                 if total_sum and is_phase else f"{'':>7}")
        lines.append(f"{name:<28}{s['count']:>7}{s['mean']:>10.3f}"
                     f"{s['p50']:>10.3f}{s['p99']:>10.3f}"
                     f"{s['sum'] / 1e3:>10.3f}{share:>8}")
    if snap["counters"]:
        lines.append("counters: " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(snap["counters"].items())))
    if snap["gauges"]:
        lines.append("gauges:   " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(snap["gauges"].items())))
    return "\n".join(lines)

"""`ObsSpec` — declarative observability configuration.

Lives in its own jax-free module so :mod:`repro.api.spec` (and schema
tooling) can import it without pulling in the runtime.  The spec is the only
knob surface: everything the flight recorder does — whether it records at
all, where the JSONL trace lands, whether a Chrome/Perfetto export or a
console summary is produced — is declared here and travels with the
experiment's JSON round trip.

Observability is *out of band* by contract: it may time and count but never
perturb, so ``ObsSpec`` is deliberately excluded from
``ExperimentSpec.config_digest()`` — trace-on and trace-off runs of the same
experiment share a replay recipe (and the invariance tests pin that their
event logs, block hashes and balances are bit-identical).
"""
from __future__ import annotations

from dataclasses import dataclass


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclass(frozen=True)
class ObsSpec:
    """Flight-recorder configuration (``ExperimentSpec.obs``).

    ``enabled`` is the master switch: when False (the default) the simulator
    binds the shared no-op recorder and the hot path pays only a handful of
    no-op method calls per round (< 0.1% of a steady round).
    """
    enabled: bool = False
    trace_path: str = "trace.jsonl"   # JSONL sink; sha256 lands in the manifest
    chrome_path: str | None = None    # optional Chrome/Perfetto trace export
    console: bool = False             # print the per-phase summary table
    block_until_ready: bool = True    # sync device inside timed spans so a
                                      # span's wall time covers the device work
                                      # it launched (timing only — never values)
    profile_dir: str | None = None    # wrap the run in jax.profiler.trace()
    sample_cap: int = 2048            # streaming-summary reservoir size

    def __post_init__(self):
        _check(isinstance(self.trace_path, str) and self.trace_path != "",
               "trace_path must be a non-empty string")
        _check(self.sample_cap >= 8,
               f"sample_cap must be >= 8, got {self.sample_cap}")
        for name in ("chrome_path", "profile_dir"):
            v = getattr(self, name)
            _check(v is None or (isinstance(v, str) and v != ""),
                   f"{name} must be None or a non-empty string, got {v!r}")

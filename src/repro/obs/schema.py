"""The JSONL trace schema — one record per line, validated in CI.

Kinds (the ``kind`` field picks the shape; unknown kinds are rejected):

    meta     first line of every trace; ``schema`` carries the version and
             the rest mirrors the run manifest (config digest, strategy, …)
    span     a timed phase: ``name``, ``cat``, nullable ``round``, wall-time
             ``ts_us``/``dur_us`` (µs since trace start / duration), nullable
             virtual-clock ``vt``, optional ``attrs`` object
    event    a point-in-time marker (e.g. ``compile``): ``name``, nullable
             ``round``, ``ts_us``, optional ``attrs``
    point    one per-round metric observation: ``name``, ``value``,
             nullable ``round``
    summary  end-of-run streaming summary of one series: ``name`` + count /
             sum / mean / min / max / p50 / p90 / p99
    counter  end-of-run counter total: ``name``, ``value``
    gauge    end-of-run gauge value: ``name``, ``value``

``validate_record`` is the single source of truth: the CI smoke and the obs
tests feed every emitted line through it, so the documented schema and the
written trace cannot drift apart.
"""
from __future__ import annotations

from typing import Any, Mapping

SCHEMA_VERSION = 1

_NUM = (int, float)


def _require(rec: Mapping, name: str, types, *, nullable: bool = False) -> Any:
    if name not in rec:
        raise ValueError(f"record missing required field {name!r}: {rec}")
    v = rec[name]
    if v is None:
        if nullable:
            return v
        raise ValueError(f"field {name!r} must not be null: {rec}")
    if not isinstance(v, types) or isinstance(v, bool):
        raise ValueError(
            f"field {name!r} must be {types}, got {type(v).__name__}: {rec}")
    return v


def _check_attrs(rec: Mapping) -> None:
    if "attrs" in rec and not isinstance(rec["attrs"], dict):
        raise ValueError(f"attrs must be an object: {rec}")


def validate_record(rec: Mapping) -> str:
    """Validate one parsed JSONL record; returns its kind, raises ValueError
    with the offending record on any schema violation."""
    if not isinstance(rec, Mapping):
        raise ValueError(f"record must be a JSON object, got {rec!r}")
    kind = _require(rec, "kind", str)
    if kind == "meta":
        version = _require(rec, "schema", int)
        if version != SCHEMA_VERSION:
            raise ValueError(f"unsupported trace schema version {version} "
                             f"(this build reads {SCHEMA_VERSION})")
    elif kind == "span":
        _require(rec, "name", str)
        _require(rec, "cat", str)
        _require(rec, "round", int, nullable=True)
        if _require(rec, "ts_us", _NUM) < 0:
            raise ValueError(f"ts_us must be >= 0: {rec}")
        if _require(rec, "dur_us", _NUM) < 0:
            raise ValueError(f"dur_us must be >= 0: {rec}")
        _require(rec, "vt", _NUM, nullable=True)
        _check_attrs(rec)
    elif kind == "event":
        _require(rec, "name", str)
        _require(rec, "round", int, nullable=True)
        if _require(rec, "ts_us", _NUM) < 0:
            raise ValueError(f"ts_us must be >= 0: {rec}")
        _check_attrs(rec)
    elif kind == "point":
        _require(rec, "name", str)
        _require(rec, "round", int, nullable=True)
        _require(rec, "value", _NUM)
    elif kind == "summary":
        _require(rec, "name", str)
        if _require(rec, "count", int) < 0:
            raise ValueError(f"count must be >= 0: {rec}")
        for f in ("sum", "mean", "min", "max", "p50", "p90", "p99"):
            _require(rec, f, _NUM)
    elif kind in ("counter", "gauge"):
        _require(rec, "name", str)
        _require(rec, "value", _NUM)
    else:
        raise ValueError(f"unknown record kind {kind!r}: {rec}")
    return kind


def validate_trace_lines(lines) -> dict[str, int]:
    """Validate an iterable of JSONL lines; returns per-kind counts.  The
    first record must be the ``meta`` header."""
    import json
    counts: dict[str, int] = {}
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            raise ValueError(f"blank line {i} in trace")
        kind = validate_record(json.loads(line))
        if i == 0 and kind != "meta":
            raise ValueError(f"first trace record must be meta, got {kind!r}")
        counts[kind] = counts.get(kind, 0) + 1
    if counts.get("meta", 0) != 1:
        raise ValueError(f"trace must contain exactly one meta record, "
                         f"got {counts.get('meta', 0)}")
    return counts

"""Canonical observability name registry — the single source of truth for
every span / event / counter / gauge / series name the flight recorder is
allowed to see.

``docs/TRACE_SCHEMA.md`` documents these names for humans; this module is
the machine-checked form.  The ``trace-schema`` rule of ``repro.analysis``
cross-checks three ways and fails CI on drift:

    1. every literal name passed to a recorder method anywhere in
       ``src/repro`` must be registered here (per method: ``span`` ->
       :data:`SPAN_NAMES`, ``event`` -> :data:`EVENT_NAMES`, ``inc`` ->
       :data:`COUNTER_NAMES`, ``set_gauge`` -> :data:`GAUGE_NAMES`,
       ``observe``/``point`` -> :data:`SERIES_NAMES`);
    2. every name registered here must appear in ``docs/TRACE_SCHEMA.md``;
    3. every dotted metric name mentioned in ``docs/TRACE_SCHEMA.md`` must
       resolve against this registry.

Dynamic name families (f-strings with a literal prefix, e.g.
``f"engine.calls.{name}"``) are registered as prefixes in
:data:`DYNAMIC_PREFIXES`; the schema doc spells them ``engine.calls.<entry>``.

This module is imported by the static analyzer, which must run without jax —
keep it dependency-free.
"""
from __future__ import annotations

# --- spans: timed phases (recorder.span) --------------------------------- #
SPAN_NAMES = frozenset({
    # sync round phases (cat "round")
    "round.total", "round.sample", "round.wait", "round.gather",
    "round.step", "round.digests", "round.chain", "round.scatter",
    "round.eval", "round.retry",
    # async FedBuff flush phases (cat "flush")
    "flush.total", "flush.gather", "flush.step", "flush.chain",
    "flush.merge", "flush.eval",
    # blockchain phases (cat "chain")
    "chain.pack", "chain.validate", "chain.verify", "chain.digests",
    "chain.commit", "chain.consensus", "chain.rewards",
    # checkpoint / run lifecycle
    "ckpt.save", "ckpt.restore", "run.final_eval",
    # serving tier (cat "serve", repro.serve)
    "serve.snapshot", "serve.verify", "serve.batch", "serve.flush",
})

# --- events: point-in-time markers (recorder.event) ----------------------- #
FAULT_EVENT_NAMES = frozenset({
    "fault.crash", "fault.producer_fail", "fault.producer_failover",
    "fault.block_quarantined", "fault.commit_dropped", "fault.commit_delayed",
    "fault.commit_delivered_late", "fault.ckpt_corrupted",
    "fault.ckpt_truncated",
})
EVENT_NAMES = frozenset({"compile"}) | FAULT_EVENT_NAMES

# --- counters: monotone totals (recorder.inc) ----------------------------- #
COUNTER_NAMES = frozenset({
    "compiles", "rounds.empty", "chain.blocks", "chain.tx",
    "ckpt.saved", "ckpt.restored", "fault.retry", "fault.retry_recovered",
    "serve.requests", "serve.rejected", "serve.batches", "serve.releases",
    "serve.verifications",
}) | (FAULT_EVENT_NAMES - {"fault.commit_delivered_late"})

# --- gauges: last-written values (recorder.set_gauge) --------------------- #
GAUGE_NAMES = frozenset({
    "arena.bytes", "arena.per_device_bytes", "engine.cohort_bytes",
    "ckpt.bytes", "run.final_accuracy", "run.n_blocks",
    "serve.bank_bytes", "serve.queue_depth",
})

# --- series: per-round observations (recorder.observe / recorder.point) --- #
SERIES_NAMES = frozenset({
    "async.staleness", "async.staleness_weight", "async.staleness_mean",
    "ledger.paid", "ledger.fees", "ledger.burned",
    "serve.latency", "serve.batch_size",
})

# Dynamic families: a recorder call may build its name with an f-string as
# long as the literal prefix is registered here (schema doc: `<...>` suffix).
DYNAMIC_PREFIXES = ("engine.calls.",)

# recorder method -> the name set it is checked against
METHOD_NAME_SETS = {
    "span": SPAN_NAMES,
    "event": EVENT_NAMES,
    "inc": COUNTER_NAMES,
    "set_gauge": GAUGE_NAMES,
    "observe": SERIES_NAMES,
    "point": SERIES_NAMES,
}

ALL_NAMES = (SPAN_NAMES | EVENT_NAMES | COUNTER_NAMES | GAUGE_NAMES
             | SERIES_NAMES)


def is_registered(name: str, allowed: frozenset | None = None) -> bool:
    """True if ``name`` (a literal, or an f-string literal prefix ending in
    ``.``) is covered by the registry — exact match or dynamic prefix."""
    pool = ALL_NAMES if allowed is None else allowed
    if name in pool:
        return True
    return any(name.startswith(p) or p.startswith(name)
               for p in DYNAMIC_PREFIXES)

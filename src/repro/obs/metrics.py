"""Metrics registry: counters, gauges, and streaming quantile summaries.

Pure Python / stdlib — safe to import from anywhere (including the
blockchain layer, which must stay jax-free).  A :class:`Summary` keeps exact
count/sum/min/max plus a bounded, deterministically-thinned sample reservoir
for p50/p90/p99 estimates: when the reservoir fills, every other kept sample
is dropped and the keep stride doubles, so memory stays O(cap) over
arbitrarily long runs while the kept samples remain an even systematic
sample of the stream (no RNG — observability must never touch a seeded
generator).
"""
from __future__ import annotations

from dataclasses import dataclass, field


class Summary:
    """Streaming distribution summary for one metric series."""

    __slots__ = ("cap", "count", "total", "min", "max", "_samples", "_stride",
                 "_phase")

    def __init__(self, cap: int = 2048):
        if cap < 8:
            raise ValueError(f"cap must be >= 8, got {cap}")
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._samples: list[float] = []
        self._stride = 1          # keep every _stride-th observation
        self._phase = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._phase += 1
        if self._phase < self._stride:
            return
        self._phase = 0
        self._samples.append(v)
        if len(self._samples) >= self.cap:
            self._samples = self._samples[::2]     # systematic thinning
            self._stride *= 2

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile over the kept reservoir."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        pos = q * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (pos - lo)

    def snapshot(self) -> dict:
        """JSON-able summary record body (the JSONL ``summary`` kind)."""
        return {
            "count": self.count,
            "sum": round(self.total, 6),
            "mean": round(self.mean, 6),
            "min": round(self.min, 6) if self.min is not None else 0.0,
            "max": round(self.max, 6) if self.max is not None else 0.0,
            "p50": round(self.quantile(0.50), 6),
            "p90": round(self.quantile(0.90), 6),
            "p99": round(self.quantile(0.99), 6),
        }


@dataclass
class MetricsRegistry:
    """Named counters / gauges / summaries for one run."""

    sample_cap: int = 2048
    counters: dict[str, float] = field(default_factory=dict)
    gauges: dict[str, float] = field(default_factory=dict)
    summaries: dict[str, Summary] = field(default_factory=dict)

    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + float(value)

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        s = self.summaries.get(name)
        if s is None:
            s = self.summaries[name] = Summary(self.sample_cap)
        s.observe(value)

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "summaries": {k: v.snapshot()
                          for k, v in sorted(self.summaries.items())},
        }

"""Explicit expert-parallel MoE via shard_map (the GShard schedule).

Auto-SPMD cannot partition capacity dispatch: the scatter from token-sharded
activations into an expert-sharded buffer makes GSPMD replicate the whole
(E·C, D) buffer (measured: ~30 s of collectives per llama4 train step at
16×16 — EXPERIMENTS.md §Perf).  This module takes manual control:

  per shard: local router → local top-k → LOCAL capacity buffer (no comm)
  all_to_all over the expert axis: (E, C_loc, D) → (E_loc, C, D)
  local expert matmuls (weights resident: E over `ep` axis, F over `tp` axis)
  psum over `tp` for the down-projection partial sums
  all_to_all back + local weighted combine.

Per-device comm per layer = 2 × T_loc·top_k·cf·D bytes of all-to-all +
one psum — the token-movement lower bound, independent of expert-table size.

Requires n_experts % ep_size == 0 (llama4 128/16 ✓, jamba 16/16 ✓;
grok's 8 experts fall back to the dense-dispatch path).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import activation, is_gated
from repro.models.moe import load_balance_loss, router_topk


def _local_dispatch(xt, gates, idx, E, C_loc, top_k):
    """Token-sharded local scatter into (E, C_loc, D) — no communication."""
    T_loc, D = xt.shape
    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                              flat_e[:, None], axis=1)[:, 0]
    keep = pos < C_loc
    slot = jnp.where(keep, flat_e * C_loc + pos, E * C_loc)
    xk = jnp.repeat(xt, top_k, axis=0)
    buf = jnp.zeros((E * C_loc, D), xt.dtype).at[slot].set(
        xk, mode="drop", unique_indices=True)
    return buf.reshape(E, C_loc, D), slot, keep


def ambient_mesh_shape() -> dict:
    """Axis sizes of the ambient (set_mesh) mesh; {} when none is active."""
    am = jax.sharding.get_abstract_mesh()
    return dict(am.shape) if am is not None else {}


def moe_apply_shard_map(act: str, p: dict, x: jax.Array, *, top_k: int,
                        capacity: int, ep_axis: str = "data",
                        tp_axis: str = "model",
                        batch_axes: tuple = ("data",)
                        ) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y, aux).  Weights: w_gate/w_up (E, D, F), w_down
    (E, F, D) — sharded P(ep, None, tp) / P(ep, tp, None).  Uses the ambient
    mesh (jax.set_mesh)."""
    orig_shape = x.shape
    D = x.shape[-1]
    E = p["router"].shape[1]
    assert is_gated(act), "shard_map EP path assumes a gated FFN"
    ep = ambient_mesh_shape()[ep_axis]
    assert E % ep == 0, (E, ep)
    C_loc = max(8, capacity // ep)

    def body(xt, router, w_gate, w_up, w_down):
        # xt (T_loc, D) full-D token shard; weights local (E_loc, D, F_loc)
        T_loc = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router                  # (T_loc, E)
        gates, idx = router_topk(logits, top_k)
        aux = jax.lax.pmean(load_balance_loss(logits, idx, E), ep_axis)

        buf, slot, keep = _local_dispatch(xt, gates, idx, E, C_loc, top_k)
        # (E, C_loc, D) -> (E_loc, C_loc*ep, D): THE expert all-to-all
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)

        gate_h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        up_h = jnp.einsum("ecd,edf->ecf", buf, w_up) if w_up is not None else None
        h = activation(act, gate_h, up_h)
        out = jnp.einsum("ecf,efd->ecd", h, w_down)
        out = jax.lax.psum(out, tp_axis)                          # F-partials

        # back to token shards: (E_loc, C_loc*ep, D) -> (E, C_loc, D)
        out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0,
                                 tiled=True)
        padded = jnp.concatenate(
            [out.reshape(E * C_loc, D), jnp.zeros((1, D), out.dtype)], axis=0)
        yk = padded[slot]
        w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(xt.dtype)
        y = jnp.sum((yk * w[:, None]).reshape(T_loc, top_k, D), axis=1)
        return y, aux

    xt = x.reshape(-1, D)
    tok_spec = P(batch_axes, None)
    in_specs = (tok_spec, P(None, None), P(ep_axis, None, tp_axis),
                P(ep_axis, None, tp_axis), P(ep_axis, tp_axis, None))
    out_specs = (tok_spec, P())

    y, aux = jax.shard_map(
        body, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(xt, p["router"],
                         p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        from repro.models.layers import ffn_apply
        y = y + ffn_apply(act, p["shared"], xt)
    return y.reshape(orig_shape), aux

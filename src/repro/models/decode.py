"""KV-cache / recurrent-state decode path (serve_step).

The cache mirrors the pattern-period layout of the parameters: one entry per
pattern position with leaves stacked over ``n_periods``, so decode is the same
``lax.scan`` as training and HLO stays O(pattern).  Cache kinds per mixer:

  attn  : k/v ring buffers — full layers allocate ``seq_len`` slots, sliding-
          window layers allocate only ``window`` slots (this is what makes
          long_500k feasible for SWA/hybrid archs);
  mamba : (conv_state, ssm_state) — O(1) in sequence length;
  rwkv  : (tm_x, cm_x, wkv) — O(1) in sequence length;
  cross : precomputed encoder K/V (whisper), written once at cache init.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import rwkv as rk
from repro.models.layers import apply_rope, norm, rms_norm
from repro.models.moe import moe_apply, moe_capacity
from repro.models.layers import ffn_apply
from repro.models.transformer import ArchConfig, LayerSpec, encode, unembed

Pytree = Any


def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, seq_len: int,
                 lead: tuple[int, ...], enc_frames: int = 0) -> dict:
    dt = cfg.dtype
    if spec.mixer == "attn":
        s_c = min(spec.window, seq_len) if spec.window > 0 else seq_len
        c = {"k": jnp.zeros(lead + (batch, s_c, cfg.n_kv_heads, cfg.head_dim), dt),
             "v": jnp.zeros(lead + (batch, s_c, cfg.n_kv_heads, cfg.head_dim), dt)}
        if spec.cross_attn:
            c["kc"] = jnp.zeros(lead + (batch, enc_frames, cfg.n_kv_heads, cfg.head_dim), dt)
            c["vc"] = jnp.zeros(lead + (batch, enc_frames, cfg.n_kv_heads, cfg.head_dim), dt)
        return c
    if spec.mixer == "mamba":
        return {"conv": jnp.zeros(lead + (batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dt),
                "ssm": jnp.zeros(lead + (batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                                 jnp.float32)}
    if spec.mixer == "rwkv":
        return {"tm_x": jnp.zeros(lead + (batch, cfg.d_model), dt),
                "cm_x": jnp.zeros(lead + (batch, cfg.d_model), dt),
                "wkv": jnp.zeros(lead + (batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                                         cfg.rwkv_head_dim), jnp.float32)}
    raise ValueError(spec.mixer)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> Pytree:
    """Abstract-friendly zero cache (use inside jit / eval_shape)."""
    enc_frames = cfg.encoder.n_frames if cfg.encoder is not None else 0
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.n_periods > 0:
        cache["layers"] = [
            _layer_cache(cfg, spec, batch, seq_len, (cfg.n_periods,), enc_frames)
            for spec in cfg.pattern]
    cache["rem"] = [
        _layer_cache(cfg, spec, batch, seq_len, (), enc_frames)
        for spec in cfg.remainder]
    return cache


def warm_cache(cfg: ArchConfig, params: Pytree, cache: Pytree,
               enc_embeds: jax.Array | None = None, pos: jax.Array | int = 0
               ) -> Pytree:
    """Fill cross-attention K/V from the encoder output and set the decode
    position (e.g. after an external prefill)."""
    cache = dict(cache)
    cache["pos"] = jnp.asarray(pos, jnp.int32)
    if cfg.encoder is not None and enc_embeds is not None:
        enc_out = encode(cfg, params, enc_embeds)
        B, Se = enc_out.shape[:2]

        def fill(layer_params, entry, lead_idx=None):
            kc = (enc_out @ layer_params["kc"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
            vc = (enc_out @ layer_params["vc"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
            entry = dict(entry)
            entry["kc"], entry["vc"] = kc, vc
            return entry

        if cfg.n_periods > 0:
            for i, spec in enumerate(cfg.pattern):
                if spec.cross_attn:
                    lp = cache["layers"][i]
                    per = [fill(jax.tree.map(lambda x, j=j: x[j], params["layers"][i]),
                                jax.tree.map(lambda x, j=j: x[j], lp))
                           for j in range(cfg.n_periods)]
                    cache["layers"][i] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        for i, spec in enumerate(cfg.remainder):
            if spec.cross_attn:
                cache["rem"][i] = fill(params["rem_layers"][i], cache["rem"][i])
    return cache


# --------------------------------------------------------------------------- #
# Single-token layer application
# --------------------------------------------------------------------------- #

def _attn_decode(cfg: ArchConfig, spec: LayerSpec, p: dict, c: dict,
                 h: jax.Array, pos: jax.Array) -> tuple[jax.Array, dict]:
    B = h.shape[0]
    x = norm(cfg.norm, h, p["norm1"])
    q = (x @ p["q"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = (x @ p["k"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["v"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"])
    if spec.rope:
        pid = jnp.broadcast_to(pos[None, None], (B, 1))
        q = apply_rope(q, pid, cfg.rope_theta)
        k = apply_rope(k, pid, cfg.rope_theta)

    s_c = c["k"].shape[1]
    slot = pos % s_c if spec.window > 0 else pos
    k_cache = jax.lax.dynamic_update_slice(c["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(c["v"], v, (0, slot, 0, 0))
    out = attn.attend_decode(q, k_cache, v_cache, pos, window=spec.window)
    h = h + out.reshape(B, 1, -1) @ p["o"]
    c = dict(c, k=k_cache, v=v_cache)

    if spec.cross_attn and "kc" in c:
        xc = norm(cfg.norm, h, p["norm_c"])
        qc = (xc @ p["qc"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        Se = c["kc"].shape[1]
        co = attn.attend_decode(qc, c["kc"], c["vc"], jnp.asarray(Se - 1, jnp.int32))
        h = h + co.reshape(B, 1, -1) @ p["oc"]
    return h, c


def _ffn_decode(cfg: ArchConfig, spec: LayerSpec, p: dict, h: jax.Array) -> jax.Array:
    x = norm(cfg.norm, h, p["norm2"])
    if spec.moe:
        T = x.shape[0] * x.shape[1]
        cap = moe_capacity(T, cfg.moe_top_k, cfg.n_experts, cfg.capacity_factor)
        ep = "data" if cfg.sharding_mode == "ep_tp" else None
        y, _ = moe_apply(cfg.activation, p["moe"], x, top_k=cfg.moe_top_k,
                         capacity=cap, ep_axis=ep)
        return h + y
    return h + ffn_apply(cfg.activation, p["ffn"], x)


def _apply_layer_decode(cfg: ArchConfig, spec: LayerSpec, p: dict, c: dict,
                        h: jax.Array, pos: jax.Array) -> tuple[jax.Array, dict]:
    if spec.mixer == "attn":
        h, c = _attn_decode(cfg, spec, p, c, h, pos)
        return _ffn_decode(cfg, spec, p, h), c
    if spec.mixer == "mamba":
        x = norm(cfg.norm, h, p["norm1"])
        y, st = mb.mamba_decode(p["mamba"], x, {"conv": c["conv"], "ssm": c["ssm"]},
                                d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv,
                                dt_rank=cfg.mamba_dt_rank)
        h = h + y
        return _ffn_decode(cfg, spec, p, h), dict(c, **st)
    if spec.mixer == "rwkv":
        x = norm(cfg.norm, h, p["norm1"])
        y, tm_x, wkv = rk.time_mix_apply(
            p["time_mix"], x, c["tm_x"], c["wkv"],
            n_heads=cfg.rwkv_heads, head_dim=cfg.rwkv_head_dim)
        h = h + y
        x = norm(cfg.norm, h, p["norm2"])
        y, cm_x = rk.channel_mix_apply(p["channel_mix"], x, c["cm_x"])
        return h + y, dict(c, tm_x=tm_x, cm_x=cm_x, wkv=wkv)
    raise ValueError(spec.mixer)


def decode_step(cfg: ArchConfig, params: Pytree, cache: Pytree,
                token: jax.Array) -> tuple[jax.Array, Pytree]:
    """One decode step. token (B, 1) int32 -> (logits (B, 1, V), new cache)."""
    pos = cache["pos"]
    h = params["embed"].astype(cfg.dtype)[token]
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.abs_pos:
        from repro.models.layers import sinusoidal_at
        h = h + sinusoidal_at(pos[None, None], cfg.d_model).astype(h.dtype)

    new_cache: dict = {"pos": pos + 1, "rem": []}
    if cfg.n_periods > 0:
        def body(h, xs):
            period_params, period_cache = xs
            new_pc = []
            for i, spec in enumerate(cfg.pattern):
                h, ci = _apply_layer_decode(cfg, spec, period_params[i],
                                            period_cache[i], h, pos)
                new_pc.append(ci)
            return h, new_pc

        h, stacked_cache = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
        new_cache["layers"] = stacked_cache
    for i, spec in enumerate(cfg.remainder):
        h, ci = _apply_layer_decode(cfg, spec, params["rem_layers"][i],
                                    cache["rem"][i], h, pos)
        new_cache["rem"].append(ci)

    h = norm(cfg.norm, h, params["final_norm"])
    logits = unembed(cfg, params, h)
    return logits, new_cache

"""RWKV6 ("Finch") mixer: linear-attention recurrence with **data-dependent
per-channel decay** (the architecture's headline feature, arXiv:2404.05892).

Time-mix:   r,k,v,g from token-shifted projections; decay
            w_t = exp(-exp(w0 + tanh(x̃ A_w) B_w)) ∈ (0,1) per channel;
            per-head state S (hd_k × hd_v):
                y_t = r_t · (S_{t-1} + (u ⊙ k_t) vᵀ_t)
                S_t = diag(w_t) S_{t-1} + k_t vᵀ_t
Channel-mix: token-shifted squared-ReLU MLP with sigmoid receptance gate
            (this *is* the FFN for RWKV layers — d_ff = 3.5·d_model = 8960).

Train path is a ``lax.scan`` over time (the chunked Pallas kernel is
repro.kernels.rwkv6_scan); decode is the single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


def rwkv_time_mix_init(key: jax.Array, d_model: int, n_heads: int, head_dim: int,
                       lora_rank: int, dtype) -> dict:
    ks = jax.random.split(key, 9)
    D = d_model
    return {
        "mu": 0.5 * jnp.ones((5, D), jnp.float32),          # shift mix for r,k,v,g,w
        "w_r": dense_init(ks[0], D, n_heads * head_dim, dtype),
        "w_k": dense_init(ks[1], D, n_heads * head_dim, dtype),
        "w_v": dense_init(ks[2], D, n_heads * head_dim, dtype),
        "w_g": dense_init(ks[3], D, n_heads * head_dim, dtype),
        "w0": jnp.full((n_heads * head_dim,), -6.0, jnp.float32),
        "w_lora_a": dense_init(ks[4], D, lora_rank, jnp.float32),
        "w_lora_b": dense_init(ks[5], lora_rank, n_heads * head_dim, jnp.float32),
        "u": (jax.random.normal(ks[6], (n_heads, head_dim), jnp.float32) * 0.1),
        "ln_scale": jnp.zeros((n_heads * head_dim,), dtype),
        "w_o": dense_init(ks[7], n_heads * head_dim, D, dtype),
    }


def rwkv_channel_mix_init(key: jax.Array, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d_model), jnp.float32),     # shift mix for k, r
        "w_in": dense_init(ks[0], d_model, d_ff, dtype),
        "w_out": dense_init(ks[1], d_ff, d_model, dtype),
        "w_rec": dense_init(ks[2], d_model, d_model, dtype),
    }


def _shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """Token shift: prepend x_prev (B, D), drop last. x (B, S, D)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay in (0, 1): (B, S, D) -> (B, S, D) fp32."""
    lo = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(p["w0"] + lo))


def time_mix_apply(p: dict, x: jax.Array, x_prev: jax.Array, wkv_state: jax.Array,
                   *, n_heads: int, head_dim: int
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (B,S,D) -> (y, new_x_prev (B,D), new_wkv_state (B,H,hd,hd))."""
    B, S, D = x.shape
    xs = _shift(x, x_prev)
    mix = lambda i: x + (xs - x) * p["mu"][i][None, None].astype(x.dtype)
    xr, xk, xv, xg, xw = (mix(i) for i in range(5))

    H, hd = n_heads, head_dim
    r = (xr @ p["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = xg @ p["w_g"]
    w = _decay(p, xw).reshape(B, S, H, hd)                    # (B,S,H,hd)

    def step(state, t_in):
        r_t, k_t, v_t, w_t = t_in                             # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hdk,hdv)
        y = jnp.einsum("bhk,bhkv->bhv",
                       r_t, state + p["u"][None, :, :, None] * kv)
        state = state * w_t[..., :, None] + kv
        return state, y

    seq = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    new_state, ys = jax.lax.scan(step, wkv_state, seq)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, H * hd)        # (B,S,D')
    y = rms_norm(y.astype(x.dtype), p["ln_scale"])
    y = y * jax.nn.silu(g)
    return y @ p["w_o"], x[:, -1], new_state


def channel_mix_apply(p: dict, x: jax.Array, x_prev: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    xs = _shift(x, x_prev)
    mix = lambda i: x + (xs - x) * p["mu"][i][None, None].astype(x.dtype)
    xk, xr = mix(0), mix(1)
    k = jax.nn.relu(xk @ p["w_in"])
    kv = (k * k) @ p["w_out"]
    r = jax.nn.sigmoid(xr @ p["w_rec"])
    return r * kv, x[:, -1]


def rwkv_init_state(batch: int, d_model: int, n_heads: int, head_dim: int,
                    dtype=jnp.float32) -> dict:
    return {
        "tm_x": jnp.zeros((batch, d_model), dtype),
        "cm_x": jnp.zeros((batch, d_model), dtype),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
    }

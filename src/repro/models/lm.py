"""Train / serve step builders — the units the launcher jits and shards."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.decode import decode_step, init_cache  # noqa: F401 (re-export)
from repro.models.transformer import ArchConfig, forward
from repro.optim import Optimizer

Pytree = Any
AUX_WEIGHT = 0.01  # MoE load-balance coefficient


def lm_loss(cfg: ArchConfig, params: Pytree, batch: dict) -> jax.Array:
    """Next-token cross-entropy (+ MoE aux).  ``batch`` carries ``labels`` and
    one of ``tokens`` / ``embeds`` (+ ``enc_embeds`` for enc-dec archs)."""
    logits, _, aux = forward(
        cfg, params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        enc_embeds=batch.get("enc_embeds"),
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + AUX_WEIGHT * aux


def make_train_step(cfg: ArchConfig, opt: Optimizer
                    ) -> Callable[[Pytree, Pytree, dict], tuple[jax.Array, Pytree, Pytree]]:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, opt_state = opt.update(params, grads, opt_state)
        return loss, params, opt_state

    return train_step


def make_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        return lm_loss(cfg, params, batch)

    return eval_step


def make_serve_step(cfg: ArchConfig):
    """serve_step(params, cache, token (B,1)) -> (logits (B,1,V), cache')."""

    def serve_step(params, cache, token):
        return decode_step(cfg, params, cache, token)

    return serve_step


def greedy_generate(cfg: ArchConfig, params: Pytree, prompt: jax.Array,
                    max_new: int, seq_len: int) -> jax.Array:
    """Host-loop greedy decoding used by the serving example (prompt (B, P))."""
    B, P = prompt.shape
    cache = init_cache(cfg, B, seq_len)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    tok = prompt[:, :1]
    out = [tok]
    logits = None
    for i in range(P + max_new - 1):
        logits, cache = step(params, cache, tok)
        if i + 1 < P:
            tok = prompt[:, i + 1:i + 2]
        else:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(prompt.dtype)
        out.append(tok)
    return jnp.concatenate(out, axis=1)

"""GQA attention: full (oracle), chunked online-softmax (train/prefill at long
seq — the XLA analogue of flash attention; the Pallas version lives in
repro.kernels.flash_attention), and single-token decode against a KV cache.

Shapes:  q (B, S, Hq, hd), k/v (B, S, Hkv, hd), Hq = G * Hkv.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int,
               dtype=jnp.float32) -> jax.Array:
    """(…, Sq, Sk) additive bias. window > 0 = sliding window (causal)."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok = ok & (d >= 0)
    if window > 0:
        ok = ok & (d < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def attend_full(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                window: int = 0, q_pos: jax.Array | None = None,
                k_pos: jax.Array | None = None) -> jax.Array:
    """Reference attention (materialises Sq×Sk scores)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if k_pos is None:
        k_pos = jnp.arange(k.shape[1])
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = scores + _mask_bias(q_pos, k_pos, causal, window)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_chunk: int = 512, k_chunk: int = 512,
                   skip_masked_chunks: bool = False) -> jax.Array:
    """Online-softmax attention, O(chunk²) live memory.

    Outer ``lax.scan`` over query blocks; inner loop over key blocks with
    running (max, sum, acc).  ``skip_masked_chunks`` (§Perf) removes the
    compute for fully-masked blocks of sliding-window layers with a
    **statically unrolled banded loop**: each q block visits only the
    ``(window+chunk-1)//chunk + 1`` kv blocks intersecting its band, indexed
    by Python constants.  Dynamic indexing (lax.cond / clipped gathers /
    dynamic fori bounds) was tried first and REFUTED — GSPMD reshards the
    attention einsums when block indices are traced values, blowing
    collectives up ~10× (EXPERIMENTS.md §Perf, iterations 2a/2b).  Pure
    causal layers keep the masked scan (their waste is only ~2×; the Pallas
    kernel skips them properly on TPU via pl.when).
    """
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // k_chunk
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0, (Sq, q_chunk, Sk, k_chunk)

    qg = q.reshape(B, nq, q_chunk, Hkv, G, hd)
    kg = k.reshape(B, nk, k_chunk, Hkv, hd)
    vg = v.reshape(B, nk, k_chunk, Hkv, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def compute(state, qb, q_pos, ki):
        m, s, acc = state
        k_pos = ki * k_chunk + jnp.arange(k_chunk)
        kb = kg[:, ki].astype(jnp.float32)
        vb = vg[:, ki].astype(jnp.float32)
        sc = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb)
        sc = sc + _mask_bias(q_pos, k_pos, causal, window)
        new_m = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - new_m[..., None])
        corr = jnp.exp(m - new_m)
        s2 = s * corr + jnp.sum(p, axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return new_m, s2, acc2

    def init_state():
        return (jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk), jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk, hd), jnp.float32))

    if skip_masked_chunks and window > 0:
        # Longformer/T5-local formulation: vectorise over ALL q blocks at
        # once and unroll a short static loop over the `band` block offsets,
        # pairing q block i with the statically-shifted k block i+off.  No
        # inner scan, no dynamic indexing — GSPMD sees `band` einsums with
        # the same contraction structure as full attention (resharding
        # happens once, not per block; see §Perf iterations 2a–2c).
        band = min(nk, (window + k_chunk - 1) // k_chunk + 1)

        def shifted(x, off):
            # shifted(x, off)[:, i] == x[:, i + off] (zero-padded)
            if off == 0:
                return x
            sh = -off
            pad = jnp.zeros_like(x[:, :sh])
            return jnp.concatenate([pad, x[:, :nk - sh]], axis=1)

        qa = qg.astype(jnp.float32) * scale                   # (B,nq,qc,Hkv,G,hd)
        q_pos = (jnp.arange(nq) * q_chunk)[:, None] + jnp.arange(q_chunk)
        m = jnp.full((B, nq, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        s = jnp.zeros((B, nq, Hkv, G, q_chunk), jnp.float32)
        acc = jnp.zeros((B, nq, Hkv, G, q_chunk, hd), jnp.float32)
        for off in range(-(band - 1), 1):
            kb = shifted(kg, off).astype(jnp.float32)         # (B,nq,kc,Hkv,hd)
            vb = shifted(vg, off).astype(jnp.float32)
            k_pos = (jnp.arange(nq) + off)[:, None] * k_chunk + jnp.arange(k_chunk)
            sc = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qa, kb)
            d = q_pos[:, :, None] - k_pos[:, None, :]         # (nq, qc, kc)
            ok = (d >= 0) if causal else jnp.ones_like(d, bool)
            ok = ok & (d < window)
            ok = ok & (k_pos[:, None, :] >= 0)   # zero-pad blocks are not keys
            sc = sc + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]
            new_m = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - new_m[..., None])
            corr = jnp.exp(m - new_m)
            s = s * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bnhgqk,bnkhd->bnhgqd", p, vb)
            m = new_m
        out = acc / jnp.maximum(s, 1e-30)[..., None]          # (B,nq,Hkv,G,qc,hd)
        out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, Sq, Hq, hd)
        return out.astype(q.dtype)

    def q_block(carry, qi):
        qb = qg[:, qi].astype(jnp.float32) * scale          # (B, qc, Hkv, G, hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(state, ki):
            return compute(state, qb, q_pos, ki), None

        (m, s, acc), _ = jax.lax.scan(kv_step, init_state(), jnp.arange(nk))
        out = acc / jnp.maximum(s, 1e-30)[..., None]         # (B,Hkv,G,qc,hd)
        return carry, out.transpose(0, 3, 1, 2, 4)           # (B,qc,Hkv,G,hd)

    _, blocks = jax.lax.scan(q_block, (), jnp.arange(nq))    # (nq,B,qc,Hkv,G,hd)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def attend_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  pos: jax.Array, *, window: int = 0) -> jax.Array:
    """One-token decode. q (B, 1, Hq, hd); caches (B, S, Hkv, hd); ``pos`` is
    the index of the current token (cache slots > pos are invalid).

    For sliding-window layers the cache is a ring buffer of size ``window``;
    validity is by slot-age rather than absolute position.
    """
    B, _, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    slots = jnp.arange(S)
    if window > 0:
        valid = slots < jnp.minimum(pos + 1, S)   # ring buffer, all slots live once warm
    else:
        valid = slots <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)

"""Shared layer primitives: norms, activations, RoPE, projections.

Weight layouts are chosen for mesh sharding (see launch/sharding.py):
matmul weights are (in_features, out_features); fused-head projections keep
heads flattened into the feature dim so GQA head counts that do not divide the
model axis still shard cleanly on the fused dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(kind: str, dim: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def dense_init(key: jax.Array, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = (1.0 / in_dim) ** 0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# Activations / gated FFN
# --------------------------------------------------------------------------- #

def activation(kind: str, gate: jax.Array, up: jax.Array | None) -> jax.Array:
    """Gated activations take (gate, up); plain ones ignore ``up``."""
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    if kind == "gelu":
        return jax.nn.gelu(gate, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(gate)
        return r * r
    raise ValueError(f"unknown activation {kind}")


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


def ffn_apply(act: str, p: dict, x: jax.Array) -> jax.Array:
    """Dense FFN. Params: w_gate (D,F) [+ w_up (D,F) if gated], w_down (F,D)."""
    gate = x @ p["w_gate"]
    up = x @ p["w_up"] if is_gated(act) else None
    h = activation(act, gate, up)
    return h @ p["w_down"]


def ffn_init(key: jax.Array, act: str, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_gate": dense_init(k1, d_model, d_ff, dtype),
         "w_down": dense_init(k3, d_ff, d_model, dtype)}
    if is_gated(act):
        p["w_up"] = dense_init(k2, d_model, d_ff, dtype)
    return p


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (n_pos, dim)."""
    return sinusoidal_at(jnp.arange(n_pos, dtype=jnp.float32), dim)


def sinusoidal_at(positions: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal embedding rows for arbitrary (possibly traced) positions."""
    pos = positions.astype(jnp.float32)[..., None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(dim // 2, dtype=jnp.float32)
                  / max(dim // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

"""Unified architecture machinery for all 10 assigned families.

Layers are described by a repeating **pattern** of :class:`LayerSpec`
(e.g. gemma3 = 5×local-SWA + 1×global; jamba = 7×mamba + 1×attn with MoE on
odd positions).  Parameters for each pattern position are stacked over the
``n_periods`` repeats so the forward pass is a single ``lax.scan`` over
periods — HLO size stays O(pattern length) regardless of depth, which keeps
512-device dry-run compiles tractable.  Layers left over when ``n_layers %
len(pattern) != 0`` are applied inline ("remainder" layers).

Every mixer (attn / mamba / rwkv) and FFN (dense / MoE) shares the same
residual skeleton; decode carries a per-position cache pytree through the
same scan.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import rwkv as rk
from repro.models.layers import (
    dense_init,
    ffn_apply,
    ffn_init,
    init_norm,
    norm,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.moe import moe_apply, moe_capacity, moe_init

Pytree = Any


@dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # attn | mamba | rwkv
    window: int = 0              # 0 = full attention, >0 = sliding window
    rope: bool = True
    moe: bool = False
    causal: bool = True
    cross_attn: bool = False     # decoder cross-attention (whisper)


@dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    n_heads: int
    d_ff: int
    n_frames: int = 1500         # whisper conv-frontend output length (stub)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    activation: str = "swiglu"
    norm: str = "rmsnorm"
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25
    # Mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # RWKV
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    # encoder-decoder / frontends
    encoder: EncoderConfig | None = None
    frontend: str = "tokens"     # tokens | audio_stub | vision_stub
    abs_pos: bool = False        # add sinusoidal absolute positions (whisper)
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    remat: bool = True
    sharding_mode: str = "tp"    # tp | fsdp_tp | ep_tp (expert-parallel MoE)
    swa_skip: bool = False       # skip fully-masked attention chunks (§Perf)
    # §Perf: pin attention activations to batch-sharded / model-replicated.
    # GQA head counts rarely divide the model axis, so auto-SPMD otherwise
    # contract-shards the score einsums and all-reduces GB-scale score
    # tensors inside the kv scan (measured 100×+ collective blow-up).
    # Set by the launcher (requires an active mesh); None = let GSPMD choose.
    attn_batch_axes: tuple | None = None
    vocab_pad_multiple: int = 2048  # Megatron-style padding so vocab shards evenly
    # provenance
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> tuple[LayerSpec, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return max(self.d_model // 16, 8)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def reduced(self, **overrides) -> "ArchConfig":
        """A small same-family variant for CPU smoke tests (≤2 pattern periods,
        d_model ≤ 512, ≤4 experts)."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_heads = max(self.n_heads // 8, 2)
        n_kv = max(min(self.n_kv_heads, n_heads), 1)
        changes = dict(
            n_layers=len(self.pattern) * min(self.n_periods, 1) or len(self.pattern),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            rwkv_head_dim=32,
            rwkv_lora_rank=16,
            param_dtype="float32",
            remat=False,
            vocab_pad_multiple=1,
        )
        if self.encoder is not None:
            changes["encoder"] = EncoderConfig(
                n_layers=2, n_heads=n_heads, d_ff=min(self.encoder.d_ff, 512),
                n_frames=16)
        # shrink sliding windows so short smoke sequences exercise the ring buffer
        changes["pattern"] = tuple(
            dataclasses.replace(s, window=min(s.window, 8)) if s.window else s
            for s in self.pattern)
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


# --------------------------------------------------------------------------- #
# Parameter construction
# --------------------------------------------------------------------------- #

def _init_layer(cfg: ArchConfig, spec: LayerSpec, key: jax.Array) -> dict:
    ks = jax.random.split(key, 12)
    dt = cfg.dtype
    D = cfg.d_model
    p: dict = {}
    if spec.mixer == "attn":
        p["norm1"] = init_norm(cfg.norm, D, dt)
        p["q"] = dense_init(ks[0], D, cfg.n_heads * cfg.head_dim, dt)
        p["k"] = dense_init(ks[1], D, cfg.n_kv_heads * cfg.head_dim, dt)
        p["v"] = dense_init(ks[2], D, cfg.n_kv_heads * cfg.head_dim, dt)
        p["o"] = dense_init(ks[3], cfg.n_heads * cfg.head_dim, D, dt)
        if cfg.qk_norm:
            p["q_norm"] = {"scale": jnp.zeros((cfg.head_dim,), dt)}
            p["k_norm"] = {"scale": jnp.zeros((cfg.head_dim,), dt)}
        if spec.cross_attn:
            p["norm_c"] = init_norm(cfg.norm, D, dt)
            p["qc"] = dense_init(ks[8], D, cfg.n_heads * cfg.head_dim, dt)
            p["kc"] = dense_init(ks[9], D, cfg.n_kv_heads * cfg.head_dim, dt)
            p["vc"] = dense_init(ks[10], D, cfg.n_kv_heads * cfg.head_dim, dt)
            p["oc"] = dense_init(ks[11], cfg.n_heads * cfg.head_dim, D, dt)
    elif spec.mixer == "mamba":
        p["norm1"] = init_norm(cfg.norm, D, dt)
        p["mamba"] = mb.mamba_init(ks[0], D, cfg.mamba_d_inner, cfg.mamba_d_state,
                                   cfg.mamba_d_conv, cfg.mamba_dt_rank, dt)
    elif spec.mixer == "rwkv":
        p["norm1"] = init_norm(cfg.norm, D, dt)
        p["time_mix"] = rk.rwkv_time_mix_init(
            ks[0], D, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.rwkv_lora_rank, dt)
        p["norm2"] = init_norm(cfg.norm, D, dt)
        p["channel_mix"] = rk.rwkv_channel_mix_init(ks[1], D, cfg.d_ff, dt)
        return p
    else:
        raise ValueError(spec.mixer)

    p["norm2"] = init_norm(cfg.norm, D, dt)
    if spec.moe:
        p["moe"] = moe_init(ks[4], cfg.activation, D, cfg.moe_d_ff or cfg.d_ff,
                            cfg.n_experts, dt, cfg.moe_shared_expert)
    else:
        p["ffn"] = ffn_init(ks[5], cfg.activation, D, cfg.d_ff, dt)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Pytree:
    keys = jax.random.split(key, 8)
    dt = cfg.dtype
    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dt)

    # pattern-period stacked layers
    def init_period(k):
        pk = jax.random.split(k, len(cfg.pattern))
        return [_init_layer(cfg, spec, pk[i]) for i, spec in enumerate(cfg.pattern)]

    period_keys = jax.random.split(keys[2], max(cfg.n_periods, 1))
    if cfg.n_periods > 0:
        stacked = jax.vmap(init_period)(period_keys)
        params["layers"] = stacked
    rem_keys = jax.random.split(keys[3], max(len(cfg.remainder), 1))
    params["rem_layers"] = [
        _init_layer(cfg, spec, rem_keys[i]) for i, spec in enumerate(cfg.remainder)]

    if cfg.encoder is not None:
        enc = cfg.encoder
        enc_spec = LayerSpec(mixer="attn", rope=False, causal=False)
        enc_cfg = dataclasses.replace(
            cfg, n_heads=enc.n_heads, n_kv_heads=enc.n_heads, d_ff=enc.d_ff,
            head_dim=cfg.d_model // enc.n_heads, qk_norm=False, activation="gelu",
            norm="layernorm")
        ek = jax.random.split(keys[4], enc.n_layers)
        params["encoder"] = {
            "layers": [_init_layer(enc_cfg, enc_spec, ek[i]) for i in range(enc.n_layers)],
            "final_norm": init_norm("layernorm", cfg.d_model, dt),
        }
    return params


def param_specs(cfg: ArchConfig) -> Pytree:
    """ShapeDtypeStruct tree (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# --------------------------------------------------------------------------- #
# Forward (train / prefill)
# --------------------------------------------------------------------------- #

def _constrain_attn(cfg: ArchConfig, *ts):
    """Pin (B, S, H, hd) tensors to batch-sharded/model-replicated (§Perf)."""
    if cfg.attn_batch_axes is None:
        return ts if len(ts) > 1 else ts[0]
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(cfg.attn_batch_axes), None, None, None)
    out = tuple(jax.lax.with_sharding_constraint(t, spec) for t in ts)
    return out if len(out) > 1 else out[0]


def _attn_sublayer(cfg: ArchConfig, spec: LayerSpec, p: dict, h: jax.Array,
                   pos_ids: jax.Array, enc_out: jax.Array | None) -> jax.Array:
    B, S, D = h.shape
    x = norm(cfg.norm, h, p["norm1"])
    q = (x @ p["q"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ p["k"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["v"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q, k, v = _constrain_attn(cfg, q, k, v)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"])
    if spec.rope:
        q = _rope(q, pos_ids, cfg.rope_theta)
        k = _rope(k, pos_ids, cfg.rope_theta)
    if S >= 2048:
        out = attn.attend_chunked(q, k, v, causal=spec.causal, window=spec.window,
                                  skip_masked_chunks=cfg.swa_skip)
    else:
        out = attn.attend_full(q, k, v, causal=spec.causal, window=spec.window,
                               q_pos=pos_ids[0], k_pos=pos_ids[0])
    out = _constrain_attn(cfg, out)
    h = h + out.reshape(B, S, -1) @ p["o"]

    if spec.cross_attn and enc_out is not None:
        xc = norm(cfg.norm, h, p["norm_c"])
        Se = enc_out.shape[1]
        qc = (xc @ p["qc"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        kc = (enc_out @ p["kc"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        vc = (enc_out @ p["vc"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        co = attn.attend_full(qc, kc, vc, causal=False)
        h = h + co.reshape(B, S, -1) @ p["oc"]
    return h


def _rope(x, pos_ids, theta):
    from repro.models.layers import apply_rope
    return apply_rope(x, pos_ids, theta)


def _ffn_sublayer(cfg: ArchConfig, spec: LayerSpec, p: dict, h: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    x = norm(cfg.norm, h, p["norm2"])
    if spec.moe:
        T = x.shape[0] * x.shape[1]
        cap = moe_capacity(T, cfg.moe_top_k, cfg.n_experts, cfg.capacity_factor)
        if cfg.sharding_mode == "ep_tp":
            from repro.models.layers import is_gated as _gated
            from repro.models.moe_sharded import ambient_mesh_shape, moe_apply_shard_map
            ms = ambient_mesh_shape()
            if (_gated(cfg.activation) and ms.get("data")
                    and cfg.n_experts % ms["data"] == 0):
                baxes = ("pod", "data") if "pod" in ms else ("data",)
                y, aux = moe_apply_shard_map(
                    cfg.activation, p["moe"], x, top_k=cfg.moe_top_k,
                    capacity=cap, batch_axes=baxes)
                return h + y, aux
        y, aux = moe_apply(cfg.activation, p["moe"], x,
                           top_k=cfg.moe_top_k, capacity=cap)
        return h + y, aux
    return h + ffn_apply(cfg.activation, p["ffn"], x), jnp.zeros((), jnp.float32)


def _apply_layer(cfg: ArchConfig, spec: LayerSpec, p: dict, h: jax.Array,
                 pos_ids: jax.Array, enc_out: jax.Array | None
                 ) -> tuple[jax.Array, jax.Array]:
    if spec.mixer == "attn":
        h = _attn_sublayer(cfg, spec, p, h, pos_ids, enc_out)
        return _ffn_sublayer(cfg, spec, p, h)
    if spec.mixer == "mamba":
        x = norm(cfg.norm, h, p["norm1"])
        h = h + mb.mamba_apply(p["mamba"], x, d_state=cfg.mamba_d_state,
                               d_conv=cfg.mamba_d_conv, dt_rank=cfg.mamba_dt_rank)
        return _ffn_sublayer(cfg, spec, p, h)
    if spec.mixer == "rwkv":
        B = h.shape[0]
        st = rk.rwkv_init_state(B, cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim,
                                h.dtype)
        x = norm(cfg.norm, h, p["norm1"])
        y, _, _ = rk.time_mix_apply(p["time_mix"], x, st["tm_x"], st["wkv"],
                                    n_heads=cfg.rwkv_heads, head_dim=cfg.rwkv_head_dim)
        h = h + y
        x = norm(cfg.norm, h, p["norm2"])
        y, _ = rk.channel_mix_apply(p["channel_mix"], x, st["cm_x"])
        return h + y, jnp.zeros((), jnp.float32)
    raise ValueError(spec.mixer)


def backbone(cfg: ArchConfig, params: Pytree, h: jax.Array,
             pos_ids: jax.Array, enc_out: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Apply all layers to hidden states h (B, S, D). Returns (h, moe_aux)."""

    def period_body(carry, period_params):
        h, aux = carry
        for i, spec in enumerate(cfg.pattern):
            h, a = _apply_layer(cfg, spec, period_params[i], h, pos_ids, enc_out)
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_periods > 0:
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["layers"])
    for i, spec in enumerate(cfg.remainder):
        h, a = _apply_layer(cfg, spec, params["rem_layers"][i], h, pos_ids, enc_out)
        aux = aux + a
    return h, aux


def encode(cfg: ArchConfig, params: Pytree, enc_embeds: jax.Array) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings (B, n_frames, D)."""
    assert cfg.encoder is not None
    enc = cfg.encoder
    pos = sinusoidal_positions(enc_embeds.shape[1], cfg.d_model).astype(enc_embeds.dtype)
    h = enc_embeds + pos[None]
    spec = LayerSpec(mixer="attn", rope=False, causal=False)
    enc_cfg = dataclasses.replace(
        cfg, n_heads=enc.n_heads, n_kv_heads=enc.n_heads, d_ff=enc.d_ff,
        head_dim=cfg.d_model // enc.n_heads, qk_norm=False, activation="gelu",
        norm="layernorm")
    pos_ids = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])
    for lp in params["encoder"]["layers"]:
        h = _attn_sublayer(enc_cfg, spec, lp, h, pos_ids, None)
        h, _ = _ffn_sublayer(enc_cfg, spec, lp, h)
    return norm("layernorm", h, params["encoder"]["final_norm"])


def forward(cfg: ArchConfig, params: Pytree, tokens: jax.Array | None = None,
            embeds: jax.Array | None = None, enc_embeds: jax.Array | None = None
            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full forward: returns (logits (B,S,V), final hidden (B,S,D), moe_aux)."""
    if embeds is None:
        assert tokens is not None
        h = params["embed"].astype(cfg.dtype)[tokens]
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    else:
        h = embeds.astype(cfg.dtype)
    B, S = h.shape[:2]
    pos_ids = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.abs_pos:
        h = h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)[None]
    enc_out = None
    if cfg.encoder is not None and enc_embeds is not None:
        enc_out = encode(cfg, params, enc_embeds)
    h, aux = backbone(cfg, params, h, pos_ids, enc_out)
    h = norm(cfg.norm, h, params["final_norm"])
    logits = unembed(cfg, params, h)
    return logits, h, aux


def unembed(cfg: ArchConfig, params: Pytree, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        logits = h @ params["lm_head"]
    if cfg.padded_vocab != cfg.vocab_size:
        # mask Megatron-style vocab padding so it never receives probability
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits

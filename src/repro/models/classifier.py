"""Small classifier used for the paper's FL experiments (CIFAR-scale stand-in).

Explicitly split into a *representation layer* and a *decision layer*
(paper §III-B): ``embed`` returns the penultimate representation — exactly the
vector PAA prototypes are built from; ``apply`` adds the decision head.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 64
    hidden: tuple[int, ...] = (128, 128)
    rep_dim: int = 64         # representation (prototype) dimension
    num_classes: int = 10


def init_mlp(cfg: MLPConfig, key: jax.Array) -> Pytree:
    dims = (cfg.in_dim, *cfg.hidden, cfg.rep_dim)
    params = {}
    keys = jax.random.split(key, len(dims))
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(keys[i], (a, b), jnp.float32) * (2.0 / a) ** 0.5
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    params["w_head"] = jax.random.normal(keys[-1], (cfg.rep_dim, cfg.num_classes),
                                         jnp.float32) * (1.0 / cfg.rep_dim) ** 0.5
    params["b_head"] = jnp.zeros((cfg.num_classes,), jnp.float32)
    return params


def embed(cfg: MLPConfig, params: Pytree, x: jax.Array) -> jax.Array:
    """Representation layer: (B, in_dim) -> (B, rep_dim)."""
    h = x
    n_hidden = len(cfg.hidden) + 1
    for i in range(n_hidden):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_hidden - 1:
            h = jax.nn.relu(h)
    return jnp.tanh(h)   # bounded reps keep Pearson well-conditioned


def apply(cfg: MLPConfig, params: Pytree, x: jax.Array) -> jax.Array:
    """Full model: (B, in_dim) -> (B, num_classes) logits."""
    return embed(cfg, params, x) @ params["w_head"] + params["b_head"]


def init_stacked(cfg: MLPConfig, key: jax.Array, n_clients: int,
                 same_init: bool = True) -> Pytree:
    """Stacked client params.  FL convention: all clients start from the same
    initialisation (``same_init=True``, as in FedAvg)."""
    if same_init:
        p = init_mlp(cfg, key)
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape).copy(), p)
    keys = jax.random.split(key, n_clients)
    return jax.vmap(lambda k: init_mlp(cfg, k))(keys)

"""Small classifier used for the paper's FL experiments (CIFAR-scale stand-in).

Explicitly split into a *representation layer* and a *decision layer*
(paper §III-B): ``embed`` returns the penultimate representation — exactly the
vector PAA prototypes are built from; ``apply`` adds the decision head.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 64
    hidden: tuple[int, ...] = (128, 128)
    rep_dim: int = 64         # representation (prototype) dimension
    num_classes: int = 10


def init_mlp(cfg: MLPConfig, key: jax.Array) -> Pytree:
    dims = (cfg.in_dim, *cfg.hidden, cfg.rep_dim)
    params = {}
    keys = jax.random.split(key, len(dims))
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(keys[i], (a, b), jnp.float32) * (2.0 / a) ** 0.5
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    params["w_head"] = jax.random.normal(keys[-1], (cfg.rep_dim, cfg.num_classes),
                                         jnp.float32) * (1.0 / cfg.rep_dim) ** 0.5
    params["b_head"] = jnp.zeros((cfg.num_classes,), jnp.float32)
    return params


def embed(cfg: MLPConfig, params: Pytree, x: jax.Array) -> jax.Array:
    """Representation layer: (B, in_dim) -> (B, rep_dim)."""
    h = x
    n_hidden = len(cfg.hidden) + 1
    for i in range(n_hidden):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_hidden - 1:
            h = jax.nn.relu(h)
    return jnp.tanh(h)   # bounded reps keep Pearson well-conditioned


def apply(cfg: MLPConfig, params: Pytree, x: jax.Array) -> jax.Array:
    """Full model: (B, in_dim) -> (B, num_classes) logits."""
    return embed(cfg, params, x) @ params["w_head"] + params["b_head"]


def embed_stacked(cfg: MLPConfig, stacked_params: Pytree, x: jax.Array) -> jax.Array:
    """All clients' representations on ONE shared probe/eval batch.

    ``vmap(embed)`` broadcasts the shared ``x`` into a batched dot whose lhs
    batch dim XLA CPU lowers poorly (~2.5× slower at 100-client cohorts).
    Here the first layer is a single width-concatenated GEMM over all
    clients (the shared batch stays the lhs); subsequent layers have
    per-client inputs, where the batched matmul lowers well.

    (B, in_dim) × stacked params -> (m, B, rep_dim).  Same math as
    ``jax.vmap(embed)`` up to float summation order.
    """
    n_hidden = len(cfg.hidden) + 1
    w0 = stacked_params["w0"]                       # (m, d0, d1)
    m, d0, d1 = w0.shape
    h = x @ jnp.transpose(w0, (1, 0, 2)).reshape(d0, m * d1)
    h = h.reshape(x.shape[0], m, d1).transpose(1, 0, 2)
    h = h + stacked_params["b0"][:, None, :]
    for i in range(1, n_hidden):
        h = jax.nn.relu(h)                          # activation between layers
        h = jnp.einsum("mbi,mij->mbj", h, stacked_params[f"w{i}"])
        h = h + stacked_params[f"b{i}"][:, None, :]
    return jnp.tanh(h)


def apply_stacked(cfg: MLPConfig, stacked_params: Pytree, x: jax.Array) -> jax.Array:
    """All clients' logits on one shared batch: (m, B, num_classes)."""
    reps = embed_stacked(cfg, stacked_params, x)
    logits = jnp.einsum("mbi,mij->mbj", reps, stacked_params["w_head"])
    return logits + stacked_params["b_head"][:, None, :]


def init_stacked(cfg: MLPConfig, key: jax.Array, n_clients: int,
                 same_init: bool = True) -> Pytree:
    """Stacked client params.  FL convention: all clients start from the same
    initialisation (``same_init=True``, as in FedAvg)."""
    if same_init:
        p = init_mlp(cfg, key)
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape).copy(), p)
    keys = jax.random.split(key, n_clients)
    return jax.vmap(lambda k: init_mlp(cfg, k))(keys)

"""Mixture-of-Experts with capacity-based scatter dispatch (GShard/Switch
lineage, adapted for TPU + GSPMD):

  * router: softmax top-k over E experts,
  * each token gets a slot in its expert's capacity-C buffer via a cumsum
    position (overflow tokens are *dropped* — their expert contribution is
    zero; the residual path keeps them sane),
  * dispatch is a scatter (memory op, not FLOPs) into an (E·C, D) buffer, so
    ``cost_analysis`` reports the *active* expert FLOPs E·C·D·F ≈ tokens·top_k
    ·cf·D·F — not the dense all-experts FLOPs a one-hot einsum would fake,
  * expert compute is a batched einsum (E, C, D) × (E, D, F), which shards
    F over the `model` mesh axis (tensor-parallel experts) and C over `data`
    (capacity-sharded slots).

``capacity`` must be chosen divisible by the data-axis size by the caller
(see ArchConfig.moe_capacity) so slot sharding is even.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation, dense_init, is_gated


def moe_init(key: jax.Array, act: str, d_model: int, d_ff: int, n_experts: int,
             dtype, shared_expert: bool = False) -> dict:
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], d_model, n_experts, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(keys[1], n_experts)),
        "w_down": jax.vmap(lambda k: dense_init(k, d_ff, d_model, dtype))(
            jax.random.split(keys[3], n_experts)),
    }
    if is_gated(act):
        p["w_up"] = jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(keys[2], n_experts))
    if shared_expert:
        from repro.models.layers import ffn_init
        p["shared"] = ffn_init(keys[4], act, d_model, d_ff, dtype)
    return p


def router_topk(logits: jax.Array, top_k: int) -> tuple[jax.Array, jax.Array]:
    """(T, E) -> gates (T, k) renormalised, idx (T, k)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(logits: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style aux loss: E · <fraction routed to e> · <mean router prob e>."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)                                   # (E,)
    onehot = jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(onehot, axis=0)
    return n_experts * jnp.sum(me * ce)


def moe_apply(act: str, p: dict, x: jax.Array, *, top_k: int,
              capacity: int, ep_axis: str | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """x (..., D) -> (y (..., D), aux_loss scalar).

    ``ep_axis``: mesh axis name for expert parallelism — the dispatch buffer
    is explicitly constrained to shard its expert dim over this axis, so the
    token scatter lowers to an all-to-all instead of GSPMD replicating the
    whole (E·C, D) buffer (measured 100× collective blow-up without the
    constraint — EXPERIMENTS.md §Perf).  Requires an active mesh (set_mesh).
    """
    orig_shape = x.shape
    D = x.shape[-1]
    xt = x.reshape(-1, D)
    T = xt.shape[0]
    E = p["router"].shape[1]
    C = capacity

    logits = xt.astype(jnp.float32) @ p["router"]                  # (T, E)
    gates, idx = router_topk(logits, top_k)                        # (T, k)
    aux = load_balance_loss(logits, idx, E)

    # position of each (token, choice) within its expert's buffer
    flat_e = idx.reshape(-1)                                       # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)               # exclusive cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)                # E*C = drop sentinel

    # scatter tokens to slots (memory movement, not FLOPs)
    xk = jnp.repeat(xt, top_k, axis=0)                             # (T*k, D)
    buf = jnp.zeros((E * C, D), x.dtype).at[slot].set(
        xk, mode="drop", unique_indices=True)
    buf = buf.reshape(E, C, D)
    if ep_axis is not None:
        from jax.sharding import PartitionSpec as P
        buf = jax.lax.with_sharding_constraint(buf, P(ep_axis, None, None))

    # expert FFN (tensor-parallel over F, capacity-sharded over C)
    gate_h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    up_h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"]) if is_gated(act) else None
    h = activation(act, gate_h, up_h)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if ep_axis is not None:
        from jax.sharding import PartitionSpec as P
        out = jax.lax.with_sharding_constraint(out, P(ep_axis, None, None))
    out = out.reshape(E * C, D)

    # gather back + weighted combine
    padded = jnp.concatenate([out, jnp.zeros((1, D), out.dtype)], axis=0)
    yk = padded[slot]                                              # (T*k, D)
    w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.sum((yk * w[:, None]).reshape(T, top_k, D), axis=1)

    if "shared" in p:
        from repro.models.layers import ffn_apply
        y = y + ffn_apply(act, p["shared"], xt)
    return y.reshape(orig_shape), aux


def moe_capacity(tokens: int, top_k: int, n_experts: int,
                 capacity_factor: float = 1.25, multiple: int = 128) -> int:
    """Slots per expert, rounded up to ``multiple`` (keeps the slot axis
    divisible by the data-axis size and MXU-aligned)."""
    raw = tokens * top_k * capacity_factor / n_experts
    return max(multiple, int(-(-raw // multiple)) * multiple)

"""Mamba (selective SSM) mixer for the Jamba hybrid architecture.

Faithful Mamba-1 block: in_proj → (x, z); causal depthwise conv; selective
(input-dependent) Δ, B, C; diagonal state-space scan; gated output.

The scan is ``lax.scan`` over time with state (B, d_inner, d_state) — the
recurrence is elementwise over d_inner, so sharding d_inner over the `model`
mesh axis makes the scan embarrassingly parallel across devices (no per-step
collectives).  Decode is the single-step recurrence against carried
(conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def mamba_init(key: jax.Array, d_model: int, d_inner: int, d_state: int,
               d_conv: int, dt_rank: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner), jnp.float32)
                   * (1.0 / d_conv) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
            (d_inner, d_state)).copy()),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }
    return p


def _selective_terms(p: dict, xc: jax.Array, d_state: int, dt_rank: int):
    """xc (B, S, d_inner) -> dt (B,S,d_inner), Bmat/Cmat (B,S,d_state)."""
    proj = xc @ p["x_proj"]
    dt_in, Bm, Cm = jnp.split(proj.astype(jnp.float32),
                              [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    return dt, Bm, Cm


def mamba_apply(p: dict, x: jax.Array, *, d_state: int, d_conv: int,
                dt_rank: int) -> jax.Array:
    """Train/prefill path. x (B, S, D) -> (B, S, D)."""
    B, S, _ = x.shape
    xz = x @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                          # (B, S, d_inner)
    d_inner = xr.shape[-1]

    # causal depthwise conv over time
    pad = jnp.pad(xr, ((0, 0), (d_conv - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + S, :] * p["conv_w"][i][None, None, :]
             for i in range(d_conv)) + p["conv_b"]
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _selective_terms(p, xc, d_state, dt_rank)
    A = -jnp.exp(p["A_log"])                                   # (d_inner, N)
    dA = jnp.exp(dt[..., None] * A[None, None])                # (B,S,d_inner,N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    def step(h, inputs):
        dA_t, dBx_t, C_t = inputs
        h = h * dA_t + dBx_t                                   # (B, d_inner, N)
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xc.astype(jnp.float32) * p["D"][None, None, :]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_init_state(batch: int, d_inner: int, d_state: int, d_conv: int,
                     dtype) -> dict:
    return {"conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
            "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32)}


def mamba_decode(p: dict, x: jax.Array, state: dict, *, d_state: int,
                 d_conv: int, dt_rank: int) -> tuple[jax.Array, dict]:
    """Single-token step. x (B, 1, D) -> (B, 1, D), new state."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                          # (B, d_inner)

    conv_buf = jnp.concatenate([state["conv"], xr[:, None]], axis=1)  # (B,d_conv,di)
    xc = jnp.einsum("bcd,cd->bd", conv_buf, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    new_conv = conv_buf[:, 1:]

    dt, Bm, Cm = _selective_terms(p, xc[:, None], d_state, dt_rank)
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A[None])                      # (B, d_inner, N)
    h = state["ssm"] * dA + (dt * xc.astype(jnp.float32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + xc.astype(jnp.float32) * p["D"][None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": new_conv, "ssm": h}

"""Model bank snapshots: trained population -> K verified cluster models.

BFLN's end product is K cluster-personalized models.  This module extracts
them from a finished run's (possibly sharded) parameter arena into a
fixed-shape ``(K, n_params)`` stacked **model bank**, fingerprints every
bank row with the Pallas digest kernel, and anchors the release on the
run's own blockchain:

  * :func:`snapshot` — one fixed-shape jitted program computes the masked
    per-cluster mean over client rows (cluster-c model = FedAvg of every
    client whose latest chain-recorded assignment is c) AND the bank's
    fingerprint residues; the arena is gathered to host first so the bank
    is bit-identical across mesh widths (replicate-before-reduce, the PR 7
    discipline);
  * :func:`publish_release` — mints a **release block**: one
    ``model_release`` tx per cluster plus the producer's sender-bound
    ``release_commit`` (`repro.blockchain.commit.RoundCommitments` keyed by
    cluster id), so each served model carries an O(log K) Merkle membership
    proof.  Training-round digests commit the *locally trained* params and
    never cover the aggregates — the release block is what puts the served
    artifacts on chain;
  * :func:`verify_bank` — the refuse-to-serve gate: recompute every bank
    row's fingerprint from the weights actually loaded and check it against
    the chain's **latest** release via `commit.verify_membership`.  Tampered
    weights, a tampered digest, a wrong cluster id, a wrong release round,
    and a stale root (bank from an older release than the chain head's) all
    raise :class:`ProvenanceError`.

Banks round-trip through one ``.npz`` file (:meth:`ModelBank.save` /
:func:`load_bank`); loading re-verifies against a chain when one is given.
"""
from __future__ import annotations

import functools
import json
from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.blockchain.chain import Block, Blockchain
from repro.blockchain.commit import (
    MODEL_RELEASE_KIND,
    RELEASE_COMMIT_KIND,
    MerkleProof,
    RoundCommitments,
    verify_membership,
)
from repro.blockchain.txpool import Transaction, TxPool
from repro.kernels.fingerprint import fingerprint_rows, format_digest
from repro.models import classifier as clf
from repro.obs import NULL_RECORDER
from repro.runtime.arena import ArenaLayout, bitcast_u32
from repro.utils.tree import tree_index

Pytree = Any


class ProvenanceError(RuntimeError):
    """A served model's chain provenance failed — refuse to load or serve."""


@dataclass(frozen=True)
class ModelRelease:
    """Per-cluster provenance record: the released digest and its Merkle
    membership proof under the release block's commitment root."""
    cluster_id: int
    digest: str
    proof: MerkleProof


@dataclass(frozen=True)
class ModelBank:
    """K cluster-personalized models as one fixed-shape stacked bank, plus
    the chain provenance that makes them servable."""
    mcfg: clf.MLPConfig
    layout: ArenaLayout
    data: jax.Array                       # (K, n_params) float32
    releases: tuple[ModelRelease, ...]    # one per cluster, id order
    root: str                             # release commitments' Merkle root
    round_idx: int                        # release round (past last training round)
    block_hash: str                       # hash of the release block

    @property
    def n_models(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_params(self) -> int:
        return int(self.data.shape[1])

    @property
    def nbytes(self) -> int:
        return int(self.data.size) * 4

    def model_pytree(self, cluster_id: int) -> Pytree:
        """Cluster ``cluster_id``'s model as a plain (unstacked) pytree."""
        return tree_index(self.layout.unflatten(self.data), cluster_id)

    def digests(self) -> list[str]:
        return [r.digest for r in self.releases]

    # ------------------------------------------------------------------ #
    # disk round-trip
    # ------------------------------------------------------------------ #

    def save(self, path: str) -> None:
        """One-file ``.npz``: bank matrix + JSON provenance/arch metadata."""
        meta = {
            "mcfg": {"in_dim": self.mcfg.in_dim,
                     "hidden": list(self.mcfg.hidden),
                     "rep_dim": self.mcfg.rep_dim,
                     "num_classes": self.mcfg.num_classes},
            "releases": [
                {"cluster_id": r.cluster_id, "digest": r.digest,
                 "proof": {"leaf": r.proof.leaf,
                           "path": [[sib, side] for sib, side in r.proof.path]}}
                for r in self.releases],
            "root": self.root,
            "round_idx": self.round_idx,
            "block_hash": self.block_hash,
        }
        with open(path, "wb") as f:
            np.savez(f, data=np.asarray(jax.device_get(self.data)),
                     meta=np.frombuffer(json.dumps(meta, sort_keys=True)
                                        .encode(), dtype=np.uint8))


def load_bank(path: str, chain: Blockchain | None = None, *,
              obs=NULL_RECORDER) -> ModelBank:
    """Load a saved bank; with ``chain`` given, refuse (raise
    :class:`ProvenanceError`) unless every model verifies against the
    chain's latest release."""
    with np.load(path) as z:
        data = jnp.asarray(z["data"])
        meta = json.loads(bytes(z["meta"].tobytes()).decode())
    mcfg = clf.MLPConfig(in_dim=int(meta["mcfg"]["in_dim"]),
                         hidden=tuple(meta["mcfg"]["hidden"]),
                         rep_dim=int(meta["mcfg"]["rep_dim"]),
                         num_classes=int(meta["mcfg"]["num_classes"]))
    releases = tuple(
        ModelRelease(int(r["cluster_id"]), str(r["digest"]),
                     MerkleProof(str(r["proof"]["leaf"]),
                                 tuple((str(s), str(side))
                                       for s, side in r["proof"]["path"])))
        for r in meta["releases"])
    # layout from an architecture template: ArenaLayout records only paths /
    # shapes / dtypes, so a 1-row init reproduces the training layout exactly
    template = clf.init_stacked(mcfg, jax.random.PRNGKey(0), 1)
    bank = ModelBank(mcfg=mcfg, layout=ArenaLayout.from_stacked(template),
                     data=data, releases=releases, root=str(meta["root"]),
                     round_idx=int(meta["round_idx"]),
                     block_hash=str(meta["block_hash"]))
    if chain is not None:
        verify_bank(bank, chain, obs=obs)
    return bank


# ---------------------------------------------------------------------- #
# extraction
# ---------------------------------------------------------------------- #

@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _extract_bank(rows: jax.Array, labels: jax.Array, valid: jax.Array, *,
                  n_clusters: int):
    """Fixed-shape bank extraction + fingerprinting in ONE program.

    ``rows`` (n, N) client params, ``labels`` (n,) last cluster assignment
    (-1 = never assigned), ``valid`` (n,) 1.0 for real client rows.  A
    cluster with no assigned clients falls back to the mean over all
    labeled clients, and — when nobody was ever labeled (e.g. async mode,
    where every client tracks the one global model) — to the mean over all
    valid rows.  Out-of-range labels vanish from ``one_hot``, so -1 rows
    never contribute to any cluster.
    """
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=rows.dtype)
    onehot = onehot * valid[:, None]
    counts = onehot.sum(axis=0)                             # (K,)
    sums = onehot.T @ rows                                  # (K, N)
    labeled = counts.sum()
    labeled_mean = sums.sum(axis=0) / jnp.maximum(labeled, 1.0)
    global_mean = ((rows * valid[:, None]).sum(axis=0)
                   / jnp.maximum(valid.sum(), 1.0))
    fallback = jnp.where(labeled > 0, labeled_mean, global_mean)
    bank = jnp.where((counts > 0)[:, None],
                     sums / jnp.maximum(counts, 1.0)[:, None],
                     fallback[None, :])
    return bank, fingerprint_rows(bitcast_u32(bank))


@jax.jit
def _fingerprint_bank(bank_rows: jax.Array) -> jax.Array:
    """Residues of the bank rows as loaded — the verification-side digest."""
    return fingerprint_rows(bitcast_u32(bank_rows))


def bank_digests(bank_rows: jax.Array, n_params: int) -> list[str]:
    """Recompute per-model digests from the actual bank weights."""
    residues = np.asarray(jax.device_get(_fingerprint_bank(bank_rows)))
    return [format_digest(residues[c], n_params)
            for c in range(bank_rows.shape[0])]


# ---------------------------------------------------------------------- #
# release block
# ---------------------------------------------------------------------- #

def publish_release(chain: Blockchain, pool: TxPool, digests: list[str], *,
                    producer: int | None = None,
                    obs=NULL_RECORDER) -> tuple[Block, RoundCommitments]:
    """Mint the release block: per-cluster ``model_release`` txs plus the
    producer's sender-bound ``release_commit`` (senders = cluster ids).

    The release round is ``head.round_idx + 1`` — strictly past every
    training round, so release leaves can never collide with (or replay
    into) a training round's commitments.  The producer defaults to the
    head block's packing producer.
    """
    round_idx = chain.head.round_idx + 1
    if producer is None:
        producer = chain.head.producer
    for cluster_id, digest in enumerate(digests):
        pool.submit(Transaction(MODEL_RELEASE_KIND, cluster_id, digest,
                                round_idx))
    rc = RoundCommitments(round_idx, tuple(enumerate(digests)))
    pool.submit(Transaction(RELEASE_COMMIT_KIND, producer, rc.to_payload(),
                            round_idx))
    block = chain.pack_block(round_idx, producer, pool)
    obs.inc("serve.releases")
    return block, rc


def latest_release(chain: Blockchain) -> tuple[Block, RoundCommitments] | None:
    """The newest block carrying a release commitment (first ``release_commit``
    from the block's own producer wins, mirroring ``verify_round``)."""
    for block in reversed(chain.blocks):
        for tx in block.transactions:
            if tx.kind == RELEASE_COMMIT_KIND and tx.sender == block.producer:
                return block, RoundCommitments.from_payload(block.round_idx,
                                                            tx.payload)
    return None


# ---------------------------------------------------------------------- #
# the refuse-to-serve gate
# ---------------------------------------------------------------------- #

def verify_bank(bank: ModelBank, chain: Blockchain, *,
                obs=NULL_RECORDER) -> None:
    """Every served model must prove provenance against the chain's LATEST
    release block; anything less raises :class:`ProvenanceError`.

    Checks, in order: a release exists; the bank points at the head release
    (stale banks refuse — a newer release supersedes them); roots and
    release rounds agree; and per model, the fingerprint recomputed from the
    weights *actually in the bank* matches the recorded digest AND its
    Merkle proof places (cluster, round, digest) under the on-chain root.
    """
    with obs.span("serve.verify", cat="serve") as sp:
        rel = latest_release(chain)
        if rel is None:
            raise ProvenanceError(
                "refusing to serve: the chain carries no model release — "
                "publish one with repro.serve.publish_release / snapshot()")
        block, rc = rel
        if block.block_hash() != bank.block_hash:
            raise ProvenanceError(
                f"refusing to serve: stale release — bank was released in "
                f"block {bank.block_hash[:12]} (round {bank.round_idx}) but "
                f"the chain's latest release is block "
                f"{block.block_hash()[:12]} (round {block.round_idx})")
        if rc.root != bank.root:
            raise ProvenanceError(
                "refusing to serve: bank's commitment root does not match "
                "the release block's agg record")
        if block.round_idx != bank.round_idx:
            raise ProvenanceError(
                "refusing to serve: bank's release round does not match the "
                "release block")
        digests = bank_digests(bank.data, bank.n_params)
        for c, digest in enumerate(digests):
            r = bank.releases[c]
            if r.cluster_id != c or r.digest != digest:
                raise ProvenanceError(
                    f"refusing to serve model {c}: loaded weights fingerprint "
                    f"to {digest[:12]} but the release records "
                    f"{r.digest[:12]} for cluster {r.cluster_id}")
            if not verify_membership(rc.root, c, bank.round_idx, digest,
                                     r.proof):
                raise ProvenanceError(
                    f"refusing to serve model {c}: Merkle membership proof "
                    f"does not place (cluster={c}, round={bank.round_idx}, "
                    f"digest={digest[:12]}) under the release root")
        sp.set(n_models=bank.n_models, block=block.index)
    obs.inc("serve.verifications")


# ---------------------------------------------------------------------- #
# snapshot: finished run -> verified bank
# ---------------------------------------------------------------------- #

def snapshot(source, *, publish: bool = True, verify: bool = True,
             obs=NULL_RECORDER) -> ModelBank:
    """Extract the K cluster-personalized models from a finished run.

    ``source`` is an ``ExperimentResult`` (from ``repro.api.run``) or the
    underlying ``SimulatedFederation``.  The arena — sharded or not — is
    gathered to host and the extraction runs replicated on the default
    device, so the bank bytes are identical across mesh widths.  With
    ``publish`` the bank's digests are minted into a release block on the
    run's own chain; with ``verify`` the freshly built bank must pass
    :func:`verify_bank` before it is returned.
    """
    sim = getattr(source, "sim", source)
    if sim is None or not hasattr(sim, "trainer"):
        raise ValueError(
            "snapshot() needs a finished run: pass the ExperimentResult "
            "returned by repro.api.run(spec) (or the SimulatedFederation)")
    with obs.span("serve.snapshot", cat="serve") as sp:
        n = sim.pop.n_clients
        n_clusters = sim.cfg.n_clusters
        if sim.arena is not None:
            layout = sim.arena.layout
            rows = np.asarray(jax.device_get(sim.arena.data))[:n]
        else:
            layout = ArenaLayout.from_stacked(sim.params)
            rows = np.asarray(jax.device_get(layout.flatten(sim.params)))
        labels = np.asarray(sim.last_labels, dtype=np.int64)
        data, residues = _extract_bank(
            jnp.asarray(rows), jnp.asarray(labels),
            jnp.ones((n,), jnp.float32), n_clusters=n_clusters)
        residues = np.asarray(jax.device_get(residues))
        digests = [format_digest(residues[c], layout.n_params)
                   for c in range(n_clusters)]
        sp.set(n_models=n_clusters, n_params=layout.n_params)

    chain = sim.trainer.chain
    if publish:
        block, rc = publish_release(chain, sim.trainer.pool, digests, obs=obs)
    else:
        rel = latest_release(chain)
        if rel is None:
            # no release on chain: return an unanchored bank — verify_bank /
            # ServingEngine will refuse it, which is the point of the gate
            rc = RoundCommitments(chain.head.round_idx + 1,
                                  tuple(enumerate(digests)))
            bank = ModelBank(
                mcfg=sim.mcfg, layout=layout, data=data,
                releases=tuple(ModelRelease(c, d, rc.proof(c))
                               for c, d in enumerate(digests)),
                root=rc.root, round_idx=rc.round_idx, block_hash="")
            if verify:
                verify_bank(bank, chain, obs=obs)
            return bank
        block, rc = rel
    bank = ModelBank(
        mcfg=sim.mcfg, layout=layout, data=data,
        releases=tuple(ModelRelease(c, d, rc.proof(c))
                       for c, d in enumerate(digests)),
        root=rc.root, round_idx=block.round_idx,
        block_hash=block.block_hash())
    if verify:
        verify_bank(bank, chain, obs=obs)
    return bank


def tampered(bank: ModelBank, cluster_id: int, scale: float = 1.0001
             ) -> ModelBank:
    """A copy of ``bank`` with one model's weights perturbed — the
    adversarial fixture for refuse-to-serve tests and demos."""
    data = bank.data.at[cluster_id].multiply(scale)
    return replace(bank, data=data)

"""`repro.serve` — chain-verified personalized serving tier.

Turns a finished ``repro.api.run(spec)`` into a serving stack for BFLN's
end product, the K cluster-personalized models:

    result = api.run(spec)
    frontend = serve(result)               # snapshot -> release -> verify
    rid = frontend.submit(cluster_id=2, x=features)
    frontend.drain()
    [done] = frontend.take_completed()

Pieces (importable individually): :func:`snapshot` extracts the fixed-shape
model bank from the (possibly sharded) arena, fingerprints it, and mints a
release block; :class:`ServingEngine` answers mixed-cluster batches in one
jitted dispatch after :func:`verify_bank`'s refuse-to-serve provenance
gate; :class:`ServeFrontend` adds deterministic size-bucketed micro-batching
on an injected clock.  ``serve.*`` spans/counters flow through the flight
recorder (`docs/TRACE_SCHEMA.md`).
"""
from repro.serve.engine import ServingEngine  # noqa: F401
from repro.serve.frontend import (  # noqa: F401
    Completion,
    ServeConfig,
    ServeFrontend,
)
from repro.serve.snapshot import (  # noqa: F401
    ModelBank,
    ModelRelease,
    ProvenanceError,
    bank_digests,
    latest_release,
    load_bank,
    publish_release,
    snapshot,
    tampered,
    verify_bank,
)


def serve(source, *, config: ServeConfig | None = None, clock=None,
          obs=None) -> ServeFrontend:
    """One call from a finished run to a verified serving frontend.

    Snapshot the run's population into a model bank, publish its release
    block, verify every model's provenance against the chain head, and wire
    the batched engine behind a frontend driven by the run's own virtual
    clock (override with ``clock``; pass ``time.perf_counter`` for wall-time
    serving).
    """
    sim = getattr(source, "sim", source)
    if obs is None:
        obs = getattr(sim, "obs", None)
        from repro.obs import NULL_RECORDER
        if obs is None:
            obs = NULL_RECORDER
    bank = snapshot(source, obs=obs)
    engine = ServingEngine(bank, sim.trainer.chain, obs=obs)
    return ServeFrontend(engine, config or ServeConfig(),
                         clock=clock if clock is not None else sim.clock,
                         obs=obs)

"""Batched multi-model serving engine: one dispatch answers a mixed batch.

The forward extends the ``classifier.apply_stacked`` width-concat idiom
(the first layer of all K cluster models runs as ONE GEMM) and routes a
mixed batch — each request bound for a different cluster model — with a
per-request gather over the ``(K, B, C)`` stacked logits.  Engine
disciplines mirror ``core/engine.py``:

  * **fixed shape, one compile per batch shape**: the jitted entry is
    traced once per distinct ``B`` (the frontend's size buckets), audited
    via :meth:`ServingEngine.cache_sizes` exactly like the round engine;
  * **donation stated**: ``donate_argnums=()`` on purpose — the bank is the
    persistent serving state reused by every call (donating it would
    invalidate the loaded models after one batch), and the per-request
    buffers are O(B·D) next to the (K, N) bank, with donation a no-op for
    them on CPU anyway;
  * **replayable**: no clocks, no RNG, no host round-trips inside the
    entry; identical requests produce bit-identical logits, and each
    request's output is independent of how the rest of the batch routes
    (the gather touches only that request's row);
  * **provenance-gated**: construction runs :func:`verify_bank` against the
    chain — a bank that fails the refuse-to-serve gate never serves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import classifier as clf
from repro.obs import NULL_RECORDER
from repro.serve.snapshot import ModelBank, ProvenanceError, verify_bank


class ServingEngine:
    """Chain-verified multi-model forward over a :class:`ModelBank`.

    ``chain`` is required unless ``verify=False`` (reserved for analysis
    probes and oracle paths that state why they skip the gate).
    """

    def __init__(self, bank: ModelBank, chain=None, *, verify: bool = True,
                 obs=NULL_RECORDER):
        if verify:
            if chain is None:
                raise ProvenanceError(
                    "refusing to serve: ServingEngine needs the chain to "
                    "verify the bank's release (pass verify=False only for "
                    "non-serving probes)")
            verify_bank(bank, chain, obs=obs)
        self.bank = bank
        self.obs = obs
        mcfg = bank.mcfg
        layout = bank.layout

        def _forward(bank_rows, x, cids):
            # stacked width-concat forward over all K models, then each
            # request gathers its routed model's row — mixed batch, ONE
            # dispatch.  donation stated: donate_argnums=() (see module doc).
            models = layout.unflatten(bank_rows)
            logits = clf.apply_stacked(mcfg, models, x)      # (K, B, C)
            return logits[cids, jnp.arange(x.shape[0])]      # (B, C)

        self._entries = {"forward": jax.jit(_forward, donate_argnums=())}
        obs.set_gauge("serve.bank_bytes", bank.nbytes)

    # ------------------------------------------------------------------ #

    def forward(self, x, cids) -> jax.Array:
        """Answer a mixed batch: ``x`` (B, in_dim) requests, ``cids`` (B,)
        cluster routing — returns (B, num_classes) logits."""
        with self.obs.span("serve.batch", cat="serve") as sp:
            out = self._entries["forward"](
                self.bank.data, jnp.asarray(x, jnp.float32),
                jnp.asarray(cids, jnp.int32))
            sp.set(batch=int(out.shape[0]))
        self.obs.inc("serve.batches")
        self.obs.compile_delta(self.cache_sizes())
        return out

    def forward_per_request(self, x, cids) -> jax.Array:
        """Reference path: route every request ALONE through its cluster
        model (one plain ``classifier.apply`` per request).  The bit-identity
        oracle for the fused mixed-batch dispatch — test/bench use only."""
        rows = [clf.apply(self.bank.mcfg, self.bank.model_pytree(int(c)),
                          jnp.asarray(x[i:i + 1], jnp.float32))[0]
                for i, c in enumerate(cids)]
        return jnp.stack(rows)

    def cache_sizes(self) -> dict[str, int]:
        """Compiles per entry — one per distinct batch shape served."""
        return {name: fn._cache_size()
                for name, fn in self._entries.items()}

    def entry_names(self) -> list[str]:
        return list(self._entries)

    def lower_entry(self, name: str, *args):
        """Lower an entry for the compiled-HLO audit (`repro.analysis`)."""
        return self._entries[name].lower(*args)

"""Deterministic serving frontend: routed queue -> size-bucketed batches.

A single FIFO request queue feeds the mixed-batch engine — cluster routing
happens *inside* each batch via the engine's cluster-id gather, so requests
for different personalized models share one dispatch.  Batching policy:

  * **size buckets**: a flush pads its requests up to the smallest
    configured bucket that fits, so the engine compiles once per bucket
    (`ServingEngine.cache_sizes` audits exactly that).  Padding rows are
    zero requests routed to cluster 0 whose outputs are dropped — the
    stacked forward is padding-neutral for the real rows;
  * **full-bucket flush**: whenever the queue reaches the largest bucket, a
    full batch flushes immediately (inside :meth:`submit`);
  * **max-wait deadline**: :meth:`pump` flushes a partial batch once the
    oldest pending request has waited ``max_wait`` clock units;
  * **graceful rejection**: a request arriving with ``max_pending`` already
    queued completes immediately with ``status="rejected"`` instead of
    growing the queue without bound.

Time is an injected clock — the sim's ``VirtualClock`` (or any ``now``
callable); the frontend itself never reads a wall clock, so a request
schedule replays bit-identically: same arrivals -> same flush boundaries,
same batch compositions, same logits.  Benches inject a wall clock to
measure real latency through the identical code path.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.obs import NULL_RECORDER
from repro.serve.engine import ServingEngine


@dataclass(frozen=True)
class ServeConfig:
    """Frontend batching policy."""
    buckets: tuple[int, ...] = (1, 2, 4, 8, 16, 32)  # padded batch shapes
    max_wait: float = 0.005        # clock units a request may wait queued
    max_pending: int = 1024        # queue depth before graceful rejection

    def __post_init__(self):
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError("buckets must be a sorted tuple of distinct sizes")
        if self.max_wait < 0 or self.max_pending < 1:
            raise ValueError("max_wait must be >= 0 and max_pending >= 1")


@dataclass(frozen=True)
class Completion:
    """One finished request: logits for served ones, None for rejected."""
    req_id: int
    cluster_id: int
    logits: np.ndarray | None
    t_arrival: float
    t_done: float
    status: str          # "ok" | "rejected"


@dataclass
class _Pending:
    req_id: int
    cluster_id: int
    x: np.ndarray
    t_arrival: float


@dataclass
class ServeFrontend:
    """Deterministic request queue in front of a :class:`ServingEngine`."""
    engine: ServingEngine
    config: ServeConfig = field(default_factory=ServeConfig)
    clock: object = None          # callable () -> float, or has a .now
    obs: object = NULL_RECORDER

    def __post_init__(self):
        c = self.clock
        if c is None:
            raise ValueError(
                "ServeFrontend needs a clock (the sim's VirtualClock, or any "
                "`now` callable) — it never reads wall time itself")
        self._now = c if callable(c) else (lambda: c.now)
        self._pending: list[_Pending] = []
        self._completed: list[Completion] = []
        self._next_id = 0
        self.n_requests = 0
        self.n_rejected = 0
        self.n_flushes = 0

    # ------------------------------------------------------------------ #

    def submit(self, cluster_id: int, x) -> int:
        """Queue one request for ``cluster_id``'s model; returns its id.

        An overloaded queue rejects immediately (a ``rejected`` completion,
        no engine work).  A queue reaching the largest bucket flushes a full
        batch before returning.
        """
        mcfg = self.engine.bank.mcfg
        x = np.asarray(x, dtype=np.float32).reshape(-1)
        if x.shape[0] != mcfg.in_dim:
            raise ValueError(f"request has {x.shape[0]} features, model "
                             f"expects {mcfg.in_dim}")
        if not 0 <= int(cluster_id) < self.engine.bank.n_models:
            raise ValueError(f"cluster_id {cluster_id} out of range "
                             f"[0, {self.engine.bank.n_models})")
        now = self._now()
        req_id = self._next_id
        self._next_id += 1
        self.n_requests += 1
        self.obs.inc("serve.requests")
        if len(self._pending) >= self.config.max_pending:
            self.n_rejected += 1
            self.obs.inc("serve.rejected")
            self._completed.append(Completion(
                req_id, int(cluster_id), None, now, now, "rejected"))
            return req_id
        self._pending.append(_Pending(req_id, int(cluster_id), x, now))
        while len(self._pending) >= self.config.buckets[-1]:
            self._flush(self.config.buckets[-1], "full")
        return req_id

    def pump(self) -> None:
        """Flush every batch whose oldest request hit the max-wait deadline
        (call after advancing the clock)."""
        now = self._now()
        while (self._pending
               and now - self._pending[0].t_arrival >= self.config.max_wait):
            self._flush(min(len(self._pending), self.config.buckets[-1]),
                        "deadline")

    def drain(self) -> None:
        """Flush everything still queued, deadline or not."""
        while self._pending:
            self._flush(min(len(self._pending), self.config.buckets[-1]),
                        "drain")

    def take_completed(self) -> list[Completion]:
        """All completions since the last take, in completion order."""
        out, self._completed = self._completed, []
        return out

    @property
    def queue_depth(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------------------ #

    def _bucket_for(self, n: int) -> int:
        for b in self.config.buckets:
            if b >= n:
                return b
        return self.config.buckets[-1]

    def _flush(self, n: int, reason: str) -> None:
        batch, self._pending = self._pending[:n], self._pending[n:]
        bucket = self._bucket_for(len(batch))
        mcfg = self.engine.bank.mcfg
        with self.obs.span("serve.flush", cat="serve") as sp:
            x = np.zeros((bucket, mcfg.in_dim), dtype=np.float32)
            cids = np.zeros((bucket,), dtype=np.int32)
            for i, r in enumerate(batch):
                x[i] = r.x
                cids[i] = r.cluster_id
            logits = np.asarray(jax.device_get(
                self.engine.forward(x, cids)))
            sp.set(n=len(batch), bucket=bucket, reason=reason)
        now = self._now()
        for i, r in enumerate(batch):
            self._completed.append(Completion(
                r.req_id, r.cluster_id, logits[i], r.t_arrival, now, "ok"))
            self.obs.observe("serve.latency", now - r.t_arrival)
        self.n_flushes += 1
        self.obs.observe("serve.batch_size", float(len(batch)))
        self.obs.set_gauge("serve.queue_depth", float(len(self._pending)))

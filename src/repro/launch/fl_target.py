"""Dry-run target for the paper's own technique at pod scale.

"Cluster-parallel federated aggregation": 64 federated clients each fine-tune
a ~100M-parameter MLP tower; one PAA round (prototype forward for every
client on the shared probe batch → Pearson matrix → spectral clustering →
cluster-masked parameter mean) runs as ONE pjit program on the production
mesh.  Clients ride the `data` axis, feature dims ride `model` — the paper's
20-client-on-one-server loop becomes a two-axis-parallel collective program.

The aggregation is the paper's star operation, so this target is the third
§Perf hillclimb subject.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.aggregation import paa_round


@dataclass(frozen=True)
class FLTargetConfig:
    n_clients: int = 64
    in_dim: int = 1024
    hidden: int = 8192
    rep_dim: int = 1024
    psi: int = 64            # probe batch size (paper's ψ)
    n_clusters: int = 8
    agg_method: str = "mix"  # "mix" (baseline) | "two_step" (§Perf)
    # ~ in·h + h·h + h·rep ≈ 84M params per client at the defaults


def init_client_params(cfg: FLTargetConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 3)
    s = lambda a, b, k: (jax.random.normal(k, (a, b), jnp.float32) * (1 / a) ** 0.5)
    return {"w0": s(cfg.in_dim, cfg.hidden, ks[0]),
            "w1": s(cfg.hidden, cfg.hidden, ks[1]),
            "w2": s(cfg.hidden, cfg.rep_dim, ks[2])}


def embed_fn(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["w0"])
    h = jax.nn.relu(h @ params["w1"])
    return jnp.tanh(h @ params["w2"])


def stacked_param_specs(cfg: FLTargetConfig):
    shape = jax.eval_shape(
        lambda: jax.vmap(lambda k: init_client_params(cfg, k))(
            jax.random.split(jax.random.PRNGKey(0), cfg.n_clients)))
    return shape


def stacked_param_pspecs(mesh) -> dict:
    """Clients over data, output features over model (matmul-friendly)."""
    daxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return {"w0": P(daxes, None, "model"),
            "w1": P(daxes, None, "model"),
            "w2": P(daxes, None, "model")}


def fl_round_step(cfg: FLTargetConfig, stacked_params: dict, probe: jax.Array):
    """One PAA aggregation round; returns (new params, labels, sizes)."""
    res = paa_round(functools.partial(embed_fn), stacked_params, probe,
                    cfg.n_clusters, agg_method=cfg.agg_method)
    return res.new_stacked_params, res.labels, res.cluster_sizes


def build(cfg: FLTargetConfig, mesh):
    """(jitted_fn, abstract_args) for launch/dryrun.py."""
    pshape = stacked_param_specs(cfg)
    pspec = stacked_param_pspecs(mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    probe = jax.ShapeDtypeStruct((cfg.psi, cfg.in_dim), jnp.float32)
    probe_sh = NamedSharding(mesh, P(None, None))
    daxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    out_sh = (psh, NamedSharding(mesh, P(daxes)), NamedSharding(mesh, P()))
    jitted = jax.jit(functools.partial(fl_round_step, cfg),
                     in_shardings=(psh, probe_sh),
                     out_shardings=out_sh)
    return jitted, (pshape, probe)

"""Pre-jax process bootstrap helpers.

This module must never import jax (directly or transitively): its whole
point is to adjust ``XLA_FLAGS`` *before* jax initialises the platform —
scripts call :func:`force_host_device_count` ahead of their first repro /
jax import (see ``benchmarks/round_bench.py`` and
``examples/simulate_population.py``).
"""
from __future__ import annotations

import os
import sys

_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> None:
    """Ensure ``XLA_FLAGS`` forces ``n`` CPU host devices, re-execing the
    current script once if the flag had to be added or changed.

    No-op when ``n <= 1`` or the flag already requests exactly ``n`` (the
    re-exec'd process lands here again and falls through).  An existing
    forced count with a different value is replaced, not shadowed.
    """
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "").split()
    want = f"{_FLAG}={n}"
    if want in flags:
        return
    flags = [f for f in flags if not f.startswith(_FLAG + "=")]
    os.environ["XLA_FLAGS"] = " ".join(flags + [want])
    os.execv(sys.executable, [sys.executable, sys.argv[0], *sys.argv[1:]])

"""Parameter / activation sharding rules (GSPMD PartitionSpecs).

Megatron-style 1D tensor parallelism over the ``model`` axis, optionally
combined with FSDP-style sharding of the complementary weight dim over the
``data`` axis (``sharding_mode="fsdp_tp"`` — required for the ≥300B configs
so params + Adam state fit 16 GB/chip).

Rules are name-based over the parameter tree's key paths, with an automatic
divisibility guard: a proposed axis is dropped if the dim is not divisible by
the mesh axis size (e.g. whisper's 51866 vocab over 16-way model axis), so
every assigned architecture lowers without bespoke cases.  Stacked layer
params (leading ``n_periods`` axis) get their spec shifted by one dim.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any

# fused-projection inputs -> shard OUT features on `model`
_IN_KEYS = {"q", "k", "v", "qc", "kc", "vc", "w_gate", "w_up", "in_proj",
            "dt_proj", "w_in", "w_rec", "w_r", "w_k", "w_v", "w_g", "lm_head"}
# projections back to d_model -> shard IN features on `model`
_OUT_KEYS = {"o", "oc", "w_down", "out_proj", "w_o", "w_out", "x_proj", "A_log"}
# 1-D vectors laid out over the sharded feature dim
_VEC_KEYS = {"conv_b", "dt_bias", "D", "w0", "ln_scale"}
_REPLICATED = {"router", "mu", "u", "scale", "bias", "w_lora_a", "w_lora_b"}


def _leaf_key(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _is_stacked(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "layers"
               for e in path)


def _div_ok(dim: int, mesh, axis) -> bool:
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return dim % size == 0


def _guard(spec: tuple, shape: tuple[int, ...], mesh) -> P:
    """Drop any proposed axis the dim size can't honour."""
    fixed = tuple(a if _div_ok(shape[i], mesh, a) else None
                  for i, a in enumerate(spec))
    return P(*fixed)


def param_pspecs(cfg, params_shape: Pytree, mesh) -> Pytree:
    """PartitionSpec tree matching ``params_shape`` (ShapeDtypeStructs).

    Modes: ``tp`` (1D tensor parallel), ``fsdp_tp`` (+ FSDP over data),
    ``ep_tp`` (like fsdp_tp, but MoE expert tables shard the *expert* axis
    over `data` — expert parallelism — instead of FSDP'ing D; tokens move via
    all-to-all instead of all-gathering hundreds of GB of expert weights).
    """
    fsdp = "data" if cfg.sharding_mode in ("fsdp_tp", "ep_tp") else None
    ep = "data" if cfg.sharding_mode == "ep_tp" else None

    def rule(path, leaf) -> P:
        key = _leaf_key(path)
        shape = leaf.shape
        nd = len(shape)
        stacked = _is_stacked(path)
        core = shape[1:] if stacked else shape
        cnd = len(core)

        if key == "embed":
            spec: tuple = ("model", fsdp)
        elif key in _REPLICATED or cnd == 0:
            spec = (None,) * cnd
        elif key in _VEC_KEYS and cnd == 1:
            spec = ("model",)
        elif key == "conv_w":
            spec = (None, "model")
        elif key in _IN_KEYS and cnd == 2:
            spec = (fsdp, "model")
        elif key in _IN_KEYS and cnd == 3:        # MoE expert tables (E, D, F)
            spec = (ep, None, "model") if ep else (None, fsdp, "model")
        elif key in _OUT_KEYS and cnd == 2:
            spec = ("model", fsdp)
        elif key in _OUT_KEYS and cnd == 3:       # MoE (E, F, D)
            spec = (ep, "model", None) if ep else (None, "model", fsdp)
        elif cnd == 1:
            spec = (None,)
        else:
            spec = (None,) * cnd

        if stacked:
            spec = (None,) + tuple(spec)
        # optimizer scalars / odd ranks: pad or trim to leaf rank
        spec = tuple(spec)[:nd] + (None,) * max(0, nd - len(spec))
        return _guard(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# --------------------------------------------------------------------------- #
# federation cohort axis (1-D client mesh, repro.launch.mesh.make_client_mesh)
# --------------------------------------------------------------------------- #

def cohort_pspec(ndim: int = 1) -> P:
    """Leading-axis cohort sharding for ``(k_pad, ...)`` per-slot values —
    trailing dims replicate.  ``k_pad`` is the cohort padded to a shard
    multiple by the round engine."""
    from repro.launch.mesh import CLIENT_AXIS
    return P(CLIENT_AXIS, *(None,) * (ndim - 1))


def cohort_shardings(mesh) -> tuple[NamedSharding, NamedSharding]:
    """The (cohort-sharded, replicated) NamedSharding pair the round engine
    constrains with: per-slot tensors (params, batches, fingerprint inputs)
    pin to the first so each device computes only its cohort slice; combine
    inputs/outputs (prototypes, labels, scalars) pin to the second."""
    return NamedSharding(mesh, cohort_pspec()), NamedSharding(mesh, P())


def opt_state_pspecs(opt_shape: Pytree, param_specs_tree: Pytree) -> Pytree:
    """Optimizer moments (m / v / mu) inherit the parameter sharding; step
    counters replicate."""
    return {k: (P() if k == "step" else param_specs_tree) for k in opt_shape}


def named(mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh) -> P:
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return P(axes)


def cache_pspecs(cfg, cache_shape: Pytree, mesh, *, shard_batch: bool) -> Pytree:
    """Decode cache sharding.

    Batched decode: batch over the data axes, KV-cache *sequence* over the
    `model` axis (context-parallel decode — attention contracts over S, so
    per-layer collectives are only the tiny (B, Hq, hd) partial-sum
    all-reduce; sharding kv-heads instead would not divide GQA head counts
    like kv=8 over a 16-way axis and would replicate hundreds of GB).

    long_500k (batch=1): the sequence dim shards over *all* mesh axes
    (data+model context parallelism); recurrent states (mamba/rwkv) shard
    their channel dim over every axis instead — they are O(1) in S.
    """
    daxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    all_axes = daxes + ("model",)

    def rule(path, leaf):
        key = _leaf_key(path)
        shape = leaf.shape
        nd = len(shape)
        if key == "pos":
            return P()
        stacked = any(isinstance(e, jax.tree_util.DictKey) and e.key == "layers"
                      for e in path)
        b_ax = daxes if shard_batch else None
        if key in ("k", "v"):                       # (B, S, Hkv, hd)
            s_ax = "model" if shard_batch else all_axes
            spec = (b_ax, s_ax, None, None)
        elif key in ("kc", "vc"):                   # (B, 1500, Hkv, hd) — S not /16
            spec = (b_ax, None, None, None)
        elif key == "conv":                         # (B, d_conv-1, d_inner)
            spec = (b_ax, None, "model" if shard_batch else all_axes)
        elif key == "ssm":                          # (B, d_inner, N)
            spec = (b_ax, "model" if shard_batch else all_axes, None)
        elif key == "wkv":                          # (B, H, hd, hd)
            spec = (b_ax, "model", None, None)
        elif key in ("tm_x", "cm_x"):               # (B, D)
            spec = (b_ax, "model" if shard_batch else all_axes)
        else:
            spec = (None,) * nd
        if stacked:
            spec = (None,) + tuple(spec)
        spec = tuple(spec)[:nd] + (None,) * max(0, nd - len(spec))
        return _guard(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)

"""Spec-driven pre-jax runtime bootstrap.

``ExperimentSpec.mesh`` carries process-level runtime knobs — ``platform``,
``x64``, extra ``xla_flags``, and the forced host-device count implied by
``shards`` — that only take effect when the environment is set BEFORE jax
initialises its backend.  This module turns that spec section into
environment state:

    from repro.launch.platform import bootstrap
    bootstrap({"mesh": {"shards": 8, "platform": "cpu"}})
    import repro.api as api          # jax now initialises under the right env

``bootstrap`` accepts the raw JSON dict of a spec (or just its ``mesh``
section, or a ``MeshSpec``-shaped object) precisely so callers can peek at a
spec file without importing anything jax-adjacent first.  Like
``repro.launch.bootstrap`` it must never import jax: when the environment
had to change after jax was already imported, the interpreter re-execs once
(``os.execv`` preserves ``os.environ``), and the re-exec'd process falls
through because the environment already matches.
"""
from __future__ import annotations

import os
import sys
from typing import Any, Mapping

_FORCE = "--xla_force_host_platform_device_count"


def _get(section: Any, name: str, default: Any) -> Any:
    """Field access across raw dicts and MeshSpec-shaped objects."""
    if isinstance(section, Mapping):
        return section.get(name, default)
    return getattr(section, name, default)


def _mesh_section(spec: Any) -> Any:
    """The mesh section of ``spec`` — itself, if already a mesh section."""
    if isinstance(spec, Mapping) and "mesh" in spec:
        return spec["mesh"]
    inner = getattr(spec, "mesh", None)
    return inner if inner is not None else spec


def resolve_env(spec: Any, environ: Mapping[str, str] | None = None
                ) -> dict[str, str]:
    """The environment updates ``spec``'s mesh section implies — pure.

    Returns only the variables whose value must CHANGE relative to
    ``environ`` (default ``os.environ``), so an empty dict means the process
    is already correctly configured (the re-exec termination condition).

      * ``platform`` (non-empty)  → ``JAX_PLATFORMS``
      * ``x64`` (true)            → ``JAX_ENABLE_X64=1``
      * ``xla_flags``             → appended to ``XLA_FLAGS`` in spec order,
                                    skipping flags already present verbatim
      * ``shards > 1``            → ``--xla_force_host_platform_device_count``
                                    (cpu / unset platform only; replaces a
                                    smaller forced count, never shrinks one)
    """
    env = os.environ if environ is None else environ
    mesh = _mesh_section(spec)
    shards = int(_get(mesh, "shards", 1))
    platform = str(_get(mesh, "platform", "") or "")
    x64 = bool(_get(mesh, "x64", False))
    extra = tuple(_get(mesh, "xla_flags", ()) or ())

    updates: dict[str, str] = {}
    if platform and env.get("JAX_PLATFORMS", "") != platform:
        updates["JAX_PLATFORMS"] = platform
    if x64 and env.get("JAX_ENABLE_X64", "") not in ("1", "true", "True"):
        updates["JAX_ENABLE_X64"] = "1"

    flags = env.get("XLA_FLAGS", "").split()
    for f in extra:
        if f not in flags:
            flags.append(f)
    if shards > 1 and platform in ("", "cpu"):
        current = 0
        for f in flags:
            if f.startswith(_FORCE + "="):
                current = int(f.split("=", 1)[1])
        if current < shards:
            flags = [f for f in flags if not f.startswith(_FORCE + "=")]
            flags.append(f"{_FORCE}={shards}")
    joined = " ".join(flags)
    if joined != env.get("XLA_FLAGS", ""):
        updates["XLA_FLAGS"] = joined
    return updates


def bootstrap(spec: Any, *, reexec: bool | None = None) -> bool:
    """Apply :func:`resolve_env` to ``os.environ``; returns True if anything
    changed.

    When jax is already imported the new environment cannot take effect in
    this process, so the script re-execs once (``reexec=None`` means "only
    if jax is in ``sys.modules``"; pass False to force in-process mutation
    for tests).  Idempotent: a second call — including the re-exec'd
    process's — finds nothing to change and falls straight through.
    """
    updates = resolve_env(spec)
    if not updates:
        return False
    os.environ.update(updates)
    if reexec is None:
        reexec = "jax" in sys.modules
    if reexec:
        os.execv(sys.executable, [sys.executable, sys.argv[0], *sys.argv[1:]])
    return True

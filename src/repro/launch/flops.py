"""Analytical FLOP / byte model per (architecture × input shape).

Why analytical: XLA's ``cost_analysis`` counts ``lax.scan`` bodies once
(verified experimentally — see EXPERIMENTS.md §Dry-run), and every model here
scans over layer periods (and Mamba/RWKV scan over time), so the HLO number
undercounts by orders of magnitude.  The roofline compute/memory terms
therefore come from this model, which counts exactly what the compiled graph
executes — including full-S² masked chunked attention (baseline), MoE
capacity dispatch, and remat recompute.  Raw ``cost_analysis`` values are kept
in the dry-run artifacts as cross-checks.

All counts are GLOBAL (whole step, all devices); callers divide by chips.
Matmul (m,k)×(k,n) = 2·m·k·n FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.shapes import InputShape
from repro.models.moe import moe_capacity
from repro.models.transformer import ArchConfig, LayerSpec


@dataclass
class CostBreakdown:
    flops_fwd: float            # one forward pass
    flops_total: float          # step total (train: fwd+bwd(+remat); decode: fwd)
    param_bytes: float          # model parameter bytes (all params, once)
    state_bytes: float          # KV cache / recurrent state bytes (decode)
    hbm_bytes: float            # estimated HBM traffic for the step (global)
    model_flops: float          # 6·N_active·D reference (the "useful" FLOPs)
    n_params: float
    n_active_params: float

    def as_dict(self) -> dict:
        return self.__dict__.copy()


# --------------------------------------------------------------------------- #
# Parameter counts
# --------------------------------------------------------------------------- #

def _layer_params(cfg: ArchConfig, spec: LayerSpec) -> tuple[float, float]:
    """(total, active) parameter count for one layer."""
    D = cfg.d_model
    total = active = 0.0
    if spec.mixer == "attn":
        a = D * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
            + cfg.n_heads * cfg.head_dim * D
        if spec.cross_attn:
            a *= 2
        total += a
        active += a
    elif spec.mixer == "mamba":
        di, N, r = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
        a = D * 2 * di + cfg.mamba_d_conv * di + di * (r + 2 * N) + r * di \
            + di * N + di * D
        total += a
        active += a
    elif spec.mixer == "rwkv":
        hd = cfg.rwkv_head_dim
        H = cfg.rwkv_heads
        a = 4 * D * H * hd + D * cfg.rwkv_lora_rank + cfg.rwkv_lora_rank * H * hd \
            + H * hd * D
        cm = D * cfg.d_ff + cfg.d_ff * D + D * D
        total += a + cm
        active += a + cm
        return total, active

    n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    if spec.moe:
        F = cfg.moe_d_ff or cfg.d_ff
        total += cfg.n_experts * n_mats * D * F + D * cfg.n_experts
        active += cfg.moe_top_k * n_mats * D * F + D * cfg.n_experts
        if cfg.moe_shared_expert:
            total += n_mats * D * F
            active += n_mats * D * F
    else:
        total += n_mats * D * cfg.d_ff
        active += n_mats * D * cfg.d_ff
    return total, active


def param_counts(cfg: ArchConfig) -> tuple[float, float]:
    """(total, active) including embeddings and encoder."""
    total = active = 0.0
    specs = list(cfg.pattern) * cfg.n_periods + list(cfg.remainder)
    for spec in specs:
        t, a = _layer_params(cfg, spec)
        total += t
        active += a
    emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    if cfg.encoder is not None:
        e = cfg.encoder
        enc_layer = 4 * cfg.d_model * cfg.d_model + 2 * cfg.d_model * e.d_ff
        total += e.n_layers * enc_layer
        active += e.n_layers * enc_layer
    return total, active


# --------------------------------------------------------------------------- #
# Forward FLOPs
# --------------------------------------------------------------------------- #

def _attn_flops(cfg: ArchConfig, spec: LayerSpec, B: int, S: int,
                *, swa_skip: bool = False, chunk: int = 512) -> float:
    D = cfg.d_model
    Hq, hd = cfg.n_heads, cfg.head_dim
    proj = 2 * B * S * D * (Hq + 2 * cfg.n_kv_heads) * hd + 2 * B * S * Hq * hd * D
    if S >= 2048:
        # chunked attention: baseline computes ALL (nq × nk) blocks with
        # masking; swa_skip computes only live blocks (§Perf optimisation)
        nq = nk = S // min(chunk, S)
        if swa_skip and spec.window > 0:
            # static banded unroll: per q block, blocks [lo(i), hi(i)]
            c = min(chunk, S)
            live = 0
            for i in range(nq):
                lo = max(0, (i * c - spec.window + 1) // c)
                hi = min(nk - 1, ((i + 1) * c - 1) // c)
                live += hi - lo + 1
        else:
            live = nq * nk  # masked scan computes every block (global layers)
        kv_pairs = live * min(chunk, S) ** 2
    else:
        kv_pairs = S * S
    score_av = 4 * B * Hq * hd * kv_pairs
    total = proj + score_av
    if spec.cross_attn and cfg.encoder is not None:
        Se = cfg.encoder.n_frames
        total += (2 * B * S * D * Hq * hd + 2 * B * Se * D * 2 * cfg.n_kv_heads * hd
                  + 4 * B * Hq * hd * S * Se + 2 * B * S * Hq * hd * D)
    return total


def _attn_decode_flops(cfg: ArchConfig, spec: LayerSpec, B: int, S: int) -> float:
    D, Hq, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    s_c = min(spec.window, S) if spec.window > 0 else S
    proj = 2 * B * D * (Hq + 2 * cfg.n_kv_heads) * hd + 2 * B * Hq * hd * D
    score_av = 4 * B * Hq * hd * s_c
    total = proj + score_av
    if spec.cross_attn and cfg.encoder is not None:
        total += 2 * B * D * Hq * hd + 4 * B * Hq * hd * cfg.encoder.n_frames \
                 + 2 * B * Hq * hd * D
    return total


def _ffn_flops(cfg: ArchConfig, spec: LayerSpec, tokens: float) -> float:
    D = cfg.d_model
    n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    if spec.moe:
        F = cfg.moe_d_ff or cfg.d_ff
        cap = moe_capacity(int(tokens), cfg.moe_top_k, cfg.n_experts,
                           cfg.capacity_factor)
        expert = 2 * cfg.n_experts * cap * n_mats * D * F
        router = 2 * tokens * D * cfg.n_experts
        shared = 2 * tokens * n_mats * D * F if cfg.moe_shared_expert else 0.0
        return expert + router + shared
    return 2 * tokens * n_mats * D * cfg.d_ff


def _mixer_flops(cfg: ArchConfig, spec: LayerSpec, B: int, S: int,
                 *, decode: bool, swa_skip: bool = False) -> float:
    D = cfg.d_model
    tokens = B * (1 if decode else S)
    if spec.mixer == "attn":
        return (_attn_decode_flops(cfg, spec, B, S) if decode
                else _attn_flops(cfg, spec, B, S, swa_skip=swa_skip))
    if spec.mixer == "mamba":
        di, N, r = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
        return tokens * (2 * D * 2 * di + 2 * cfg.mamba_d_conv * di
                         + 2 * di * (r + 2 * N) + 2 * r * di
                         + 6 * di * N + 2 * di * D)
    if spec.mixer == "rwkv":
        hd, H = cfg.rwkv_head_dim, cfg.rwkv_heads
        tm = tokens * (8 * D * H * hd + 2 * D * cfg.rwkv_lora_rank
                       + 2 * cfg.rwkv_lora_rank * H * hd + 5 * H * hd * hd
                       + 2 * H * hd * D)
        cm = tokens * (2 * D * cfg.d_ff + 2 * cfg.d_ff * D + 2 * D * D)
        return tm + cm
    raise ValueError(spec.mixer)


def forward_flops(cfg: ArchConfig, B: int, S: int, *, decode: bool = False,
                  swa_skip: bool = False) -> float:
    tokens = B * (1 if decode else S)
    total = 0.0
    specs = list(cfg.pattern) * cfg.n_periods + list(cfg.remainder)
    for spec in specs:
        total += _mixer_flops(cfg, spec, B, S, decode=decode, swa_skip=swa_skip)
        if spec.mixer != "rwkv":
            total += _ffn_flops(cfg, spec, tokens)
    total += 2 * tokens * cfg.d_model * cfg.padded_vocab        # unembed
    if cfg.encoder is not None and not decode:
        e = cfg.encoder
        Se = e.n_frames
        enc_attn = 2 * B * Se * cfg.d_model * 4 * cfg.d_model + 4 * B * e.n_heads \
            * (cfg.d_model // e.n_heads) * Se * Se
        enc_ffn = 2 * B * Se * 2 * cfg.d_model * e.d_ff
        total += e.n_layers * (enc_attn + enc_ffn)
    return total


# --------------------------------------------------------------------------- #
# HBM traffic estimate
# --------------------------------------------------------------------------- #

def _dtype_bytes(cfg: ArchConfig) -> int:
    return 2 if cfg.param_dtype == "bfloat16" else 4


def state_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    """Decode cache bytes (KV / conv / ssm / wkv)."""
    by = _dtype_bytes(cfg)
    total = 0.0
    specs = list(cfg.pattern) * cfg.n_periods + list(cfg.remainder)
    for spec in specs:
        if spec.mixer == "attn":
            s_c = min(spec.window, S) if spec.window > 0 else S
            total += 2 * B * s_c * cfg.n_kv_heads * cfg.head_dim * by
            if spec.cross_attn and cfg.encoder is not None:
                total += 2 * B * cfg.encoder.n_frames * cfg.n_kv_heads * cfg.head_dim * by
        elif spec.mixer == "mamba":
            total += B * (cfg.mamba_d_conv - 1) * cfg.mamba_d_inner * by \
                     + B * cfg.mamba_d_inner * cfg.mamba_d_state * 4
        elif spec.mixer == "rwkv":
            total += 2 * B * cfg.d_model * by \
                     + B * cfg.rwkv_heads * cfg.rwkv_head_dim ** 2 * 4
    return total


def step_cost(cfg: ArchConfig, shape: InputShape, *, swa_skip: bool = False
              ) -> CostBreakdown:
    B, S = shape.global_batch, shape.seq_len
    by = _dtype_bytes(cfg)
    n_total, n_active = param_counts(cfg)
    pbytes = n_total * by
    decode = shape.kind == "decode"
    fwd = forward_flops(cfg, B, S, decode=decode, swa_skip=swa_skip)

    if shape.kind == "train":
        # bwd = 2×fwd; full remat re-runs fwd once more
        mult = 4.0 if cfg.remat else 3.0
        flops_total = fwd * mult
        tokens = B * S
        act_traffic = 12 * tokens * cfg.d_model * by * cfg.n_layers
        # params: read fwd + read bwd (+ remat read) + grad write; Adam m/v r+w fp32
        hbm = pbytes * (4 if cfg.remat else 3) + n_total * (4 * 4) + act_traffic
        model_flops = 6 * n_active * tokens
        sbytes = 0.0
    elif shape.kind == "prefill":
        flops_total = fwd
        tokens = B * S
        act_traffic = 6 * tokens * cfg.d_model * by * cfg.n_layers
        hbm = pbytes + act_traffic
        model_flops = 2 * n_active * tokens
        sbytes = 0.0
    else:  # decode
        flops_total = fwd
        sbytes = state_bytes(cfg, B, S)
        hbm = pbytes + sbytes + 2 * B * cfg.d_model * cfg.n_layers * by
        model_flops = 2 * n_active * B
    return CostBreakdown(
        flops_fwd=fwd, flops_total=flops_total, param_bytes=pbytes,
        state_bytes=sbytes, hbm_bytes=hbm, model_flops=model_flops,
        n_params=n_total, n_active_params=n_active)

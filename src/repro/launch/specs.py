"""ShapeDtypeStruct input stand-ins for every (arch × input shape) — the
dry-run lowers against these; nothing is ever allocated.

Frontend carve-out (DESIGN.md): audio/vlm archs receive precomputed frame /
patch embeddings of the right shape instead of raw media.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import SHAPES, InputShape
from repro.models.decode import init_cache
from repro.models.transformer import ArchConfig

Pytree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch: dict = {"labels": _sds((B, S), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if cfg.encoder is not None:
        batch["enc_embeds"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


def batch_pspecs(cfg: ArchConfig, batch: dict, mesh) -> dict:
    daxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    B = jax.tree.leaves(batch)[0].shape[0]
    size = 1
    for n in daxes:
        size *= mesh.shape[n]
    b_ax = daxes if B % size == 0 else None   # batch=1 long-context: replicate

    out = {}
    for k, v in batch.items():
        if v.ndim == 2:
            out[k] = P(b_ax, None)
        else:
            out[k] = P(b_ax, None, None)
    return out


def decode_inputs(cfg: ArchConfig, shape: InputShape) -> tuple[dict, Pytree]:
    """(token batch, abstract cache) for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    token = _sds((B, 1), jnp.int32)
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {"token": token}, cache


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """All abstract inputs for the given shape (train batch or decode set)."""
    shape = SHAPES[shape_name]
    if shape.kind == "train" or shape.kind == "prefill":
        return {"batch": train_batch_specs(cfg, shape)}
    token, cache = decode_inputs(cfg, shape)
    return {"token": token["token"], "cache": cache}

"""Training launcher.

Two modes:

* ``--mode local`` (default) — run REAL training steps on the host devices
  (CPU here, TPU slice in production) with a reduced or full config.
  Demonstrates the substrate end-to-end: data pipeline → sharded train_step
  → checkpointing.

* ``--mode dryrun`` — delegate to repro.launch.dryrun for the 512-chip
  lower+compile proof (kept in its own module because XLA_FLAGS must be set
  before jax initialises).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-3b --reduced \
        --steps 50 --batch 8 --seq 64
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --reduced \
        --steps 20 --ckpt experiments/lm_ckpt.npz
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_trainer_state
from repro.configs import get_config
from repro.data.lm import batch_stream, make_token_stream
from repro.models.lm import make_train_step
from repro.models.transformer import init_params
from repro.optim import adamw, warmup_cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced same-family variant (CPU-safe)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 512))
    if cfg.frontend != "tokens" or cfg.encoder is not None:
        raise SystemExit(f"{args.arch}: local LM training needs a token "
                         "frontend (vlm/audio archs train via the dry-run path)")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(warmup_cosine_schedule(args.lr, args.steps // 10 + 1, args.steps))
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)

    toks = make_token_stream(cfg.vocab_size, 50_000, seed=0)
    t0 = time.time()
    first = last = None
    for i, (x, y) in enumerate(batch_stream(toks, args.batch, args.seq,
                                            args.steps, seed=0)):
        loss, params, opt_state = step(
            params, opt_state, {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)})
        loss = float(loss)
        first = first if first is not None else loss
        last = loss
        if i % args.log_every == 0 or i == args.steps - 1:
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  loss {loss:.4f}  tok/s {tps:,.0f}")
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({time.time()-t0:.0f}s)")
    if args.ckpt:
        save_trainer_state(args.ckpt, params, opt_state, args.steps,
                           {"arch": cfg.name})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()

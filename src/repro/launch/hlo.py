"""Post-SPMD HLO parsing: collective byte accounting for the roofline.

``compiled.as_text()`` shows the *per-device* partitioned module, so summed
operand bytes are bytes-through-each-chip; the roofline collective term is
``local_bytes / link_bw``.

XLA's ``cost_analysis`` counts while-loop (lax.scan) bodies **once**, ignoring
trip counts — our models scan over layer periods, so naive sums undercount by
~n_layers.  This parser is *computation-aware*: it maps every collective to
its enclosing HLO computation, resolves the while-loop nesting chain via the
``known_trip_count`` backend_config, and multiplies bytes by the product of
trip counts.  Convention: each collective contributes its *output* bytes
(ring all-reduce moves ~2×; we state the convention rather than model each
algorithm).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+("
    + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its lines."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and "{" in line:
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    return comps


def computation_multipliers(hlo_text: str) -> dict[str, int]:
    """Execution count per computation (product of enclosing scan trips)."""
    comps = _split_computations(hlo_text)
    # body computation -> (parent computation, trip count)
    parent: dict[str, tuple[str, int]] = {}
    for cname, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m and m.group(2):
                body = m.group(2)
                t = _TRIP_RE.search(line)
                trips = int(t.group(1)) if t else 1
                parent[body] = (cname, trips)
                cond = m.group(1)
                parent.setdefault(cond, (cname, trips))

    mult: dict[str, int] = {}

    def resolve(name: str, depth: int = 0) -> int:
        if name in mult:
            return mult[name]
        if depth > 64 or name not in parent:
            mult[name] = 1
            return 1
        pname, trips = parent[name]
        m = resolve(pname, depth + 1) * trips
        mult[name] = m
        return m

    for cname in comps:
        resolve(cname)
    return mult


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Trip-count-weighted output bytes per collective kind (per-device)."""
    comps = _split_computations(hlo_text)
    mult = computation_multipliers(hlo_text)
    totals: dict[str, int] = defaultdict(int)
    for cname, lines in comps.items():
        w = mult.get(cname, 1)
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            tuple_body, dtype, dims, kind = m.groups()
            if tuple_body is not None:
                size = sum(_shape_bytes(dt, dm)
                           for dt, dm in _SHAPE_RE.findall(tuple_body))
            else:
                size = _shape_bytes(dtype, dims)
            totals[kind] += size * w
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return dict(totals)


def collective_counts(hlo_text: str) -> dict[str, int]:
    """Trip-count-weighted number of collective launches (per-device)."""
    comps = _split_computations(hlo_text)
    mult = computation_multipliers(hlo_text)
    counts: dict[str, int] = defaultdict(int)
    for cname, lines in comps.items():
        w = mult.get(cname, 1)
        for line in lines:
            m = _OP_RE.search(line)
            if m:
                counts[m.group(4)] += w
    return dict(counts)


# --------------------------------------------------------------------------- #
# static-audit helpers (repro.analysis.hlo_audit)
# --------------------------------------------------------------------------- #

_ALIAS_HEADER_RE = re.compile(r"input_output_alias=\{")
_ALIAS_PARAM_RE = re.compile(r"\(\s*(\d+)\s*,")
_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')


def donated_params(hlo_text: str) -> set[int]:
    """Parameter indices the compiled module aliases to outputs — i.e. the
    buffers XLA actually donated.  Parsed from the module header's
    ``input_output_alias={ {out}: (param, {index}, may-alias), ... }``
    (balanced-brace scan; the header is one logical line)."""
    m = _ALIAS_HEADER_RE.search(hlo_text)
    if not m:
        return set()
    depth, i = 1, m.end()
    while i < len(hlo_text) and depth > 0:
        if hlo_text[i] == "{":
            depth += 1
        elif hlo_text[i] == "}":
            depth -= 1
        i += 1
    body = hlo_text[m.end():i - 1]
    # each alias entry is `{out_index}: (param_number, {param_index}[, kind])`
    return {int(p) for p in _ALIAS_PARAM_RE.findall(body)}


def collective_lines(hlo_text: str) -> list[tuple[str, str, str]]:
    """Every collective op line: (computation, kind, op_name metadata).

    ``op_name`` carries the jax ``named_scope`` path, so the audit can
    attribute a collective to e.g. the ``cohort_combine`` phase."""
    out: list[tuple[str, str, str]] = []
    for cname, lines in _split_computations(hlo_text).items():
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            nm = _OP_NAME_RE.search(line)
            out.append((cname, m.group(4), nm.group(1) if nm else ""))
    return out


_F64_RESULT_RE = re.compile(r"=\s*(?:\([^)]*\bf64\[|f64\[)")


def f64_op_count(hlo_text: str) -> int:
    """Number of HLO op lines producing an f64 result — with jax x64 off
    this must be zero (a hit means a silent widen, e.g. a python float
    folded through np and back)."""
    return sum(1 for line in hlo_text.splitlines()
               if _F64_RESULT_RE.search(line))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-touching import: jax locks the
# device count at first backend init, and the production meshes need 512
# placeholder host devices.  (Tests/benches never import this module.)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.flops import step_cost  # noqa: E402
from repro.launch.hlo import collective_bytes, collective_counts  # noqa: E402
from repro.launch.mesh import make_production_mesh, use_mesh  # noqa: E402
from repro.launch.specs import batch_pspecs, decode_inputs, train_batch_specs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.transformer import param_specs  # noqa: E402
from repro.optim import adamw  # noqa: E402

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_fl_round(*, multi_pod: bool, n_clients: int = 64,
                   agg_method: str = "mix", verbose: bool = True) -> dict:
    """Dry-run the paper's own technique (PAA aggregation) at pod scale."""
    from repro.launch.fl_target import FLTargetConfig, build

    cfg = FLTargetConfig(n_clients=n_clients, agg_method=agg_method)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with use_mesh(mesh):
        jitted, args = build(cfg, mesh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    # analytical: per client fwd = 2·ψ·(in·h + h·h + h·rep); mix matmul 2·m²·Np
    n_params = cfg.in_dim * cfg.hidden + cfg.hidden ** 2 + cfg.hidden * cfg.rep_dim
    fwd = 2 * cfg.n_clients * cfg.psi * n_params
    mixmm = 2 * cfg.n_clients ** 2 * n_params
    flops = fwd + mixmm
    hbm = cfg.n_clients * n_params * 4 * 2  # read + write of stacked params
    n_chips = mesh.size
    result = {
        "arch": "fl-round-paa", "agg_method": agg_method,
        "shape": f"{cfg.n_clients}cl-100M",
        "mesh": "2x16x16" if multi_pod else "16x16", "n_chips": n_chips,
        "kind": "fl_round", "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "collective_bytes_per_device": coll,
        "collective_counts": collective_counts(hlo),
        "memory_analysis": {f: getattr(mem, f, None) for f in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes")} if mem else {},
        "cost_model": {"flops_total": flops, "hbm_bytes": hbm,
                       "model_flops": mixmm, "n_params": n_params * cfg.n_clients},
        "t_compute": flops / n_chips / PEAK_FLOPS,
        "t_memory": hbm / n_chips / HBM_BW,
        "t_collective": coll.get("total", 0) / ICI_BW,
        "model_flops_ratio": mixmm / flops,
    }
    terms = {k: result[f"t_{k}"] for k in ("compute", "memory", "collective")}
    result["bottleneck"] = max(terms, key=terms.get)
    if verbose:
        print(f"[dryrun] fl-round-paa × {result['shape']} × {result['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"t_comp {result['t_compute']*1e3:.2f}ms "
              f"t_mem {result['t_memory']*1e3:.2f}ms "
              f"t_coll {result['t_collective']*1e3:.2f}ms "
              f"-> {result['bottleneck']}")
    return result


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = True, sharding_mode: str | None = None,
                swa_skip: bool = False, cap_factor: float | None = None,
                attn_constraint: bool = False,
                dm_shape: tuple[int, int] | None = None) -> dict:
    """Lower + compile one (arch × shape × mesh); return roofline raw terms."""
    import dataclasses

    cfg = get_config(arch)
    overrides = {}
    if sharding_mode:
        overrides["sharding_mode"] = sharding_mode
    if swa_skip:
        overrides["swa_skip"] = True
    if cap_factor is not None:
        overrides["capacity_factor"] = cap_factor
    if attn_constraint:
        overrides["attn_batch_axes"] = ("pod", "data") if multi_pod else ("data",)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, dm_shape=dm_shape)
    n_chips = mesh.size

    pshape = param_specs(cfg)
    pspec = shd.param_pspecs(cfg, pshape, mesh)
    psh = _ns(mesh, pspec)

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind in ("train", "prefill"):
            opt = adamw(1e-4)
            oshape = jax.eval_shape(opt.init, pshape)
            ospec = shd.opt_state_pspecs(oshape, pspec)
            osh = _ns(mesh, ospec)
            batch = train_batch_specs(cfg, shape)
            bspec = batch_pspecs(cfg, batch, mesh)
            bsh = _ns(mesh, bspec)
            if shape.kind == "train":
                step = lm.make_train_step(cfg, opt)
                jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                                 out_shardings=(NamedSharding(mesh, P()), psh, osh))
                lowered = jitted.lower(pshape, oshape, batch)
            else:  # prefill: forward-only loss (inference prefill cost)
                step = lm.make_eval_step(cfg)
                jitted = jax.jit(step, in_shardings=(psh, bsh),
                                 out_shardings=NamedSharding(mesh, P()))
                lowered = jitted.lower(pshape, batch)
        else:  # decode
            token, cache_shape = decode_inputs(cfg, shape)
            shard_batch = shape.global_batch > 1
            cspec = shd.cache_pspecs(cfg, cache_shape, mesh, shard_batch=shard_batch)
            csh = _ns(mesh, cspec)
            daxes = ("pod", "data") if multi_pod else ("data",)
            tok_spec = P(daxes, None) if shard_batch else P(None, None)
            tsh = NamedSharding(mesh, tok_spec)
            logits_sh = NamedSharding(mesh, P(daxes if shard_batch else None, None, "model"))
            step = lm.make_serve_step(cfg)
            jitted = jax.jit(step, in_shardings=(psh, csh, tsh),
                             out_shardings=(logits_sh, csh))
            lowered = jitted.lower(pshape, cache_shape, token["token"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    counts = collective_counts(hlo)

    flops_per_device = float(cost.get("flops", 0.0))
    bytes_per_device = float(cost.get("bytes accessed", 0.0))
    mem_fields = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_fields[f] = getattr(mem, f, None)

    # analytical cost model (global) — see launch/flops.py for why HLO
    # cost_analysis alone is insufficient (scan bodies counted once)
    cost_model = step_cost(cfg, shape, swa_skip=cfg.swa_skip)
    t_compute = cost_model.flops_total / n_chips / PEAK_FLOPS
    t_memory = cost_model.hbm_bytes / n_chips / HBM_BW
    t_coll = coll.get("total", 0) / ICI_BW   # per-device bytes / per-link bw
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    dm = dm_shape or (16, 16)
    mesh_name = f"{dm[0]}x{dm[1]}" + ("" if not multi_pod else "")
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": f"2x{mesh_name}" if multi_pod else mesh_name,
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw XLA numbers (per-device; scan bodies counted once — cross-check only)
        "xla_flops_per_device": flops_per_device,
        "xla_bytes_per_device": bytes_per_device,
        "collective_bytes_per_device": coll,
        "collective_counts": counts,
        "memory_analysis": mem_fields,
        # analytical model (global)
        "cost_model": cost_model.as_dict(),
        # roofline terms in seconds
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "bottleneck": bottleneck,
        "model_flops_ratio": (cost_model.model_flops / cost_model.flops_total
                              if cost_model.flops_total else 0.0),
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"t_comp {t_compute*1e3:.2f}ms t_mem {t_memory*1e3:.2f}ms "
              f"t_coll {t_coll*1e3:.2f}ms -> {bottleneck} | "
              f"useful {result['model_flops_ratio']:.2f}")
        if mem_fields:
            print(f"         memory_analysis: {mem_fields}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="BFLN multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id, 'all', or 'fl-round'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--sharding-mode", default=None,
                    choices=[None, "tp", "fsdp_tp", "ep_tp"],
                    help="override the arch's sharding mode (§Perf)")
    ap.add_argument("--swa-skip", action="store_true",
                    help="skip fully-masked attention chunks (§Perf)")
    ap.add_argument("--agg-method", default="mix", choices=["mix", "two_step", "two_step_bf16"],
                    help="fl-round aggregation schedule (§Perf)")
    ap.add_argument("--attn-constraint", action="store_true",
                    help="pin attention activations batch-sharded (§Perf)")
    ap.add_argument("--cap-factor", type=float, default=None,
                    help="override MoE capacity factor (§Perf)")
    ap.add_argument("--dm-shape", default=None,
                    help="override (data, model) mesh factorisation, e.g. 32x8")
    ap.add_argument("--tag", default="", help="artifact suffix for perf variants")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    if args.arch == "fl-round":
        for multi_pod in meshes:
            res = lower_fl_round(multi_pod=multi_pod,
                                 agg_method=args.agg_method)
            tag = f"fl-round__{'multi' if multi_pod else 'single'}{args.tag}"
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
        print("\nfl-round dry-run complete.")
        return

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    failures = []
    for arch in archs:
        for shape_name in shapes:
            ok, reason = shape_applicable(arch, shape_name)
            if not ok:
                print(f"[dryrun] SKIP {arch} × {shape_name}: {reason}")
                continue
            for multi_pod in meshes:
                tag = (f"{arch}__{shape_name}__"
                       f"{'multi' if multi_pod else 'single'}{args.tag}")
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] cached {tag}")
                    continue
                dm = (tuple(int(x) for x in args.dm_shape.split("x"))
                      if args.dm_shape else None)
                try:
                    res = lower_combo(arch, shape_name, multi_pod=multi_pod,
                                      sharding_mode=args.sharding_mode,
                                      swa_skip=args.swa_skip, dm_shape=dm,
                                      cap_factor=args.cap_factor,
                                      attn_constraint=args.attn_constraint)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                except Exception as e:  # noqa: BLE001 — report, keep sweeping
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nAll dry-run combinations lowered + compiled successfully.")


if __name__ == "__main__":
    main()

"""Production mesh definitions (TPU v5e pods).

Functions, not module-level constants — importing this module never touches
jax device state (critical: the dry-run sets XLA_FLAGS before first jax use,
while tests/benches must keep seeing 1 CPU device).

Version compatibility: the repo targets the modern mesh API
(``jax.sharding.AxisType``, ``jax.set_mesh``, two-argument ``AbstractMesh``)
but must run on the installed JAX 0.4.37, which predates all three.  The
shims below feature-detect once and degrade gracefully:

  * ``_auto(n)``          → ``None`` when ``AxisType`` is absent, and every
    ``make_mesh`` call here omits ``axis_types`` in that case (0.4.x meshes
    are implicitly fully-auto, so behaviour is identical);
  * ``make_abstract_mesh`` → builds ``AbstractMesh`` through whichever
    constructor signature the installed JAX accepts (0.4.x wants a single
    ``((name, size), ...)`` tuple and raises ``TypeError: 'int' object is
    not iterable`` on the modern two-argument form);
  * ``use_mesh``          → ``jax.set_mesh`` context when available, else the
    mesh itself (``Mesh`` is a context manager in 0.4.x).
"""
from __future__ import annotations

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _auto(n: int):
    """``n`` Auto axis types, or ``None`` when this JAX predates AxisType."""
    if _AXIS_TYPE is None:
        return None
    return (_AXIS_TYPE.Auto,) * n


def compat_make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_types = _auto(len(axes))
    if axis_types is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free ``AbstractMesh`` across both constructor generations."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)            # modern (sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))  # 0.4.x shape_tuple


def use_mesh(mesh):
    """Context manager activating ``mesh`` for jit/shard_map bodies."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh                                     # 0.4.x: Mesh is a CM


def make_production_mesh(*, multi_pod: bool = False,
                         dm_shape: tuple[int, int] | None = None):
    """16×16 = 256 chips single pod; 2×16×16 = 512 chips across two pods.

    ``dm_shape`` overrides the (data, model) factorisation (same chip count)
    — e.g. (32, 8) keeps attention-head sharding divisible for archs with few
    (GQA) heads; see EXPERIMENTS.md §Perf.
    """
    dm = dm_shape or (16, 16)
    assert dm[0] * dm[1] == 256, dm
    shape = (2, *dm) if multi_pod else dm
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return compat_make_mesh((data, model), ("data", "model"))


CLIENT_AXIS = "clients"


def make_client_mesh(shards: int):
    """1-D mesh sharding the *population* (client) axis of the parameter
    arena (`repro.runtime.arena.ShardedParamArena`) over ``shards`` devices.

    This is the federation scaling axis: population state is
    O(n_clients · N_params) and spreads across devices as arena rows, while
    the per-round cohort axis shards over the SAME mesh (each device trains
    its slice of the cohort; `repro.launch.sharding.cohort_shardings` builds
    the constraint pair).  On CPU, force multiple host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* the
    first jax call (CI's mesh leg and the sharded tests do exactly this).
    """
    avail = len(jax.devices())
    if shards > avail:
        raise ValueError(
            f"make_client_mesh({shards}) needs {shards} devices but only "
            f"{avail} exist; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={shards} before jax "
            f"initialises")
    return compat_make_mesh((shards,), (CLIENT_AXIS,))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1

"""Production mesh definitions (TPU v5e pods).

Functions, not module-level constants — importing this module never touches
jax device state (critical: the dry-run sets XLA_FLAGS before first jax use,
while tests/benches must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False,
                         dm_shape: tuple[int, int] | None = None):
    """16×16 = 256 chips single pod; 2×16×16 = 512 chips across two pods.

    ``dm_shape`` overrides the (data, model) factorisation (same chip count)
    — e.g. (32, 8) keeps attention-head sharding divisible for archs with few
    (GQA) heads; see EXPERIMENTS.md §Perf.
    """
    dm = dm_shape or (16, 16)
    assert dm[0] * dm[1] == 256, dm
    shape = (2, *dm) if multi_pod else dm
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1

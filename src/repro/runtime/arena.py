"""Parameter arena: ONE canonical flat layout for population-stacked params.

Before this module, three subsystems each invented their own flattening of
the stacked parameter pytree: ``kernels.fingerprint`` re-stacked-and-raveled
every leaf per digest call, ``kernels.cluster_agg`` asked its callers to
hand-build an ``(m, N)`` matrix, and the sim driver shuttled whole pytrees
through per-leaf host-side gathers and scatters — an O(n_clients · N_params)
reallocation every round.  The arena flattens the population ONCE into a
single ``(n_clients, N_params)`` matrix with a recorded leaf layout, and
everything downstream (cohort gather, cluster-masked FedAvg, fingerprint
digests, masked scatter-back) operates on rows of that matrix.

Canonical leaf order is **path-sorted** (``jax.tree_util.keystr``), the same
order ``kernels.fingerprint`` has always used — so digests of arena rows are
bit-identical to digests of the original pytrees.  Flatten/unflatten are
pure reshape/concat (no arithmetic); the value path accepts only leaf
dtypes exactly representable in the arena dtype (fp32 arena: f32/bf16/f16),
so round-tripping is exact and the views fuse away inside a jitted
program.  The uint32 *bit* view for fingerprinting (``flatten_u32``) is
separate and keeps the legacy permissive cast semantics.

The :class:`ParamArena` wrapper is a host-side convenience; the fused round
engine (``repro.core.engine``) passes the raw ``data`` matrix through its
donated jitted step and writes the result back.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclass(frozen=True)
class ArenaLayout:
    """Recorded flat layout of a stacked pytree (leading client axis).

    ``paths``/``shapes``/``dtypes``/``sizes``/``offsets`` describe the leaves
    in canonical (path-sorted) column order; ``treedef`` plus ``order`` (the
    permutation from tree order to canonical order) reconstruct the pytree.
    """

    treedef: Any = field(repr=False)
    paths: tuple[str, ...]
    shapes: tuple[tuple[int, ...], ...]   # per-client shapes (no client axis)
    dtypes: tuple[Any, ...]
    sizes: tuple[int, ...]
    offsets: tuple[int, ...]
    order: tuple[int, ...]                # canonical position -> tree position
    dtype: Any = jnp.float32              # arena storage dtype

    @property
    def n_params(self) -> int:
        return int(sum(self.sizes))

    # ------------------------------------------------------------------ #

    @classmethod
    def from_stacked(cls, stacked: Pytree, dtype=jnp.float32) -> "ArenaLayout":
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(stacked)
        keystrs = [jax.tree_util.keystr(p) for p, _ in leaves_p]
        order = tuple(sorted(range(len(leaves_p)), key=lambda i: keystrs[i]))
        paths, shapes, dtypes, sizes = [], [], [], []
        for i in order:
            leaf = leaves_p[i][1]
            paths.append(keystrs[i])
            shapes.append(tuple(leaf.shape[1:]))
            dtypes.append(leaf.dtype)
            sizes.append(int(np.prod(leaf.shape[1:], dtype=np.int64)))
        offsets = tuple(int(o) for o in np.cumsum([0] + sizes[:-1]))
        return cls(treedef=treedef, paths=tuple(paths), shapes=tuple(shapes),
                   dtypes=tuple(dtypes), sizes=tuple(sizes), offsets=offsets,
                   order=order, dtype=dtype)

    # ------------------------------------------------------------------ #

    def flatten(self, stacked: Pytree) -> jax.Array:
        """Stacked pytree -> ``(m, N)`` matrix in canonical column order.

        Value path: only dtypes exactly representable in the arena dtype are
        accepted (for fp32 arenas: float32/bfloat16/float16), so
        ``unflatten(flatten(x)) == x`` bit for bit.  The *bit*-pattern view
        for fingerprinting (``flatten_u32``) is separate and permissive.
        """
        leaves = jax.tree_util.tree_leaves(stacked)
        for pos, i in enumerate(self.order):
            # the leaf's own dtype — jnp.asarray would silently demote f64
            # (x64 disabled) before the guard could see it
            dt = np.dtype(getattr(leaves[i], "dtype", None)
                          or np.asarray(leaves[i]).dtype)
            if not jnp.issubdtype(dt, jnp.floating) or dt.itemsize > \
                    jnp.dtype(self.dtype).itemsize:
                raise TypeError(
                    f"arena leaf {self.paths[pos]} has dtype {dt}, not "
                    f"exactly representable in the "
                    f"{jnp.dtype(self.dtype).name} arena")
        m = leaves[0].shape[0]
        cols = [leaves[i].astype(self.dtype).reshape(m, -1) for i in self.order]
        return jnp.concatenate(cols, axis=1)

    def flatten_u32(self, stacked: Pytree) -> jax.Array:
        """Stacked pytree -> ``(m, N)`` uint32 bit matrix (fingerprint input).

        Non-32-bit leaves are cast to float32 first, exactly like the
        original ``kernels.fingerprint.stack_flatten_u32``.
        """
        leaves = jax.tree_util.tree_leaves(stacked)
        m = leaves[0].shape[0]
        cols = []
        for i in self.order:
            leaf = leaves[i]
            if leaf.dtype.itemsize != 4:
                leaf = leaf.astype(jnp.float32)
            cols.append(jax.lax.bitcast_convert_type(leaf, jnp.uint32)
                        .reshape(m, -1))
        return jnp.concatenate(cols, axis=1)

    def unflatten(self, flat: jax.Array) -> Pytree:
        """``(m, N)`` matrix -> stacked pytree (exact inverse of flatten)."""
        m = flat.shape[0]
        tree_order: list = [None] * len(self.order)
        for pos, i in enumerate(self.order):
            col = flat[:, self.offsets[pos]: self.offsets[pos] + self.sizes[pos]]
            tree_order[i] = col.reshape((m,) + self.shapes[pos]) \
                               .astype(self.dtypes[pos])
        return jax.tree_util.tree_unflatten(self.treedef, tree_order)


def bitcast_u32(rows: jax.Array) -> jax.Array:
    """Arena rows (fp32) -> their exact uint32 bit pattern (fingerprint view)."""
    return jax.lax.bitcast_convert_type(rows, jnp.uint32)


class ParamArena:
    """The population parameter matrix plus its recorded layout.

    ``data`` is an ``(n_clients, N_params)`` device array.  The fused round
    engine consumes and returns ``data`` directly (buffer-donated); the
    methods here are thin views for host-side callers and tests.
    """

    def __init__(self, layout: ArenaLayout, data: jax.Array):
        self.layout = layout
        self.data = data

    @classmethod
    def from_stacked(cls, stacked: Pytree, dtype=jnp.float32) -> "ParamArena":
        layout = ArenaLayout.from_stacked(stacked, dtype=dtype)
        return cls(layout, layout.flatten(stacked))

    @property
    def n_clients(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_params(self) -> int:
        return self.layout.n_params

    # ------------------------------------------------------------------ #

    def gather(self, cohort) -> jax.Array:
        """Rows for a cohort of client ids -> ``(k, N)``."""
        return self.data[jnp.asarray(cohort)]

    def masked_scatter(self, cohort, mask, rows: jax.Array) -> None:
        """Write ``rows`` back into the cohort's slots where ``mask`` is set;
        masked-out slots (stragglers, dropouts) keep their existing params.
        Fixed-shape: the update is a ``where`` over the full cohort, never a
        dynamically-sized row subset."""
        idx = jnp.asarray(cohort)
        keep = jnp.asarray(mask).astype(bool)[:, None]
        upd = jnp.where(keep, rows, self.data[idx])
        self.data = self.data.at[idx].set(upd)

    def rebind(self, flat: jax.Array) -> None:
        """Install a freshly computed (n, N) population matrix (host-side
        entry; the hot path donates ``data`` through the engine instead)."""
        self.data = flat

    def as_pytree(self, rows: jax.Array | None = None) -> Pytree:
        """Pytree view of ``rows`` (default: the whole population)."""
        return self.layout.unflatten(self.data if rows is None else rows)

    def row_pytree(self, i: int) -> Pytree:
        """One client's (unstacked) param pytree."""
        return jax.tree_util.tree_map(
            lambda x: x[0], self.as_pytree(self.data[i][None]))


class ShardedParamArena(ParamArena):
    """A :class:`ParamArena` whose ``(n, N)`` matrix is row-sharded across a
    1-D device mesh on the client axis (`repro.launch.mesh.make_client_mesh`).

    Population state is the O(n_clients · N_params) scaling wall; the cohort
    working set is only O(k · N).  The arena rows spread over the mesh (each
    device holds ``n_padded / shards`` rows), and the round engine shards the
    *cohort* axis over the same mesh: the gather lands each device its own
    cohort slice (never a replicated (k, N) block), local training and
    batched fingerprints run shard-local, and aggregation combines
    shard-local partials with fixed-order tree reductions
    (`repro.core.aggregation`) whose bits do not depend on the partition
    layout — so the full arena never materialises on one device AND seeded
    replay stays bit-identical to the unsharded engine.  The masked
    scatter-back writes only the rows each device owns.

    Rows are zero-padded up to a multiple of the shard count (0.4.x
    NamedShardings require divisible dims); padding rows sit beyond every
    real client id, are never gathered or scattered, and ``n_clients`` /
    ``as_pytree`` expose only the logical population.

    Scope of the "never on one device" invariant: it covers the ROUND LOOP —
    every donated step consumes and produces the row-sharded matrix.  The
    host-side entry points (``from_stacked``, ``rebind``, the driver's
    ``params`` setter and async end-of-run broadcast) still build the full
    matrix once on the default device before ``device_put`` redistributes
    it, because the stacked *source* pytree they flatten is itself
    single-device.  Sharded population *initialisation* (per-shard
    ``make_array_from_callback`` fed by a sharded init) is the next scaling
    rung — see ROADMAP.
    """

    def __init__(self, layout: ArenaLayout, data: jax.Array, n_clients: int,
                 mesh):
        from jax.sharding import NamedSharding, PartitionSpec
        super().__init__(layout, data)
        self._n_clients = int(n_clients)
        self.mesh = mesh
        axis = mesh.axis_names[0]
        self.sharding = NamedSharding(mesh, PartitionSpec(axis))
        self.replicated = NamedSharding(mesh, PartitionSpec())
        if data.shape[0] % mesh.devices.size:
            raise ValueError(
                f"padded arena rows ({data.shape[0]}) not divisible by the "
                f"{mesh.devices.size}-device client mesh")
        self.data = jax.device_put(data, self.sharding)

    @classmethod
    def from_stacked(cls, stacked: Pytree, mesh, dtype=jnp.float32
                     ) -> "ShardedParamArena":
        layout = ArenaLayout.from_stacked(stacked, dtype=dtype)
        flat = layout.flatten(stacked)
        n = flat.shape[0]
        return cls(layout, cls._pad_rows(flat, n, mesh), n, mesh)

    @staticmethod
    def _pad_rows(flat: jax.Array, n_clients: int, mesh) -> jax.Array:
        shards = mesh.devices.size
        n_padded = -(-n_clients // shards) * shards
        if n_padded != flat.shape[0]:
            flat = jnp.concatenate(
                [flat, jnp.zeros((n_padded - flat.shape[0], flat.shape[1]),
                                 flat.dtype)])
        return flat

    # ------------------------------------------------------------------ #

    @property
    def n_clients(self) -> int:          # logical population, not padded rows
        return self._n_clients

    @property
    def n_padded(self) -> int:
        return int(self.data.shape[0])

    def rebind(self, flat: jax.Array) -> None:
        """Install a freshly computed (n, N) population matrix, re-padding and
        re-placing it onto the mesh (host-side entry; the hot path donates
        ``data`` through the engine instead)."""
        self.data = jax.device_put(
            self._pad_rows(flat, self._n_clients, self.mesh), self.sharding)

    def as_pytree(self, rows: jax.Array | None = None) -> Pytree:
        if rows is None:
            rows = self.data[: self._n_clients]      # drop padding rows
        return self.layout.unflatten(rows)

    def per_device_bytes(self) -> int:
        """Arena bytes resident on ONE device (the scaling headline)."""
        shard = self.data.addressable_shards[0].data
        return int(np.prod(shard.shape) * shard.dtype.itemsize)

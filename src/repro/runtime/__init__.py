"""Runtime substrate shared across core/kernels/sim: the parameter arena."""
from repro.runtime.arena import ArenaLayout, ParamArena, bitcast_u32  # noqa: F401

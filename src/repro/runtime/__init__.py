"""Runtime substrate shared across core/kernels/sim: the parameter arena."""
from repro.runtime.arena import (  # noqa: F401
    ArenaLayout,
    ParamArena,
    ShardedParamArena,
    bitcast_u32,
)

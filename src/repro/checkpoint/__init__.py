from repro.checkpoint.io import load_pytree, restore_trainer_state, save_pytree, save_trainer_state  # noqa: F401

from repro.checkpoint.io import (  # noqa: F401
    CheckpointError,
    checkpoint_path,
    list_checkpoints,
    load_latest,
    load_pytree,
    restore_trainer_state,
    save_checkpoint,
    save_pytree,
    save_trainer_state,
)
from repro.checkpoint.spec import CheckpointSpec  # noqa: F401
from repro.checkpoint.state import (  # noqa: F401
    capture_experiment_state,
    restore_experiment_state,
)

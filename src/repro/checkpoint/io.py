"""Crash-consistent pytree checkpointing.

Leaves are stored under their flattened key-paths, so any nesting of
dict/list/tuple round-trips exactly (structure is stored alongside).

The on-disk format is a hardened container (format v2):

    MAGIC "BFLNCKPT" | u32 format version | u64 header length
    | header JSON (payload sha256 + length) | npz payload

Durability discipline: the payload is staged to a temp file in the target
directory, ``fsync``'d, atomically ``os.replace``'d into place, and the
*directory* is fsync'd afterwards — a crash (SIGKILL, power loss) at any
point leaves either the previous checkpoint or the complete new one, never
a torn file under the final name.  On read the header's sha256 is verified
before anything is unpickled, so a truncated or bit-flipped file raises a
clean :class:`CheckpointError` instead of a raw zip/pickle exception.
Files written by the pre-header format (bare npz, zip magic) still load.

Directory-level management (``save_checkpoint`` / ``load_latest``) keeps
the last K snapshots and falls back to the newest *readable* one when the
latest is corrupt — the automatic-recovery path the fault-injection tests
exercise with truncated and bit-flipped checkpoints.
"""
from __future__ import annotations

import hashlib
import io as _io
import json
import os
import pickle
import re
import struct
import tempfile
from typing import Any

import jax
import numpy as np

Pytree = Any

MAGIC = b"BFLNCKPT"
FORMAT_VERSION = 2
_HDR = struct.Struct("<IQ")           # format version, header length

_CKPT_RE = re.compile(r"^ckpt_(\d{8})\.npz$")


class CheckpointError(RuntimeError):
    """A checkpoint file is missing, truncated, corrupt, or incompatible."""


# --------------------------------------------------------------------- #
# payload (npz) encode/decode — leaf arrays + pickled treedef
# --------------------------------------------------------------------- #


def _encode_payload(tree: Pytree) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    dtypes = {}
    for i, x in enumerate(leaves):
        arr = np.asarray(jax.device_get(x))
        dtypes[i] = str(arr.dtype)
        if arr.dtype.kind == "V" or str(arr.dtype) not in np.sctypeDict:
            # non-native dtypes (bfloat16, fp8 via ml_dtypes): store raw bytes
            arrays[f"leaf_{i}"] = arr.view(np.uint8).reshape(arr.shape + (-1,)) \
                if arr.ndim else np.frombuffer(arr.tobytes(), np.uint8)
            arrays[f"shape_{i}"] = np.asarray(arr.shape, np.int64)
        else:
            arrays[f"leaf_{i}"] = arr
    meta = {"treedef": pickle.dumps(treedef), "n": len(leaves),
            "dtypes": dtypes}
    buf = _io.BytesIO()
    np.savez(buf, __meta__=np.frombuffer(pickle.dumps(meta), np.uint8),
             **arrays)
    return buf.getvalue()


def _decode_payload(payload: bytes) -> Pytree:
    import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy

    with np.load(_io.BytesIO(payload), allow_pickle=False) as z:
        meta = pickle.loads(z["__meta__"].tobytes())
        treedef = pickle.loads(meta["treedef"])
        leaves = []
        for i in range(meta["n"]):
            arr = z[f"leaf_{i}"]
            want = meta.get("dtypes", {}).get(i, str(arr.dtype))
            if f"shape_{i}" in z:
                shape = tuple(z[f"shape_{i}"])
                arr = arr.reshape(-1).view(np.dtype(want)).reshape(shape)
            elif str(arr.dtype) != want:
                arr = arr.astype(np.dtype(want))
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------------- #
# hardened file container
# --------------------------------------------------------------------- #


def save_pytree(path: str, tree: Pytree) -> int:
    """Write ``tree`` to ``path`` crash-consistently; returns bytes written.

    fsync(file) → atomic rename → fsync(directory): after this returns the
    checkpoint is durable, and a crash mid-write can never leave a torn
    file under ``path``.
    """
    payload = _encode_payload(tree)
    header = json.dumps({
        "format": FORMAT_VERSION,
        "payload_len": len(payload),
        "payload_sha256": hashlib.sha256(payload).hexdigest(),
    }, sort_keys=True).encode()
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(MAGIC)
            f.write(_HDR.pack(FORMAT_VERSION, len(header)))
            f.write(header)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        dfd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return len(MAGIC) + _HDR.size + len(header) + len(payload)


def load_pytree(path: str) -> Pytree:
    """Read a checkpoint, verifying the header's payload sha256 first.

    Raises :class:`CheckpointError` on a missing, truncated, corrupt, or
    version-incompatible file.  Pre-header (bare npz) files still load.
    """
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        raise CheckpointError(f"cannot read checkpoint {path!r}: {e}") from e
    if raw[:2] == b"PK":                      # legacy format: bare npz
        try:
            return _decode_payload(raw)
        except Exception as e:
            raise CheckpointError(
                f"legacy checkpoint {path!r} is corrupt: {e}") from e
    if len(raw) < len(MAGIC) + _HDR.size or raw[: len(MAGIC)] != MAGIC:
        raise CheckpointError(
            f"{path!r} is not a checkpoint (bad magic / truncated header)")
    version, hdr_len = _HDR.unpack_from(raw, len(MAGIC))
    if version > FORMAT_VERSION:
        raise CheckpointError(
            f"checkpoint {path!r} has format v{version}; this build reads "
            f"<= v{FORMAT_VERSION}")
    body = len(MAGIC) + _HDR.size
    try:
        header = json.loads(raw[body: body + hdr_len])
    except (ValueError, UnicodeDecodeError) as e:
        raise CheckpointError(
            f"checkpoint {path!r} has a corrupt header: {e}") from e
    payload = raw[body + hdr_len:]
    if len(payload) != header.get("payload_len", -1):
        raise CheckpointError(
            f"checkpoint {path!r} is truncated: payload {len(payload)} bytes,"
            f" header recorded {header.get('payload_len')}")
    digest = hashlib.sha256(payload).hexdigest()
    if digest != header.get("payload_sha256"):
        raise CheckpointError(
            f"checkpoint {path!r} failed its sha256 integrity check "
            f"(corrupt payload)")
    try:
        return _decode_payload(payload)
    except Exception as e:
        raise CheckpointError(f"checkpoint {path!r} payload does not decode "
                              f"despite a valid digest: {e}") from e


# --------------------------------------------------------------------- #
# directory management: numbered snapshots, keep-last-K, corrupt fallback
# --------------------------------------------------------------------- #


def checkpoint_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")


def list_checkpoints(ckpt_dir: str) -> list[tuple[int, str]]:
    """``[(step, path)]`` ascending by step; empty for a missing directory."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(ckpt_dir, name)))
    return sorted(out)


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    keep_last: int = 3) -> tuple[str, int]:
    """Write snapshot ``step`` into ``ckpt_dir`` and prune to the newest
    ``keep_last`` snapshots; returns ``(path, bytes_written)``."""
    path = checkpoint_path(ckpt_dir, step)
    n_bytes = save_pytree(path, tree)
    if keep_last >= 1:
        for _, old in list_checkpoints(ckpt_dir)[:-keep_last]:
            try:
                os.unlink(old)
            except OSError:
                pass                        # pruning is best-effort
    return path, n_bytes


def load_latest(ckpt_dir: str) -> tuple[int, Pytree]:
    """Load the newest *readable* snapshot in ``ckpt_dir``.

    A corrupt/truncated latest snapshot (e.g. injected via
    ``FaultSpec.corrupt_checkpoint_round``) falls back to the previous
    keep-last-K snapshot; raises :class:`CheckpointError` only when no
    snapshot in the directory is readable.
    """
    entries = list_checkpoints(ckpt_dir)
    if not entries:
        raise CheckpointError(f"no checkpoints found in {ckpt_dir!r}")
    errors = []
    for step, path in reversed(entries):
        try:
            return step, load_pytree(path)
        except CheckpointError as e:
            errors.append(str(e))
    raise CheckpointError(
        "every checkpoint in {!r} is unreadable:\n  {}".format(
            ckpt_dir, "\n  ".join(errors)))


# --------------------------------------------------------------------- #
# trainer-state convenience wrappers (legacy surface, kept)
# --------------------------------------------------------------------- #


def save_trainer_state(path: str, params: Pytree, opt_state: Pytree,
                       round_idx: int, extra: dict | None = None) -> None:
    save_pytree(path, {"params": params, "opt_state": opt_state,
                       "round_idx": np.asarray(round_idx),
                       "extra_json": np.frombuffer(
                           json.dumps(extra or {}).encode(), np.uint8)})


def restore_trainer_state(path: str):
    state = load_pytree(path)
    extra = json.loads(bytes(state["extra_json"]).decode())
    return state["params"], state["opt_state"], int(state["round_idx"]), extra

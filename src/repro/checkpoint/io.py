"""NPZ-based pytree checkpointing (+ blockchain state).

Leaves are stored under their flattened key-paths, so any nesting of
dict/list/tuple round-trips exactly (structure is stored alongside).
Atomic writes: temp file + rename.
"""
from __future__ import annotations

import json
import os
import pickle
import tempfile
from typing import Any

import jax
import numpy as np

Pytree = Any


def save_pytree(path: str, tree: Pytree) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    dtypes = {}
    for i, x in enumerate(leaves):
        arr = np.asarray(jax.device_get(x))
        dtypes[i] = str(arr.dtype)
        if arr.dtype.kind == "V" or str(arr.dtype) not in np.sctypeDict:
            # non-native dtypes (bfloat16, fp8 via ml_dtypes): store raw bytes
            arrays[f"leaf_{i}"] = arr.view(np.uint8).reshape(arr.shape + (-1,)) \
                if arr.ndim else np.frombuffer(arr.tobytes(), np.uint8)
            arrays[f"shape_{i}"] = np.asarray(arr.shape, np.int64)
        else:
            arrays[f"leaf_{i}"] = arr
    payload = {"treedef": pickle.dumps(treedef), "n": len(leaves),
               "dtypes": dtypes}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(pickle.dumps(payload), np.uint8), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str) -> Pytree:
    import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy

    with np.load(path, allow_pickle=False) as z:
        meta = pickle.loads(z["__meta__"].tobytes())
        treedef = pickle.loads(meta["treedef"])
        leaves = []
        for i in range(meta["n"]):
            arr = z[f"leaf_{i}"]
            want = meta.get("dtypes", {}).get(i, str(arr.dtype))
            if f"shape_{i}" in z:
                shape = tuple(z[f"shape_{i}"])
                arr = arr.reshape(-1).view(np.dtype(want)).reshape(shape)
            elif str(arr.dtype) != want:
                arr = arr.astype(np.dtype(want))
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_trainer_state(path: str, params: Pytree, opt_state: Pytree,
                       round_idx: int, extra: dict | None = None) -> None:
    save_pytree(path, {"params": params, "opt_state": opt_state,
                       "round_idx": np.asarray(round_idx),
                       "extra_json": np.frombuffer(
                           json.dumps(extra or {}).encode(), np.uint8)})


def restore_trainer_state(path: str):
    state = load_pytree(path)
    extra = json.loads(bytes(state["extra_json"]).decode())
    return state["params"], state["opt_state"], int(state["round_idx"]), extra

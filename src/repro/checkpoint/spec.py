"""`CheckpointSpec` — declarative checkpoint/resume configuration.

Jax-free, like :mod:`repro.obs.spec`, so :mod:`repro.api.spec` can import it
without pulling in the runtime.  When ``interval > 0`` the simulator
snapshots the *complete* experiment state (sharded arena gathered to host,
blockchain + txpool, ledger, async staleness buffer, both RNG streams,
virtual clock, event queue, round index) into ``dir`` at every round/flush
boundary divisible by ``interval``, keeping the newest ``keep_last``
snapshots.

Checkpointing is out of band for the *trajectory*: a run with checkpointing
on computes bit-identical results to one with it off, so ``CheckpointSpec``
is excluded from ``ExperimentSpec.config_digest()`` alongside ``obs`` —
resuming from a snapshot reproduces the uninterrupted run's manifest
digests exactly.
"""
from __future__ import annotations

from dataclasses import dataclass


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclass(frozen=True)
class CheckpointSpec:
    """Checkpoint configuration (``ExperimentSpec.checkpoint``).

    ``interval == 0`` (the default) disables checkpointing entirely; the
    driver then never touches the filesystem.
    """
    interval: int = 0            # snapshot every N rounds/flushes; 0 = off
    dir: str = "checkpoints"     # snapshot directory
    keep_last: int = 3           # keep-last-K pruning window

    def __post_init__(self):
        _check(self.interval >= 0,
               f"interval must be >= 0, got {self.interval}")
        _check(self.keep_last >= 1,
               f"keep_last must be >= 1, got {self.keep_last}")
        _check(isinstance(self.dir, str) and self.dir != "",
               "dir must be a non-empty string")

    @property
    def enabled(self) -> bool:
        return self.interval > 0

"""Capture/restore of the COMPLETE experiment state at a round boundary.

A snapshot is everything a resumed process needs to continue a run such
that the final manifest digests (event-log sha256, block hashes, balances,
final accuracy) are bit-identical to the uninterrupted run:

* the parameter state — the (gathered-to-host) arena matrix in engine mode,
  or the stacked param pytree in legacy-oracle mode,
* the blockchain (blocks + quarantined), the tx pool, the token ledger,
  and the CACC packing queue,
* the discrete-event machinery — virtual clock, the event queue's heap
  *as-is* (restoring the raw heap list preserves pop order exactly) and
  its insertion counter, the event log, and the round history,
* both numpy RNG streams (the driver's and the latency model's — the
  latency model owns a separate generator consumed per draw) plus the
  fault injector's stream,
* async mode: the FedBuff view — model version, global state, version
  snapshots, in-flight dispatch map, and the staleness buffer.

Arrays travel through the hardened npz channel of :mod:`repro.checkpoint.io`
(exact bytes, bfloat16-safe); host objects travel as one pickled blob
stored as a uint8 leaf.  Every snapshot stamps the spec's
``resume_digest()`` — the experiment identity *excluding* obs/checkpoint/
faults — so a run can be resumed with its fault schedule cleared or its
checkpoint cadence changed, but never silently resumed into a different
experiment.
"""
from __future__ import annotations

import pickle
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import CheckpointError

CAPTURE_VERSION = 1


def capture_experiment_state(sim, next_round: int,
                             async_view: dict | None = None) -> dict:
    """Snapshot ``sim`` (a ``repro.sim.SimulatedFederation``) at a boundary
    where ``next_round`` rounds/flushes have completed.  Returns the pytree
    handed to :func:`repro.checkpoint.io.save_checkpoint`."""
    # deferred device accuracies materialise now instead of at end of run —
    # same values, so the trajectory is unperturbed
    sim._finalize_history()
    trainer = sim.trainer
    host: dict[str, Any] = {
        "version": CAPTURE_VERSION,
        "resume_digest": sim.spec.resume_digest(),
        "mode": sim.cfg.mode,
        "next_round": int(next_round),
        "clock": sim.clock.now,
        "queue_heap": list(sim.queue._heap),
        "queue_seq": sim.queue._seq,
        "event_log": list(sim.event_log),
        "history": list(sim.history),
        "last_labels": sim.last_labels.copy(),
        "rng": sim.rng.bit_generator.state,
        "latency_rng": sim.pop.latency.rng.bit_generator.state,
        "chain_blocks": list(trainer.chain.blocks),
        "chain_quarantined": list(trainer.chain.quarantined),
        "pool_pending": list(trainer.pool.pending),
        "ledger_balances": trainer.ledger.balances.copy(),
        "ledger_minted": trainer.ledger.minted,
        "packing_queue": list(trainer._queue),
        "faults": sim.faults.state_dict(),
    }
    if async_view is not None:
        # at a flush boundary every buffered update is still delta-less
        # (deltas are materialised lazily inside the flush), so (client,
        # version) pairs reconstruct the buffer exactly
        host["async"] = {
            "version": int(async_view["version"]),
            "global_state": jax.device_get(async_view["global_state"]),
            "snapshots": {int(v): jax.device_get(s)
                          for v, s in async_view["snapshots"].items()},
            "inflight": dict(async_view["inflight"]),
            "buffer": [(int(u.client), int(u.version))
                       for u in async_view["agg"].buffer],
        }
    arrays: dict[str, Any] = {}
    if sim.arena is not None:
        arrays["arena"] = np.asarray(
            jax.device_get(sim.arena.data[: sim.arena.n_clients]))
    else:
        arrays["params"] = jax.device_get(sim._params)
    return {"arrays": arrays,
            "host": np.frombuffer(pickle.dumps(host), np.uint8)}


def restore_experiment_state(sim, tree: dict) -> tuple[int, dict | None]:
    """Restore a freshly-constructed ``sim`` (same spec, same population)
    from a snapshot tree.  Returns ``(next_round, async_view)`` where
    ``async_view`` (async mode only) re-seeds ``_run_async``'s loop state."""
    try:
        host = pickle.loads(np.asarray(tree["host"]).tobytes())
    except Exception as e:
        raise CheckpointError(f"snapshot host blob does not decode: {e}") from e
    if host.get("version") != CAPTURE_VERSION:
        raise CheckpointError(
            f"snapshot capture version {host.get('version')} != "
            f"{CAPTURE_VERSION}")
    want = sim.spec.resume_digest()
    if host["resume_digest"] != want:
        raise CheckpointError(
            "snapshot belongs to a different experiment: resume_digest "
            f"{host['resume_digest'][:12]} != spec's {want[:12]} (obs/"
            "checkpoint/faults sections are free to differ; everything else "
            "must match)")

    arrays = tree["arrays"]
    if sim.arena is not None:
        sim.arena.rebind(jnp.asarray(np.asarray(arrays["arena"])))
    else:
        sim._params = jax.tree.map(jnp.asarray, arrays["params"])

    sim.clock._now = float(host["clock"])
    sim.queue._heap = list(host["queue_heap"])
    sim.queue._seq = int(host["queue_seq"])
    sim.event_log[:] = host["event_log"]
    sim.history[:] = host["history"]
    sim.last_labels[:] = host["last_labels"]
    sim.rng.bit_generator.state = host["rng"]
    sim.pop.latency.rng.bit_generator.state = host["latency_rng"]

    chain = sim.trainer.chain
    chain.blocks[:] = host["chain_blocks"]
    chain.quarantined[:] = host["chain_quarantined"]
    sim.trainer.pool.pending[:] = host["pool_pending"]
    ledger = sim.trainer.ledger
    ledger.balances = np.asarray(host["ledger_balances"], np.float64)
    ledger.minted = float(host["ledger_minted"])
    sim.trainer._queue[:] = host["packing_queue"]
    sim.faults.load_state(host.get("faults"))

    av = host.get("async")
    if av is not None:
        from repro.sim.async_agg import BufferedAggregator, BufferedUpdate
        agg = BufferedAggregator(sim.cfg.buffer_size, sim.cfg.staleness_alpha)
        agg.buffer = [BufferedUpdate(c, None, v) for c, v in av["buffer"]]
        av = {
            "version": av["version"],
            "global_state": jax.tree.map(jnp.asarray, av["global_state"]),
            "snapshots": {v: jax.tree.map(jnp.asarray, s)
                          for v, s in av["snapshots"].items()},
            "inflight": dict(av["inflight"]),
            "agg": agg,
        }
    return int(host["next_round"]), av

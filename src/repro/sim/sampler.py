"""Per-round client sampling strategies.

A sampler picks the round's cohort from the currently-online clients.  All
samplers draw from an explicit ``numpy.random.Generator`` (deterministic
replay) and receive a :class:`SamplerState` snapshot of everything the server
legitimately knows: token balances (chain state) and each client's last CACC
cluster label (from the most recent round it participated in, ``-1`` if it
has never been clustered).

  * ``uniform``            — classic FedAvg-style uniform-without-replacement,
  * ``stake_weighted``     — inclusion probability ∝ ledger balance; couples
    sampling to the BFLN incentive loop (well-behaved clients accumulate
    stake and are sampled more — a DPoS-flavoured selection rule),
  * ``cluster_stratified`` — proportional allocation across CACC cluster
    labels, so every non-IID data cluster keeps representation even at small
    sampling rates; unlabeled clients form their own stratum (exploration).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass
class SamplerState:
    """What the server knows when sampling (all host-side, chain-derived)."""
    balances: np.ndarray | None = None      # (n,) token ledger balances
    last_labels: np.ndarray | None = None   # (n,) last CACC label, -1 unknown
    n_clusters: int = 0


# sampler(rng, online_ids, k, state) -> cohort ids (sorted, unique)
Sampler = Callable[[np.random.Generator, np.ndarray, int, SamplerState],
                   np.ndarray]


def _take(rng: np.random.Generator, ids: np.ndarray, k: int,
          p: np.ndarray | None = None) -> np.ndarray:
    k = min(k, len(ids))
    if k == 0:
        return np.empty(0, dtype=np.int64)
    sel = rng.choice(ids, size=k, replace=False, p=p)
    return np.sort(sel.astype(np.int64))


def uniform(rng: np.random.Generator, online: np.ndarray, k: int,
            state: SamplerState) -> np.ndarray:
    return _take(rng, online, k)


def stake_weighted(rng: np.random.Generator, online: np.ndarray, k: int,
                   state: SamplerState) -> np.ndarray:
    if state.balances is None:
        return _take(rng, online, k)
    w = np.maximum(np.asarray(state.balances, dtype=np.float64)[online], 1e-9)
    return _take(rng, online, k, p=w / w.sum())


def cluster_stratified(rng: np.random.Generator, online: np.ndarray, k: int,
                       state: SamplerState) -> np.ndarray:
    if state.last_labels is None:
        return _take(rng, online, k)
    labels = np.asarray(state.last_labels)[online]
    strata = [online[labels == c] for c in range(-1, state.n_clusters)]
    strata = [s for s in strata if len(s)]
    if not strata:
        return _take(rng, online, k)
    # proportional allocation with largest-remainder rounding
    sizes = np.array([len(s) for s in strata], dtype=np.float64)
    quota = k * sizes / sizes.sum()
    take = np.floor(quota).astype(int)
    rem = k - take.sum()
    if rem > 0:
        order = np.argsort(-(quota - take))
        take[order[:rem]] += 1
    take = np.minimum(take, sizes.astype(int))
    picks = [_take(rng, s, t) for s, t in zip(strata, take) if t > 0]
    cohort = np.concatenate(picks) if picks else np.empty(0, np.int64)
    # top up from the leftover pool if rounding or small strata left a gap
    if len(cohort) < k:
        left = np.setdiff1d(online, cohort, assume_unique=False)
        cohort = np.concatenate([cohort, _take(rng, left, k - len(cohort))])
    return np.sort(cohort)


SAMPLERS: dict[str, Sampler] = {
    "uniform": uniform,
    "stake_weighted": stake_weighted,
    "cluster_stratified": cluster_stratified,
}


def get_sampler(name: str) -> Sampler:
    try:
        return SAMPLERS[name]
    except KeyError:
        raise ValueError(f"unknown sampler {name!r}; options: {sorted(SAMPLERS)}")

"""Buffered asynchronous aggregation (FedBuff-style) with staleness weights.

Async mode removes the round barrier: clients are dispatched a snapshot of
the global model, train at their own speed, and their *deltas* (update −
snapshot) accumulate in a fixed-capacity buffer.  When the buffer fills, the
server merges it in one shot and bumps the model version.  An update that
trained against version ``v`` but merges at version ``v'`` has staleness
``s = v' − v`` and is down-weighted

    w(s) = (1 + s)^(-alpha)            (FedBuff / Nguyen et al., 2022)

so slow clients still contribute but cannot drag the model backwards.

The merge itself is the repo's one true weighted-mean collective — the
fixed-order tree reduction from ``repro.core.aggregation`` — so the jittable
inner program is shared by the fused engine and the legacy driver, and it
always runs on replicated (host-staged) buffer rows, which keeps async
seeded replay identical across mesh widths.  Chain
integration is the caller's job: the driver gates merge weights with CACC
verification, so tampered updates carry zero weight *and* zero reward.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import masked_tree_sum, tree_sum
from repro.utils.tree import tree_stack

Pytree = Any


def staleness_weight(staleness: jax.Array | np.ndarray,
                     alpha: float = 0.5) -> jax.Array:
    """(1 + s)^(-alpha); alpha=0 disables staleness discounting."""
    s = jnp.asarray(staleness, jnp.float32)
    return (1.0 + s) ** (-alpha)


@jax.jit
def weighted_delta_mean(stacked_deltas: Pytree, weights: jax.Array) -> Pytree:
    """Normalised weighted mean over the leading buffer axis, via the
    deterministic fixed-order tree (zero-weight slots are where-guarded to
    exactly +0.0, denominator clamped like the single-cluster collective it
    replaced)."""
    w = weights.astype(jnp.float32)
    denom = jnp.maximum(tree_sum(w), 1e-9)

    def leaf(x):
        return (masked_tree_sum(x.astype(jnp.float32), w) / denom) \
            .astype(x.dtype)

    return jax.tree.map(leaf, stacked_deltas)


@dataclass(frozen=True)
class BufferedUpdate:
    client: int
    delta: Pytree                 # local params − dispatch snapshot
    version: int                  # server model version at dispatch time


@dataclass
class MergeResult:
    delta: Pytree                 # staleness-weighted mean delta
    clients: np.ndarray           # (K,) contributing client ids
    staleness: np.ndarray         # (K,) int staleness per contribution
    weights: np.ndarray           # (K,) effective merge weights


@dataclass
class BufferedAggregator:
    """Fixed-capacity update buffer; :meth:`flush` merges and empties it."""

    capacity: int = 16
    alpha: float = 0.5
    buffer: list[BufferedUpdate] = field(default_factory=list)

    def add(self, update: BufferedUpdate) -> bool:
        """Returns True when the buffer has reached capacity (time to flush)."""
        self.buffer.append(update)
        return len(self.buffer) >= self.capacity

    def __len__(self) -> int:
        return len(self.buffer)

    def flush(self, current_version: int,
              gate: np.ndarray | None = None) -> MergeResult:
        """Merge everything buffered.  ``gate`` (optional, (K,) 0/1) zeroes
        the merge weight of individual contributions — the driver passes the
        chain's verification mask so unverified (tampered) updates are
        excluded from the model as well as from rewards."""
        if not self.buffer:
            raise ValueError("flush of empty buffer")
        clients = np.array([u.client for u in self.buffer], dtype=np.int64)
        staleness = np.array([current_version - u.version for u in self.buffer],
                             dtype=np.int64)
        w = np.asarray(staleness_weight(staleness, self.alpha), np.float32)
        if gate is not None:
            w = w * np.asarray(gate, np.float32)
        stacked = tree_stack([u.delta for u in self.buffer])
        merged = weighted_delta_mean(stacked, jnp.asarray(w))
        self.buffer = []
        return MergeResult(merged, clients, staleness, w)

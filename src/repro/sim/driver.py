"""`SimulatedFederation` — event-driven federation over a virtual population.

Layers realistic client dynamics (sampling, stragglers, dropouts, Byzantine
freeriders) on top of the existing BFLN machinery.  The driver is
strategy-generic: the experiment's strategy (BFLN or any registered
baseline, `repro.api.registry`) supplies both the local objective and the
jittable mask-weighted ``aggregate_cohort`` stage the fused round engine
traces.  Configuration arrives as a nested `repro.api.ExperimentSpec` (the
canonical form, see `repro.api.run`) or the flat legacy :class:`SimConfig`
(deprecated shim).  Per synchronous round:

    1. availability draw → online pool → sampler picks the cohort,
    2. cohort events scheduled on the virtual clock (arrival, update-ready
       after per-client latency, dropout), block slot closes the round,
    3. ONE fused, buffer-donated jitted step (`repro.core.engine`): arena
       gather → local training → PAA (arrival mask = aggregation weights) →
       cohort fingerprint digests → masked scatter-back into the donated
       parameter arena (`repro.runtime.arena`),
    4. `FederatedTrainer.chain_round` runs the full blockchain protocol over
       the cohort — hash commits, CACC packing queue, block, verification,
       participation-aware reward settlement on the population-wide ledger.

Async mode (``mode="async"``) replaces 2–3 with FedBuff buffered
aggregation: clients train against dispatched snapshots, finished deltas
buffer up, and each buffer flush = one block + one staleness-weighted merge
(merge weights are *gated by chain verification*, so tampered updates carry
zero weight and zero reward).

``SimConfig.engine=False`` preserves the pre-arena driver — eager per-leaf
gathers/scatters and shape-polymorphic eval — as the bit-identical oracle
for the engine (`tests/test_engine.py`) and the baseline for
``benchmarks/round_bench.py``.

``SimConfig.mesh_shards > 1`` row-shards the parameter arena over a
client-axis device mesh (`repro.runtime.arena.ShardedParamArena`): each
device holds only ``n_clients/shards`` rows of population state while the
cohort working set replicates, so seeded replay stays bit-identical to the
single-device engine (`tests/test_sharded_engine.py`).

Everything is driven by seeded numpy generators and a deterministic event
queue: two runs with the same config produce identical event logs, block
hashes, ledger balances and final parameters — with the engine on or off.

Modeling notes: cohort members that miss the deadline still burn local
compute (their training is simulated) but their params never reach the
producer — they keep their previous personalized model and earn nothing.
Byzantine clients train honestly but *commit a hash for params they did not
train* (the paper's freeriding attack); CACC verification catches the
mismatch.
"""
from __future__ import annotations

import functools
import os
import warnings
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.blockchain import TokenLedger
from repro.core import FederatedTrainer, ModelBundle, digest_of
from repro.core.engine import RoundEngine
from repro.core.fl import global_evaluate, local_train
from repro.faults import NULL_INJECTOR, FaultInjector
from repro.models import classifier as clf
from repro.obs import NULL_RECORDER, FlightRecorder
from repro.optim import adam
from repro.runtime.arena import ParamArena, ShardedParamArena
from repro.sim import events as ev
from repro.sim.async_agg import (
    BufferedAggregator,
    BufferedUpdate,
    staleness_weight,
    weighted_delta_mean,
)
from repro.sim.clock import VirtualClock
from repro.sim.events import EventQueue
from repro.sim.population import ClientPopulation
from repro.sim.sampler import SamplerState, get_sampler
from repro.utils.tree import tree_index, tree_stack

Pytree = Any


_SIMCONFIG_INTERNAL = False    # True while repro.api builds the flat view


@dataclass(frozen=True)
class SimConfig:
    """Flat legacy experiment config.

    .. deprecated::
        Build a nested :class:`repro.api.ExperimentSpec` instead (and run it
        with :func:`repro.api.run`).  ``SimConfig(...)`` keeps working as a
        shim: it validates, maps onto the nested spec via :meth:`to_spec`,
        and ``SimulatedFederation`` accepts either form.
    """
    rounds: int = 20                  # sync rounds, or async buffer flushes
    sample_frac: float = 0.10
    n_clusters: int = 5
    local_epochs: int = 1
    lr: float = 1e-3
    deadline: float = 30.0            # virtual seconds per block slot (sync)
    sampler: str = "uniform"
    mode: str = "sync"                # "sync" | "async"
    strategy: str = "bfln"            # repro.api.registry name
    strategy_params: dict = field(default_factory=dict)
    buffer_size: int = 16             # async: flush threshold K
    staleness_alpha: float = 0.5      # async: w(s) = (1+s)^-alpha
    server_lr: float = 1.0            # async: global += lr · merged delta
    concurrency: int = 64             # async: target in-flight clients
    total_reward: float = 20.0
    rho: float = 2.0
    initial_stake: float = 5.0
    eval_every: int = 5               # 0 = only final eval
    eval_clients: int = 128           # population sub-sample for evaluation
    eval_examples: int = 1024         # shared-test sub-sample for evaluation
    hidden: tuple[int, ...] = (64,)
    rep_dim: int = 32
    engine: bool = True               # arena-backed fused round engine
    mesh_shards: int = 1              # >1: shard the arena's client axis over
                                      # a device mesh (engine mode only); on
                                      # CPU force devices with XLA_FLAGS=
                                      # --xla_force_host_platform_device_count=N
    mesh_cohort: str = "sharded"      # cohort axis on that mesh: "sharded"
                                      # slices + tree-combines, "replicated"
                                      # gathers the cohort to every device
    seed: int = 0

    def __post_init__(self):
        # ONE source of validation truth: building the nested spec runs every
        # sub-spec's __post_init__ (mode/sampler/strategy membership,
        # fractions, positivity, the mesh-requires-engine cross check) — a
        # bad value raises ValueError here, at construction, never deep
        # inside the round loop
        self.to_spec()
        if not _SIMCONFIG_INTERNAL:
            warnings.warn(
                "SimConfig is deprecated; build a nested "
                "repro.api.ExperimentSpec and run it with repro.api.run() "
                "(SimConfig(...) keeps working as a shim via .to_spec())",
                DeprecationWarning, stacklevel=3)

    @classmethod
    def _internal(cls, **kw) -> "SimConfig":
        """Construct the flat view without the deprecation warning (used by
        ``ExperimentSpec.sim_config()``); validation still runs."""
        global _SIMCONFIG_INTERNAL
        prev, _SIMCONFIG_INTERNAL = _SIMCONFIG_INTERNAL, True
        try:
            return cls(**kw)
        finally:
            _SIMCONFIG_INTERNAL = prev

    def to_spec(self, data=None):
        """The equivalent nested :class:`repro.api.ExperimentSpec` (the
        old-kwargs → new-spec mapping the compat test pins).  ``data`` may
        supply a :class:`repro.api.DataSpec`; population-less callers (the
        common case — they pass a materialised population) get defaults."""
        from repro.api.spec import (
            AsyncSpec,
            ChainSpec,
            DataSpec,
            EvalSpec,
            ExperimentSpec,
            MeshSpec,
            TrainSpec,
        )
        return ExperimentSpec(
            data=data if data is not None else DataSpec(),
            train=TrainSpec(
                strategy=self.strategy,
                strategy_params=dict(self.strategy_params),
                rounds=self.rounds, sample_frac=self.sample_frac,
                n_clusters=self.n_clusters, local_epochs=self.local_epochs,
                lr=self.lr, deadline=self.deadline, sampler=self.sampler,
                mode=self.mode, hidden=tuple(self.hidden),
                rep_dim=self.rep_dim),
            async_=AsyncSpec(
                buffer_size=self.buffer_size,
                staleness_alpha=self.staleness_alpha,
                server_lr=self.server_lr, concurrency=self.concurrency),
            eval=EvalSpec(every=self.eval_every, clients=self.eval_clients,
                          examples=self.eval_examples),
            chain=ChainSpec(total_reward=self.total_reward, rho=self.rho,
                            initial_stake=self.initial_stake),
            mesh=MeshSpec(shards=self.mesh_shards, cohort=self.mesh_cohort),
            engine=self.engine, seed=self.seed)


@dataclass
class SimRoundRecord:
    round_idx: int
    t_open: float
    t_close: float
    cohort: np.ndarray
    arrived: np.ndarray               # (k,) bool
    n_stragglers: int
    n_dropouts: int
    n_byzantine: int
    producer: int
    verified_frac: float
    reward_paid: float
    reward_burned: float
    mean_loss: float
    accuracy: float = float("nan")    # cohort accuracy (sync) / global (async)
    staleness_mean: float = 0.0       # async only
    cluster_accuracy: np.ndarray | None = None   # (C,) engine-mode sync eval


@dataclass
class SimReport:
    config: SimConfig
    history: list[SimRoundRecord]
    event_log: list[tuple]
    final_accuracy: float
    balances: np.ndarray
    chain_valid: bool
    n_blocks: int
    ledger_conserved: bool

    def summary(self) -> str:
        h = self.history
        paid = sum(r.reward_paid for r in h)
        burned = sum(r.reward_burned for r in h)
        return (f"{len(h)} rounds, {len(self.event_log)} events, "
                f"final_acc={self.final_accuracy:.4f}, paid={paid:.1f}, "
                f"burned={burned:.1f}, blocks={self.n_blocks}, "
                f"chain_valid={self.chain_valid}, "
                f"conserved={self.ledger_conserved}")


class SimulatedFederation:
    """Drives `FederatedTrainer` round logic over sampled cohorts of a
    virtual client population, on a deterministic virtual clock.

    ``config`` may be a nested :class:`repro.api.ExperimentSpec` (the
    canonical form) or a flat legacy :class:`SimConfig`; both normalise to
    the same pair (``self.spec``, ``self.cfg``).  The strategy is resolved
    by name through :mod:`repro.api.registry`, so any registered strategy —
    BFLN or a Table II baseline — runs through the fused round engine, the
    simulator, and the sharded mesh.
    """

    def __init__(self, population: ClientPopulation, config):
        from repro.api.registry import build_strategy
        from repro.api.spec import ExperimentSpec
        if isinstance(config, ExperimentSpec):
            self.spec = config
            config = config.sim_config()
        else:
            self.spec = config.to_spec()
        self.pop = population
        self.cfg = config
        n = population.n_clients

        mcfg = clf.MLPConfig(in_dim=population.in_dim,
                             hidden=tuple(config.hidden),
                             rep_dim=config.rep_dim,
                             num_classes=population.num_classes)
        self.mcfg = mcfg    # the serving tier rebuilds forwards from this
        self.bundle = ModelBundle(functools.partial(clf.apply, mcfg),
                                  functools.partial(clf.embed, mcfg),
                                  population.num_classes)
        self.opt = adam(config.lr)
        strat = build_strategy(config.strategy, self.bundle,
                               probe=population.probe,
                               n_clusters=config.n_clusters,
                               **config.strategy_params)
        self.trainer = FederatedTrainer(
            self.bundle, strat, self.opt, local_epochs=config.local_epochs,
            n_clusters=config.n_clusters, total_reward=config.total_reward,
            rho=config.rho, initial_stake=config.initial_stake)
        # population-wide ledger (the trainer's chain_round settles against it)
        self.trainer.ledger = TokenLedger(n, config.initial_stake)

        self.arena: ParamArena | None = None
        self.engine: RoundEngine | None = None
        self.params = clf.init_stacked(mcfg, jax.random.PRNGKey(config.seed), n)
        # shared tamper digest for Byzantine commits (built once; chain_round
        # substitutes the digest each freerider *claims*, which never varies)
        self._fake_digest = digest_of(
            jax.tree.map(jnp.zeros_like, tree_index(self.params, 0)))
        self.last_labels = np.full(n, -1, dtype=np.int64)
        self.sampler = get_sampler(config.sampler)

        self.rng = np.random.default_rng(config.seed)
        self.clock = VirtualClock()
        self.queue = EventQueue()
        self.event_log: list[tuple] = []
        self.history: list[SimRoundRecord] = []

        # flight recorder (repro.obs): spans/metrics ride along out of band.
        # Disabled runs bind the shared no-op recorder — the hot path then
        # pays only no-op method calls (the < 2% trace-off budget).
        obs_spec = getattr(self.spec, "obs", None)
        if obs_spec is not None and obs_spec.enabled:
            self.obs = FlightRecorder(obs_spec, clock=lambda: self.clock.now)
        else:
            self.obs = NULL_RECORDER

        # checkpoint/resume + fault injection (repro.checkpoint/repro.faults):
        # both default off and follow the recorder's no-op-object pattern, so
        # the default hot path is bit-identical to a build without them
        ckpt_spec = getattr(self.spec, "checkpoint", None)
        self.ckpt = ckpt_spec if (ckpt_spec is not None
                                  and ckpt_spec.enabled) else None
        fault_spec = getattr(self.spec, "faults", None)
        if fault_spec is not None and fault_spec.enabled:
            self.faults = FaultInjector(fault_spec, obs=self.obs)
        else:
            self.faults = NULL_INJECTOR
        self._resume_async: dict | None = None
        self._resumed_from: tuple[str, int] | None = None
        self._ckpt_written = 0
        self._ckpt_bytes = 0
        self._ckpt_executor = None     # lazy single-worker snapshot writer
        self._ckpt_future = None       # at most one write in flight

        strategy = strat
        opt = self.opt
        n_clusters = config.n_clusters
        epochs = config.local_epochs

        if config.engine:
            # flatten the population ONCE into the (n, N) arena; all round
            # state now lives as donated rows of this matrix.  mesh_shards>1
            # row-shards the arena over a client-axis device mesh — each
            # device then holds only n/shards rows of population state
            if config.mesh_shards > 1:
                from repro.launch.mesh import make_client_mesh
                self.arena = ShardedParamArena.from_stacked(
                    self._params, make_client_mesh(config.mesh_shards))
            else:
                self.arena = ParamArena.from_stacked(self._params)
            self._params = None
            self.engine = RoundEngine(
                self.arena.layout, apply_fn=self.bundle.apply_fn,
                strategy=strategy, opt=opt,
                n_clusters=n_clusters, local_epochs=epochs,
                stacked_apply_fn=functools.partial(clf.apply_stacked, mcfg),
                sharding=getattr(self.arena, "sharding", None),
                cohort_mode=config.mesh_cohort,
                obs=self.obs)
            if self.obs.enabled:
                self.obs.set_gauge("arena.bytes", int(self.arena.data.nbytes))
                per_dev = getattr(self.arena, "per_device_bytes", None)
                self.obs.set_gauge(
                    "arena.per_device_bytes",
                    int(per_dev()) if per_dev else int(self.arena.data.nbytes))
                # per-round cohort collective traffic (see repro.core.engine):
                # sharded cohort moves each device's slice in/out plus the
                # replicated combine block; replicated mode gathers the full
                # (k, N) block in and scatters the row updates out
                k = max(1, int(round(config.sample_frac * n)))
                n_params = self.arena.layout.n_params
                if self.engine.cohort_mode == "sharded":
                    s = self.engine.cohort_shards
                    k_pad = -(-k // s) * s
                    per_dev_slice = (k_pad // s) * n_params * 4
                    traffic = 2 * per_dev_slice + k_pad * n_params * 4
                else:
                    traffic = 2 * k * n_params * 4
                self.obs.set_gauge("engine.cohort_bytes", traffic)
        self.trainer.attach_obs(self.obs)
        self.trainer.attach_faults(self.faults)

        # ------- legacy (pre-arena) jitted programs, kept as the oracle ---- #

        @jax.jit
        def _cohort_round(cohort_params, cx, cy, arrived_w):
            """Local training (fresh per-round optimizer, standard for sampled
            cohorts) + the strategy's cohort aggregation weighted by the
            arrival mask (BFLN: the PAA pipeline)."""
            opt_state = jax.vmap(opt.init)(cohort_params)
            extras = strategy.round_extras(cohort_params, cx, cy)
            res = local_train(strategy.local_loss, opt, cohort_params,
                              opt_state, cx, cy, extras, epochs,
                              shared_extras=strategy.shared_extras)
            agg = strategy.aggregate_cohort(res.params, cx, cy, arrived_w)
            return res.params, agg, jnp.mean(res.mean_loss)

        self._cohort_round = _cohort_round

        @jax.jit
        def _local_only(cohort_params, cx, cy):
            """Async path: just the local updates (aggregation happens at
            flush time in ``async_agg.weighted_delta_mean``)."""
            opt_state = jax.vmap(opt.init)(cohort_params)
            extras = strategy.round_extras(cohort_params, cx, cy)
            res = local_train(strategy.local_loss, opt, cohort_params,
                              opt_state, cx, cy, extras, epochs,
                              shared_extras=strategy.shared_extras)
            return res.params, jnp.mean(res.mean_loss)

        self._local_only = _local_only
        self._eval = jax.jit(functools.partial(global_evaluate,
                                               self.bundle.apply_fn))
        # the final population eval has its own jitted entry: its leading dim
        # (eval_clients) differs from the round cohort's, and sharing one
        # cache entry per distinct shape made compile counts unauditable
        self._eval_final = jax.jit(functools.partial(global_evaluate,
                                                     self.bundle.apply_fn))

    # ------------------------------------------------------------------ #
    # stacked-params view (legacy attribute; engine mode stores the arena)
    # ------------------------------------------------------------------ #

    @property
    def params(self) -> Pytree:
        if self.arena is not None:
            return self.arena.as_pytree()
        return self._params

    @params.setter
    def params(self, value: Pytree) -> None:
        if self.arena is not None:
            self.arena.rebind(self.arena.layout.flatten(value))
        else:
            self._params = value

    # ------------------------------------------------------------------ #
    # shared helpers
    # ------------------------------------------------------------------ #

    def _log(self, event: ev.Event) -> None:
        self.event_log.append(event.log_entry())

    def _sampler_state(self) -> SamplerState:
        return SamplerState(balances=self.trainer.ledger.balances,
                            last_labels=self.last_labels,
                            n_clusters=self.cfg.n_clusters)

    def _tampers(self, cohort: np.ndarray, arrived: np.ndarray) -> dict:
        """Byzantine freeriders commit digests of params they did not train."""
        return {int(gid): self._fake_digest
                for slot, gid in enumerate(cohort)
                if arrived[slot] and self.pop.byzantine[gid]}

    def _schedule_retries(self, r: int, gid: int, t_fail: float,
                          lat: float) -> None:
        """Bounded retry-with-backoff for a dropped cohort slot
        (``FaultSpec.retry``).  Every redraw comes from the injector's own
        seeded generator — the simulator's streams are untouched, so the
        retry knob perturbs nothing else and replays/resumes exactly.  A
        recovered client may still miss the deadline: retry is bounded, not
        a delivery guarantee."""
        faults, obs = self.faults, self.obs
        t_retry = t_fail
        for attempt in range(1, faults.spec.retry_max + 1):
            with obs.span("round.retry", round=r, client=gid,
                          attempt=attempt) as sp:
                t_retry += faults.retry_latency(lat, attempt)
                ok = faults.retry_succeeds(self.pop.dropout[gid])
                sp.set(t_retry=t_retry, recovered=ok)
            obs.inc("fault.retry")
            if ok:
                self.queue.push(t_retry, ev.UPDATE_READY, gid, r)
                obs.inc("fault.retry_recovered")
                return
            self.queue.push(t_retry, ev.DROPOUT, gid, r)

    def _eval_slices(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        return (self.pop.test_x[: self.cfg.eval_examples],
                self.pop.test_y[: self.cfg.eval_examples])

    def _evaluate_clients(self, ids: np.ndarray) -> float:
        ex, ey = self._eval_slices()
        if self.engine is not None:
            return float(self.engine.eval_population(
                self.arena.data, jnp.asarray(ids), ex, ey))
        stacked = jax.tree.map(lambda x: x[jnp.asarray(ids)], self._params)
        return float(self._eval_final(stacked, ex, ey))

    # ------------------------------------------------------------------ #
    # synchronous mode
    # ------------------------------------------------------------------ #

    def _run_sync_round(self, r: int) -> SimRoundRecord:
        with self.obs.span("round.total", round=r) as rt:
            return self._sync_round_body(r, rt)

    def _sync_round_body(self, r: int, rt) -> SimRoundRecord:
        cfg, pop, rng, obs = self.cfg, self.pop, self.rng, self.obs
        self.faults.maybe_crash(r, "round_start")
        t0 = self.clock.now
        k = max(1, int(round(cfg.sample_frac * pop.n_clients)))

        with obs.span("round.sample", round=r) as sp:
            online = pop.online_clients(rng)
            cohort = self.sampler(rng, online, k, self._sampler_state())
            sp.set(online=len(online), k=len(cohort))
        self.queue.push(t0 + cfg.deadline, ev.BLOCK_SLOT, round_idx=r)

        dropouts: set[int] = set()        # classified at schedule time — a
        for gid in cohort:                # dropout past the deadline is still
            gid = int(gid)                # a death, not a straggler
            self.queue.push(t0, ev.CLIENT_ARRIVAL, gid, r)
            lat = pop.latency.draw(gid)
            if rng.random() < pop.dropout[gid]:
                dropouts.add(gid)
                t_fail = t0 + lat * rng.uniform(0.1, 0.9)
                self.queue.push(t_fail, ev.DROPOUT, gid, r)
                if self.faults.retry:
                    self._schedule_retries(r, gid, t_fail, lat)
            else:
                self.queue.push(t0 + lat, ev.UPDATE_READY, gid, r)

        arrived_set: set[int] = set()
        with obs.span("round.wait", round=r) as sp:
            # the block slot on the VIRTUAL clock: wall time here is event
            # bookkeeping, the span's vt_dur attr is the simulated wait
            n_events = 0
            while True:
                e = self.queue.pop()
                self.clock.advance_to(e.time)
                self._log(e)
                n_events += 1
                if e.kind == ev.BLOCK_SLOT and e.round_idx == r:
                    break
                if e.round_idx != r:
                    continue                  # late event from an old round
                if e.kind == ev.UPDATE_READY:
                    arrived_set.add(e.client)
            sp.set(n_events=n_events)

        arrived = np.array([int(g) in arrived_set for g in cohort], dtype=bool)
        # with FaultSpec.retry a dropout may recover and still arrive;
        # count only the deaths that stuck (faults off: identical to before)
        n_drop = sum(1 for g in dropouts if g not in arrived_set)
        n_strag = int(len(cohort) - arrived.sum() - n_drop)
        rt.set(arrived=int(arrived.sum()))

        record = SimRoundRecord(
            round_idx=r, t_open=t0, t_close=self.clock.now, cohort=cohort,
            arrived=arrived, n_stragglers=n_strag, n_dropouts=n_drop,
            n_byzantine=int(pop.byzantine[cohort][arrived].sum()),
            producer=-1, verified_frac=0.0, reward_paid=0.0,
            reward_burned=0.0, mean_loss=float("nan"))

        if not arrived.any():
            obs.inc("rounds.empty")
            return record                     # empty round: no block minted

        with obs.span("round.gather", round=r):
            cx, cy = pop.cohort_data(cohort)
        arrived_w = jnp.asarray(arrived, jnp.float32)

        if self.engine is not None:
            # ONE donated device program: gather → train → PAA → digests →
            # masked scatter-back; the host sees only O(cohort) bytes
            cohort_idx = jnp.asarray(cohort)
            with obs.span("round.step", round=r,
                          shards=self.engine.cohort_shards,
                          cohort_mode=self.engine.cohort_mode):
                self.arena.data, out = self.engine.sync_step(
                    self.arena.data, cohort_idx, cx, cy, arrived_w)
                obs.ready(out)
            if obs.enabled:
                obs.compile_delta(self.engine.cache_sizes(), r)
            labels_dev, mean_loss = out.labels, out.mean_loss
            with obs.span("round.digests", round=r):
                digests = self.engine.format_digests(out.residues)
            self.faults.maybe_crash(r, "pre_chain")
            with obs.span("round.chain", round=r):
                cres = self.trainer.chain_round(
                    r, None, labels_dev, out.corr, cohort=cohort,
                    arrived=arrived, tamper=self._tampers(cohort, arrived),
                    digests=digests)
        else:
            with obs.span("round.step", round=r):
                cohort_params = jax.tree.map(lambda x: x[jnp.asarray(cohort)],
                                             self._params)
                local_params, agg, mean_loss = self._cohort_round(
                    cohort_params, cx, cy, arrived_w)
                obs.ready(mean_loss)
            labels_dev = agg.labels
            self.faults.maybe_crash(r, "pre_chain")
            with obs.span("round.chain", round=r):
                cres = self.trainer.chain_round(
                    r, local_params, agg.labels, agg.corr, cohort=cohort,
                    arrived=arrived, tamper=self._tampers(cohort, arrived))

            # arrived clients adopt their aggregated model; stragglers and
            # dropouts keep their previous personalized params
            with obs.span("round.scatter", round=r):
                new_rows = jax.tree.map(
                    lambda x: x[jnp.asarray(np.flatnonzero(arrived))],
                    agg.stacked_params)
                upd_ids = jnp.asarray(np.asarray(cohort)[arrived])
                self._params = jax.tree.map(
                    lambda P, rows: P.at[upd_ids].set(rows),
                    self._params, new_rows)

        upd = np.asarray(cohort)[arrived]
        labels = np.asarray(labels_dev)
        self.last_labels[upd] = labels[arrived]

        record.producer = cres.producer
        record.verified_frac = float(cres.verified[arrived].mean())
        record.reward_paid = float(cres.rewards.sum())
        record.reward_burned = float(cfg.total_reward - cres.rewards.sum())
        record.mean_loss = float(mean_loss)
        if cfg.eval_every and ((r + 1) % cfg.eval_every == 0):
            ex, ey = self._eval_slices()
            if self.engine is not None:
                # fixed-shape mask-weighted eval: the cohort shape never
                # changes, so this entry compiles exactly once.  The outputs
                # stay on device — metrics never gate the round, so the eval
                # overlaps the next round's host work (`_finalize_history`
                # materialises them at end of run).  Tracing blocks on them
                # (timing attribution only — the values are unchanged).
                with obs.span("round.eval", round=r):
                    acc, cacc = self.engine.eval_cohort(
                        out.new_rows, arrived_w, labels_dev, ex, ey)
                    obs.ready(acc)
                if obs.enabled:
                    obs.compile_delta(self.engine.cache_sizes(), r)
                record.accuracy = acc
                record.cluster_accuracy = cacc
            else:
                # evaluate only the adopted (arrived) rows: stragglers keep
                # their old params, and a cluster with zero arrivals yields a
                # garbage row.  new_rows' leading dim varies with the arrival
                # count → one jit recompile per distinct count (the engine
                # path exists to kill exactly this).
                with obs.span("round.eval", round=r):
                    record.accuracy = float(self._eval(new_rows, ex, ey))
        return record

    # ------------------------------------------------------------------ #
    # asynchronous mode (FedBuff)
    # ------------------------------------------------------------------ #

    def _run_async(self) -> None:
        cfg, pop, rng = self.cfg, self.pop, self.rng
        if cfg.buffer_size + cfg.concurrency > pop.n_clients:
            # buffered clients stay "busy" until their flush: a buffer that
            # cannot fill from the remaining population stalls forever
            raise ValueError(
                f"buffer_size ({cfg.buffer_size}) + concurrency "
                f"({cfg.concurrency}) exceeds the population "
                f"({pop.n_clients}); the buffer could never fill")
        resume = self._resume_async
        self._resume_async = None
        if resume is not None:
            # loop state restored from a flush-boundary snapshot
            # (`repro.checkpoint.state`): the post-flush dispatch already
            # happened before the snapshot, so the loop re-enters directly
            version = resume["version"]
            global_state = resume["global_state"]
            snapshots: dict[int, Any] = resume["snapshots"]
            inflight: dict[int, int] = resume["inflight"]
            agg = resume["agg"]
        else:
            version = 0
            if self.arena is not None:
                global_state = self.arena.data[0]      # (N,) flat row
            else:
                global_state = tree_index(self._params, 0)
            snapshots = {0: global_state}
            inflight = {}                  # client -> dispatch version
            agg = BufferedAggregator(cfg.buffer_size, cfg.staleness_alpha)

        def dispatch() -> None:
            want = cfg.concurrency - len(inflight)
            if want <= 0:
                return
            # a client already in flight OR sitting in the buffer must not be
            # re-dispatched: a duplicate in one flush cohort would collapse
            # its two rewards into one ledger scatter slot
            busy = set(inflight) | {u.client for u in agg.buffer}
            online = pop.online_clients(rng)
            online = np.setdiff1d(online, np.fromiter(busy, np.int64,
                                                      len(busy)))
            picked = self.sampler(rng, online, want, self._sampler_state())
            t = self.clock.now
            for gid in picked:
                gid = int(gid)
                inflight[gid] = version
                self.queue.push(t, ev.CLIENT_ARRIVAL, gid,
                                round_idx=version, tag=version)
                lat = pop.latency.draw(gid)
                if rng.random() < pop.dropout[gid]:
                    self.queue.push(t + lat * rng.uniform(0.1, 0.9),
                                    ev.DROPOUT, gid, version, tag=version)
                else:
                    self.queue.push(t + lat, ev.UPDATE_READY, gid, version,
                                    tag=version)

        if resume is None:
            dispatch()
        while version < cfg.rounds and self.queue:
            e = self.queue.pop()
            self.clock.advance_to(e.time)
            self._log(e)
            if e.kind == ev.DROPOUT:
                inflight.pop(e.client, None)
                dispatch()
                continue
            if e.kind != ev.UPDATE_READY:
                continue
            dispatched_v = inflight.pop(e.client, None)
            if dispatched_v is None:
                continue
            agg.add(BufferedUpdate(e.client, None, dispatched_v))
            flushed = len(agg) >= cfg.buffer_size
            if flushed:
                version, global_state = self._async_flush(
                    agg, version, global_state, snapshots)
                snapshots[version] = global_state
                live = set(inflight.values()) | {version}
                for v in [v for v in snapshots if v not in live]:
                    del snapshots[v]
            dispatch()
            if flushed:
                # flush boundary: snapshot AFTER the post-flush dispatch so
                # a resume re-enters the loop with nothing left to re-issue
                self._maybe_checkpoint(version, async_view={
                    "version": version, "global_state": global_state,
                    "snapshots": snapshots, "inflight": inflight,
                    "agg": agg})
                if self.faults.will_crash(version, "post_checkpoint"):
                    self._ckpt_wait()      # snapshot durable before dying
                self.faults.maybe_crash(version, "post_checkpoint")

        if version < cfg.rounds:
            # event queue drained early (e.g. availability collapse) — the
            # report simply carries fewer flushes than requested
            self.event_log.append((self.clock.now, "queue_drained", -1,
                                   version, 0))
        if self.arena is not None:
            self.arena.rebind(jnp.broadcast_to(
                global_state[None],
                (self.arena.n_clients,) + global_state.shape))
        else:
            self._params = jax.tree.map(
                lambda g: jnp.broadcast_to(g[None], (pop.n_clients,) + g.shape),
                global_state)

    def _async_flush(self, agg: BufferedAggregator, version: int,
                     global_state, snapshots: dict) -> tuple:
        """One buffer flush = one training batch + one block + one merge."""
        with self.obs.span("flush.total", cat="flush", round=version):
            return self._async_flush_body(agg, version, global_state,
                                          snapshots)

    def _async_flush_body(self, agg: BufferedAggregator, version: int,
                          global_state, snapshots: dict) -> tuple:
        cfg, pop, obs = self.cfg, self.pop, self.obs
        self.faults.maybe_crash(version, "round_start")
        clients = np.array([u.client for u in agg.buffer], dtype=np.int64)
        versions = [u.version for u in agg.buffer]
        k = len(clients)
        with obs.span("flush.gather", cat="flush", round=version):
            cx, cy = pop.cohort_data(clients)

        # chain: single-cluster CACC over the flush group
        labels = jnp.zeros((k,), jnp.int32)
        corr = jnp.eye(k, dtype=jnp.float32)
        arrived = np.ones(k, dtype=bool)
        tamper = self._tampers(clients, arrived)

        if self.engine is not None:
            layout = self.arena.layout
            with obs.span("flush.step", cat="flush", round=version,
                          shards=self.engine.cohort_shards,
                          cohort_mode=self.engine.cohort_mode):
                base_rows = jnp.stack(
                    [snapshots[v] for v in versions])          # (k, N)
                local_rows, residues, mean_loss = self.engine.async_step(
                    base_rows, cx, cy)
                obs.ready(local_rows)
            if obs.enabled:
                obs.compile_delta(self.engine.cache_sizes(), version)
            self.faults.maybe_crash(version, "pre_chain")
            with obs.span("flush.chain", cat="flush", round=version):
                cres = self.trainer.chain_round(
                    version, None, labels, corr, cohort=clients,
                    arrived=arrived, tamper=tamper,
                    digests=self.engine.format_digests(residues))
            staleness = np.array([version - v for v in versions], np.int64)
            w = np.asarray(staleness_weight(staleness, cfg.staleness_alpha),
                           np.float32) * cres.verified.astype(np.float32)
            with obs.span("flush.merge", cat="flush", round=version):
                # merge through the SAME jitted collective as the legacy path
                # (same leaf shapes -> same executable -> bit-identical
                # replay); the unflatten/flatten round-trips are exact
                # reshapes
                deltas = layout.unflatten(local_rows - base_rows)
                merged = weighted_delta_mean(deltas, jnp.asarray(w))
                merged_row = layout.flatten(
                    jax.tree.map(lambda x: x[None], merged))[0]
                global_state = global_state + cfg.server_lr * merged_row
                obs.ready(global_state)
            agg.buffer = []
            staleness_mean = float(staleness.mean())
            staleness_w = w
        else:
            with obs.span("flush.step", cat="flush", round=version):
                base = tree_stack([snapshots[v] for v in versions])
                local_params, mean_loss = self._local_only(base, cx, cy)
                deltas = jax.tree.map(lambda a, b: a - b, local_params, base)
                obs.ready(mean_loss)
            # re-materialise the buffer with the actual deltas (kept lazy
            # until now so every flush trains its K clients in one vmapped
            # call)
            agg.buffer = [BufferedUpdate(int(c), tree_index(deltas, i), v)
                          for i, (c, v) in enumerate(zip(clients, versions))]
            self.faults.maybe_crash(version, "pre_chain")
            with obs.span("flush.chain", cat="flush", round=version):
                cres = self.trainer.chain_round(
                    version, local_params, labels, corr, cohort=clients,
                    arrived=arrived, tamper=tamper)
            with obs.span("flush.merge", cat="flush", round=version):
                merge = agg.flush(version,
                                  gate=cres.verified.astype(np.float32))
                global_state = jax.tree.map(
                    lambda g, d: g + cfg.server_lr * d.astype(g.dtype),
                    global_state, merge.delta)
                obs.ready(global_state)
            staleness = np.asarray(merge.staleness)
            staleness_mean = float(staleness.mean())
            staleness_w = np.asarray(
                staleness_weight(staleness, cfg.staleness_alpha),
                np.float32) * cres.verified.astype(np.float32)

        if obs.enabled:
            # staleness-weight distribution: how much each flush discounts
            # its stale contributors (and zeroes its unverified ones)
            for s in staleness:
                obs.observe("async.staleness", float(s))
            for wv in staleness_w:
                obs.observe("async.staleness_weight", float(wv))
            obs.point("async.staleness_mean", staleness_mean, round=version)

        new_version = version + 1
        self.last_labels[clients] = 0
        record = SimRoundRecord(
            round_idx=version, t_open=self.clock.now, t_close=self.clock.now,
            cohort=clients, arrived=arrived, n_stragglers=0, n_dropouts=0,
            n_byzantine=int(pop.byzantine[clients].sum()),
            producer=cres.producer,
            verified_frac=float(cres.verified.mean()),
            reward_paid=float(cres.rewards.sum()),
            reward_burned=float(cfg.total_reward - cres.rewards.sum()),
            mean_loss=float(mean_loss),
            staleness_mean=staleness_mean)
        if cfg.eval_every and (new_version % cfg.eval_every == 0):
            ex, ey = self._eval_slices()
            if self.engine is not None:
                # deferred like the sync eval: materialised at end of run
                with obs.span("flush.eval", cat="flush", round=version):
                    record.accuracy = self.engine.eval_global(
                        global_state, ex, ey)
                    obs.ready(record.accuracy)
                if obs.enabled:
                    obs.compile_delta(self.engine.cache_sizes(), version)
            else:
                with obs.span("flush.eval", cat="flush", round=version):
                    stacked = jax.tree.map(lambda g: g[None], global_state)
                    record.accuracy = float(self._eval(stacked, ex, ey))
        self.history.append(record)
        return new_version, global_state

    # ------------------------------------------------------------------ #

    def _finalize_history(self) -> None:
        """Materialise deferred (still-on-device) eval metrics.  The engine
        path leaves accuracy outputs as device arrays so metric extraction
        never blocks the round hot path."""
        for rec in self.history:
            if not isinstance(rec.accuracy, float):
                rec.accuracy = float(rec.accuracy)
            if rec.cluster_accuracy is not None:
                rec.cluster_accuracy = np.asarray(rec.cluster_accuracy)

    def _maybe_checkpoint(self, boundary: int,
                          async_view: dict | None = None) -> None:
        """Snapshot the complete experiment state when ``boundary``
        (completed rounds/flushes) hits the checkpoint interval.

        Only the *capture* (a consistent host copy of all state) runs on the
        round hot path; the expensive half — npz encode, sha256, write,
        fsync — is handed to a single background writer thread so the next
        round overlaps the disk work (the <10% steady-overhead budget,
        `benchmarks/round_bench.py --checkpoint-interval`).  At most one
        write is in flight: a new boundary first retires the previous one.
        Crash consistency is unaffected — the writer stages to a temp file
        and atomically renames, so a death mid-write leaves the previous
        snapshot intact — and a scheduled ``post_checkpoint`` crash flushes
        the writer first (see :meth:`run`), keeping the kill-and-resume
        contract exact.  The fault injector corrupts the file (if scheduled)
        only after its write completes."""
        ck = self.ckpt
        if ck is None or boundary == 0 or boundary % ck.interval:
            return
        from repro.checkpoint import save_checkpoint
        from repro.checkpoint.state import capture_experiment_state
        with self.obs.span("ckpt.save", cat="ckpt", round=boundary) as sp:
            tree = capture_experiment_state(self, boundary, async_view)
            self._ckpt_wait()          # retire the previous in-flight write
            if self._ckpt_executor is None:
                from concurrent.futures import ThreadPoolExecutor
                self._ckpt_executor = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="ckpt-writer")
            faults = self.faults

            def _write() -> int:
                path, n_bytes = save_checkpoint(ck.dir, boundary, tree,
                                                keep_last=ck.keep_last)
                faults.corrupt_checkpoint(path, boundary)
                return n_bytes
            self._ckpt_future = self._ckpt_executor.submit(_write)
            sp.set(boundary=boundary)

    def _ckpt_wait(self) -> None:
        """Block until the in-flight snapshot write (if any) is durable,
        then account for it (``ckpt.saved`` counter, ``ckpt.bytes`` gauge).
        Re-raises a failed write's exception on the main thread."""
        fut, self._ckpt_future = self._ckpt_future, None
        if fut is None:
            return
        n_bytes = fut.result()
        self.obs.inc("ckpt.saved")
        self.obs.set_gauge("ckpt.bytes", n_bytes)
        self._ckpt_written += 1
        self._ckpt_bytes = n_bytes

    def _restore(self, resume_from: str) -> int:
        """Restore from ``resume_from`` (a snapshot file, or a checkpoint
        directory whose newest *readable* snapshot is used).  Returns the
        next round/flush index to execute."""
        from repro.checkpoint import load_latest, load_pytree
        from repro.checkpoint.state import restore_experiment_state
        with self.obs.span("ckpt.restore", cat="ckpt") as sp:
            if os.path.isdir(resume_from):
                _, tree = load_latest(resume_from)
            else:
                tree = load_pytree(resume_from)
            next_round, async_view = restore_experiment_state(self, tree)
            sp.set(step=next_round)
        self.obs.inc("ckpt.restored")
        self._resume_async = async_view
        self._resumed_from = (resume_from, next_round)
        return next_round

    def run(self, resume_from: str | None = None) -> SimReport:
        cfg = self.cfg
        start = self._restore(resume_from) if resume_from is not None else 0
        if cfg.mode == "sync":
            for r in range(start, cfg.rounds):
                self.history.append(self._run_sync_round(r))
                self._maybe_checkpoint(r + 1)
                if self.faults.will_crash(r + 1, "post_checkpoint"):
                    self._ckpt_wait()      # snapshot durable before dying
                self.faults.maybe_crash(r + 1, "post_checkpoint")
        elif cfg.mode == "async":
            self._run_async()
        else:
            raise ValueError(f"unknown mode {cfg.mode!r}")
        self._ckpt_wait()                  # retire any in-flight snapshot
        if self._ckpt_executor is not None:
            self._ckpt_executor.shutdown(wait=True)
            self._ckpt_executor = None
        self._finalize_history()

        n_eval = min(cfg.eval_clients, self.pop.n_clients)
        eval_ids = np.linspace(0, self.pop.n_clients - 1, n_eval).astype(int)
        with self.obs.span("run.final_eval", cat="run") as sp:
            final_acc = self._evaluate_clients(eval_ids)
            sp.set(n_eval=n_eval)
        if self.obs.enabled and self.engine is not None:
            self.obs.compile_delta(self.engine.cache_sizes())
        ledger = self.trainer.ledger
        report = SimReport(
            config=cfg, history=self.history, event_log=self.event_log,
            final_accuracy=final_acc, balances=ledger.balances.copy(),
            chain_valid=self.trainer.chain.validate(),
            n_blocks=len(self.trainer.chain.blocks),
            ledger_conserved=ledger.conserved())
        if self.obs.enabled:
            self.obs.set_gauge("run.final_accuracy", report.final_accuracy)
            self.obs.set_gauge("run.n_blocks", report.n_blocks)
        return report

"""Virtual client populations over the non-IID partitions.

Scales the paper's 20 always-on clients to thousands of *virtual* clients:
each client owns a Dirichlet label-skew shard (``repro.data.partition``) plus
a behavioural profile —

  * ``speed``        — latency multiplier (stragglers live in the slow tail),
  * ``availability`` — probability the client is online when a round starts,
  * ``dropout``      — probability an accepted client dies mid-round,
  * ``byzantine``    — commits a hash for params it did not train (the
                       paper's freeriding attack, caught by CACC verification).

Data stays rectangular (every client: ``n_batches × batch_size`` train
samples + a small local test split) so any sampled cohort stacks into the
vmapped trainer without reshaping.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.sim.clock import LatencyModel, make_speed_profile


@dataclass(frozen=True)
class PopulationSpec:
    n_clients: int = 1000
    dataset: str = "synth10"
    beta: float = 0.3                 # Dirichlet label-skew concentration
    n_batches: int = 1
    batch_size: int = 16
    availability: float = 0.85        # mean per-round online probability
    dropout_rate: float = 0.03        # mean mid-round death probability
    straggler_frac: float = 0.10
    straggler_slowdown: float = 8.0
    byzantine_frac: float = 0.0
    base_latency: float = 10.0        # virtual seconds, 1×-speed local round
    latency_sigma: float = 0.25
    psi: int = 32                     # probe-batch size for PAA
    seed: int = 0


@dataclass
class ClientPopulation:
    """Materialised population: data shards + behaviour profiles + latency."""

    spec: PopulationSpec
    cx: jnp.ndarray                   # (n, n_batches, B, ...) train
    cy: jnp.ndarray                   # (n, n_batches, B)
    tx: np.ndarray                    # (n, n_test, ...) per-client local test
    ty: np.ndarray                    # (n, n_test)
    test_x: jnp.ndarray               # shared global test split
    test_y: jnp.ndarray
    probe: jnp.ndarray                # (psi, ...) PAA probe batch
    num_classes: int
    in_dim: int
    availability: np.ndarray          # (n,) per-client online probability
    dropout: np.ndarray               # (n,) per-client mid-round death prob
    byzantine: np.ndarray             # (n,) bool
    latency: LatencyModel = field(repr=False)

    @property
    def n_clients(self) -> int:
        return self.spec.n_clients

    @classmethod
    def from_spec(cls, spec: PopulationSpec) -> "ClientPopulation":
        rng = np.random.default_rng(spec.seed)
        (xt, yt), (xe, ye) = make_classification_dataset(spec.dataset,
                                                         seed=spec.seed)
        parts = dirichlet_partition(yt, spec.n_clients, spec.beta,
                                    seed=spec.seed)
        cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=spec.n_batches,
                                      batch_size=spec.batch_size,
                                      seed=spec.seed)
        probe = sample_probe_batch(xt, yt, category=0, psi=spec.psi,
                                   seed=spec.seed)

        n = spec.n_clients
        # per-client behaviour, jittered around the spec means
        avail = np.clip(rng.normal(spec.availability, 0.08, size=n), 0.05, 1.0)
        drop = np.clip(rng.normal(spec.dropout_rate, spec.dropout_rate / 2,
                                  size=n), 0.0, 0.9)
        byz = np.zeros(n, dtype=bool)
        n_byz = int(round(spec.byzantine_frac * n))
        if n_byz:
            byz[rng.choice(n, size=n_byz, replace=False)] = True

        speed = make_speed_profile(n, spec.straggler_frac,
                                   spec.straggler_slowdown, rng)
        latency = LatencyModel(speed, spec.base_latency, spec.latency_sigma,
                               np.random.default_rng(spec.seed + 1))
        return cls(
            spec=spec,
            cx=jnp.asarray(cx), cy=jnp.asarray(cy), tx=tx, ty=ty,
            test_x=jnp.asarray(xe), test_y=jnp.asarray(ye),
            probe=jnp.asarray(probe),
            num_classes=int(yt.max()) + 1, in_dim=int(xt.shape[1]),
            availability=avail, dropout=drop, byzantine=byz,
            latency=latency,
        )

    # ------------------------------------------------------------------ #

    def online_clients(self, rng: np.random.Generator) -> np.ndarray:
        """Ids of clients online at a round boundary (availability draw)."""
        return np.flatnonzero(rng.random(self.n_clients) < self.availability)

    def cohort_data(self, cohort: np.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Stacked (k, n_batches, B, ...) train data for a sampled cohort."""
        idx = jnp.asarray(np.asarray(cohort))
        return self.cx[idx], self.cy[idx]

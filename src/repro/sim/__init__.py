"""`repro.sim` — event-driven federation simulator.

Layers realistic client populations (partial participation, stragglers,
dropouts, Byzantine freeriders) on top of the BFLN core: a deterministic
virtual-time event queue drives sampled cohorts through the full protocol —
local training, PAA aggregation, hash commits, block packing, CACC
verification and participation-aware reward settlement — or through FedBuff
buffered asynchronous aggregation with staleness-weighted, chain-gated
merging.
"""
from repro.sim.async_agg import (  # noqa: F401
    BufferedAggregator,
    BufferedUpdate,
    MergeResult,
    staleness_weight,
    weighted_delta_mean,
)
from repro.sim.clock import LatencyModel, VirtualClock, make_speed_profile  # noqa: F401
from repro.sim.driver import (  # noqa: F401
    SimConfig,
    SimReport,
    SimRoundRecord,
    SimulatedFederation,
)
from repro.sim.events import Event, EventQueue  # noqa: F401
from repro.sim.population import ClientPopulation, PopulationSpec  # noqa: F401
from repro.sim.sampler import SAMPLERS, SamplerState, get_sampler  # noqa: F401

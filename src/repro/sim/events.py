"""Event types and the deterministic event queue for the federation simulator.

The simulator is a classic discrete-event loop over *virtual* time: nothing
sleeps, every latency is a number drawn from a seeded distribution, and the
queue pops events in (time, insertion-seq) order so two runs with the same
seed produce byte-identical event logs — the property every chain validator
needs to replay a simulated round.

Event kinds (ISSUE terminology):

  * ``CLIENT_ARRIVAL`` — a sampled client accepts the round's task and starts
    local training (sync mode) or is dispatched a global-model snapshot
    (async mode),
  * ``UPDATE_READY``   — the client's trained update reaches the aggregator
    after its compute+network latency,
  * ``DROPOUT``        — the client died mid-round; its update never arrives,
  * ``BLOCK_SLOT``     — the DPoS block slot closes; whatever has arrived by
    now is what the producer aggregates (sync mode deadline).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

CLIENT_ARRIVAL = "client_arrival"
UPDATE_READY = "update_ready"
DROPOUT = "dropout"
BLOCK_SLOT = "block_slot"


@dataclass(frozen=True, order=True)
class Event:
    """One scheduled occurrence.  Ordering is (time, seq): ``seq`` is the
    queue's insertion counter, so simultaneous events resolve in the exact
    order they were scheduled — deterministic under replay."""
    time: float
    seq: int
    kind: str = field(compare=False)
    client: int = field(compare=False, default=-1)
    round_idx: int = field(compare=False, default=-1)
    # free-form small payload (e.g. dispatch model version for async staleness)
    tag: int = field(compare=False, default=0)

    def log_entry(self) -> tuple:
        """Compact hashable form for the replayable event log."""
        return (round(self.time, 9), self.kind, self.client, self.round_idx, self.tag)


class EventQueue:
    """Min-heap of :class:`Event` with a deterministic tiebreak counter."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client: int = -1,
             round_idx: int = -1, tag: int = 0) -> Event:
        ev = Event(float(time), self._seq, kind, client, round_idx, tag)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

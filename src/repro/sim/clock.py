"""Virtual clock and per-client latency model (straggler machinery).

Latency of one local-training round for client *i* is modeled as

    latency_i = base · speed_i · LogNormal(0, sigma²)

where ``speed_i`` is a per-client multiplier fixed at population build time:
most clients draw from a narrow band around 1×, a ``straggler_frac`` tail
draws an extra ``straggler_slowdown``× factor.  A lognormal jitter on top
reproduces the heavy-tailed round times observed in cross-device FL (clients
on flaky networks occasionally take many deadlines to respond, not just one).

Everything is driven by ``numpy.random.Generator`` streams seeded once, so
latencies — and therefore every arrival ordering downstream — replay exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class VirtualClock:
    """Monotone virtual time.  The event loop owns advancement — nothing in
    the simulator ever reads a wall clock."""

    def __init__(self, start: float = 0.0) -> None:
        self._now = float(start)

    @property
    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> float:
        if t < self._now:
            raise ValueError(f"virtual time moved backwards: {t} < {self._now}")
        self._now = float(t)
        return self._now


@dataclass
class LatencyModel:
    """Seeded per-client round-latency sampler."""

    speed: np.ndarray                 # (n,) fixed per-client multiplier
    base: float = 10.0                # mean seconds of one local round at 1×
    sigma: float = 0.25               # lognormal jitter
    rng: np.random.Generator = field(
        default_factory=lambda: np.random.default_rng(0))

    def draw(self, client: int) -> float:
        jitter = float(np.exp(self.rng.normal(0.0, self.sigma)))
        return self.base * float(self.speed[client]) * jitter


def make_speed_profile(n_clients: int, straggler_frac: float,
                       straggler_slowdown: float,
                       rng: np.random.Generator) -> np.ndarray:
    """(n,) per-client speed multipliers: a narrow band around 1× plus a
    heavy ``straggler_slowdown``× tail for ``straggler_frac`` of clients."""
    speed = rng.uniform(0.8, 1.25, size=n_clients)
    n_strag = int(round(straggler_frac * n_clients))
    if n_strag:
        stragglers = rng.choice(n_clients, size=n_strag, replace=False)
        speed[stragglers] *= straggler_slowdown
    return speed.astype(np.float64)

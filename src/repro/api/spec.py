"""`ExperimentSpec` — ONE declarative description of a BFLN experiment.

The public experiment surface used to be three disjoint entry points: the
legacy ``FederatedTrainer`` (hand-wired bundle/optimizer/data), the flat
22-field ``SimConfig`` (BFLN hardcoded), and per-example wiring.  The spec
nests the flat knobs into nine sub-configs —

    data        population: shards, behaviour profiles, latency (→ PopulationSpec)
    train       the round loop: strategy, rounds, sampling, model width, lr
    async_      FedBuff buffered aggregation (mode="async" only)
    eval        metric cadence and sub-sampling
    chain       blockchain incentives: reward pool, rho, initial stake
    mesh        client-axis device mesh for the sharded arena
    obs         flight recorder: span tracing + metrics sinks (→ repro.obs)
    checkpoint  crash-consistent snapshot/resume (→ repro.checkpoint)
    faults      seeded fault-injection schedule (→ repro.faults)

— and is the input to :func:`repro.api.run`.  Every spec round-trips through
JSON (``from_json(to_json(spec)) == spec``) and hashes to a stable
``config_digest`` that is stamped into every run manifest, so a result can
always be traced back to the exact configuration that produced it.

Validation happens at construction: invalid ``mode`` / ``sampler`` /
``strategy`` / ``mesh_shards`` / fraction values raise ``ValueError``
immediately instead of failing deep inside the round loop.  The legacy
``SimConfig`` delegates to the same validators (and still works, with a
``DeprecationWarning``) — see ``repro.sim.driver``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Mapping

from repro.checkpoint.spec import CheckpointSpec
from repro.faults.spec import FaultSpec
from repro.obs.spec import ObsSpec


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _check_frac(name: str, value: float, *, lo: float = 0.0, hi: float = 1.0,
                lo_open: bool = False) -> None:
    ok = (value > lo if lo_open else value >= lo) and value <= hi
    _check(ok, f"{name} must be in {'(' if lo_open else '['}{lo}, {hi}], "
               f"got {value!r}")


@dataclass(frozen=True)
class DataSpec:
    """The virtual client population (mirrors ``repro.sim.PopulationSpec``)."""
    n_clients: int = 1000
    dataset: str = "synth10"
    beta: float = 0.3                 # Dirichlet label-skew concentration
    n_batches: int = 1
    batch_size: int = 16
    availability: float = 0.85
    dropout_rate: float = 0.03
    straggler_frac: float = 0.10
    straggler_slowdown: float = 8.0
    byzantine_frac: float = 0.0
    base_latency: float = 10.0
    latency_sigma: float = 0.25
    psi: int = 32                     # probe-batch size for PAA

    def __post_init__(self):
        _check(self.n_clients >= 1, f"n_clients must be >= 1, got {self.n_clients}")
        for f in ("n_batches", "batch_size", "psi"):
            _check(getattr(self, f) >= 1, f"{f} must be >= 1, got {getattr(self, f)}")
        _check(self.beta > 0, f"beta must be > 0, got {self.beta}")
        _check_frac("availability", self.availability, lo_open=True)
        for f in ("dropout_rate", "straggler_frac", "byzantine_frac"):
            _check_frac(f, getattr(self, f))
        _check(self.straggler_slowdown >= 1.0,
               f"straggler_slowdown must be >= 1, got {self.straggler_slowdown}")
        _check(self.base_latency > 0, f"base_latency must be > 0, got {self.base_latency}")


@dataclass(frozen=True)
class TrainSpec:
    """The round loop: which strategy runs, over whom, for how long."""
    strategy: str = "bfln"            # repro.api.registry name
    strategy_params: Mapping[str, Any] = field(default_factory=dict)
    rounds: int = 20                  # sync rounds, or async buffer flushes
    sample_frac: float = 0.10
    n_clusters: int = 5
    local_epochs: int = 1
    lr: float = 1e-3
    deadline: float = 30.0            # virtual seconds per block slot (sync)
    sampler: str = "uniform"
    mode: str = "sync"                # "sync" | "async"
    hidden: tuple[int, ...] = (64,)   # MLP widths of the trained model
    rep_dim: int = 32

    def __post_init__(self):
        # strategy membership is checked lazily against the registry so the
        # spec module stays importable without the strategy factories
        from repro.api.registry import strategy_names
        _check(self.strategy in strategy_names(),
               f"unknown strategy {self.strategy!r}; "
               f"registered: {strategy_names()}")
        _check(self.mode in ("sync", "async"),
               f"mode must be 'sync' or 'async', got {self.mode!r}")
        from repro.sim.sampler import SAMPLERS
        _check(self.sampler in SAMPLERS,
               f"unknown sampler {self.sampler!r}; options: {sorted(SAMPLERS)}")
        _check_frac("sample_frac", self.sample_frac, lo_open=True)
        for f in ("rounds", "n_clusters", "local_epochs"):
            _check(getattr(self, f) >= 1, f"{f} must be >= 1, got {getattr(self, f)}")
        _check(self.lr > 0, f"lr must be > 0, got {self.lr}")
        _check(self.deadline > 0, f"deadline must be > 0, got {self.deadline}")
        _check(self.rep_dim >= 1, f"rep_dim must be >= 1, got {self.rep_dim}")
        _check(len(self.hidden) >= 1 and all(h >= 1 for h in self.hidden),
               f"hidden must be a non-empty tuple of widths, got {self.hidden!r}")


@dataclass(frozen=True)
class AsyncSpec:
    """FedBuff buffered aggregation knobs (``mode='async'`` only)."""
    buffer_size: int = 16             # flush threshold K
    staleness_alpha: float = 0.5      # w(s) = (1+s)^-alpha
    server_lr: float = 1.0            # global += lr · merged delta
    concurrency: int = 64             # target in-flight clients

    def __post_init__(self):
        _check(self.buffer_size >= 1, f"buffer_size must be >= 1, got {self.buffer_size}")
        _check(self.concurrency >= 1, f"concurrency must be >= 1, got {self.concurrency}")
        _check(self.staleness_alpha >= 0,
               f"staleness_alpha must be >= 0, got {self.staleness_alpha}")
        _check(self.server_lr > 0, f"server_lr must be > 0, got {self.server_lr}")


@dataclass(frozen=True)
class EvalSpec:
    every: int = 5                    # 0 = only final eval
    clients: int = 128                # population sub-sample for evaluation
    examples: int = 1024              # shared-test sub-sample for evaluation

    def __post_init__(self):
        _check(self.every >= 0, f"every must be >= 0, got {self.every}")
        _check(self.clients >= 1, f"clients must be >= 1, got {self.clients}")
        _check(self.examples >= 1, f"examples must be >= 1, got {self.examples}")


@dataclass(frozen=True)
class ChainSpec:
    """Blockchain incentives (paper Table I)."""
    total_reward: float = 20.0
    rho: float = 2.0
    initial_stake: float = 5.0

    def __post_init__(self):
        _check(self.total_reward >= 0, f"total_reward must be >= 0, got {self.total_reward}")
        _check(self.rho >= 0, f"rho must be >= 0, got {self.rho}")
        _check(self.initial_stake >= 0, f"initial_stake must be >= 0, got {self.initial_stake}")


#: Cohort-axis execution modes for the mesh round engine.
COHORT_MODES = ("sharded", "replicated")


@dataclass(frozen=True)
class MeshSpec:
    """Client-axis device mesh for the row-sharded parameter arena.

    ``cohort`` picks how the per-round cohort executes on that mesh:
    ``"sharded"`` (default) trains each device's cohort slice locally and
    combines shard-local aggregation partials with a fixed-order tree;
    ``"replicated"`` gathers the whole cohort to every device (the pre-shard
    behaviour — still bit-identical, kept as an escape hatch for strategies
    without partial/combine stages).

    ``platform`` / ``x64`` / ``xla_flags`` are process-level runtime knobs
    resolved by :func:`repro.launch.platform.bootstrap` BEFORE jax
    initialises — they cannot take effect once a backend exists, which is
    why they live on the spec rather than in ad-hoc shell exports.
    """
    shards: int = 1
    cohort: str = "sharded"           # "sharded" | "replicated"
    platform: str = ""                # "" = let jax pick ("cpu"/"gpu"/"tpu")
    x64: bool = False                 # enable float64 (JAX_ENABLE_X64)
    xla_flags: tuple[str, ...] = ()   # extra XLA_FLAGS, appended in order

    def __post_init__(self):
        _check(isinstance(self.shards, int) and self.shards >= 1,
               f"mesh shards must be an int >= 1, got {self.shards!r}")
        _check(self.cohort in COHORT_MODES,
               f"mesh cohort must be one of {COHORT_MODES}, "
               f"got {self.cohort!r}")
        _check(isinstance(self.platform, str),
               f"mesh platform must be a string, got {self.platform!r}")
        _check(isinstance(self.x64, bool),
               f"mesh x64 must be a bool, got {self.x64!r}")
        _check(isinstance(self.xla_flags, tuple)
               and all(isinstance(f, str) and f for f in self.xla_flags),
               f"mesh xla_flags must be a tuple of non-empty strings, "
               f"got {self.xla_flags!r}")


_SUB_SPECS = {"data": DataSpec, "train": TrainSpec, "async_": AsyncSpec,
              "eval": EvalSpec, "chain": ChainSpec, "mesh": MeshSpec,
              "obs": ObsSpec, "checkpoint": CheckpointSpec,
              "faults": FaultSpec}

#: FaultSpec round-list fields normalised list -> tuple on JSON load.
_FAULT_TUPLE_FIELDS = ("producer_fail_rounds", "bad_block_rounds",
                       "drop_commit_rounds", "delay_commit_rounds")


@dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, declaratively: ``run(spec) -> ExperimentResult``."""
    data: DataSpec = field(default_factory=DataSpec)
    train: TrainSpec = field(default_factory=TrainSpec)
    async_: AsyncSpec = field(default_factory=AsyncSpec)
    eval: EvalSpec = field(default_factory=EvalSpec)
    chain: ChainSpec = field(default_factory=ChainSpec)
    mesh: MeshSpec = field(default_factory=MeshSpec)
    obs: ObsSpec = field(default_factory=ObsSpec)   # flight recorder (off)
    checkpoint: CheckpointSpec = field(         # snapshot/resume (off)
        default_factory=CheckpointSpec)
    faults: FaultSpec = field(default_factory=FaultSpec)  # injection (off)
    engine: bool = True               # arena-backed fused round engine
    seed: int = 0

    def __post_init__(self):
        # cross-field constraint (was a deep-in-the-driver failure before)
        _check(self.mesh.shards == 1 or self.engine,
               "mesh shards > 1 requires engine=True (the legacy oracle "
               "driver is single-device only)")

    # ------------------------------------------------------------------ #
    # conversions
    # ------------------------------------------------------------------ #

    def population_spec(self):
        """The ``repro.sim.PopulationSpec`` this experiment's population uses
        (seeded with the experiment seed)."""
        from repro.sim.population import PopulationSpec
        return PopulationSpec(**dataclasses.asdict(self.data), seed=self.seed)

    def sim_config(self):
        """Flat legacy view (``repro.sim.SimConfig``) consumed by the round
        loop; constructed without the deprecation warning."""
        from repro.sim.driver import SimConfig
        t, a, e, c = self.train, self.async_, self.eval, self.chain
        return SimConfig._internal(
            rounds=t.rounds, sample_frac=t.sample_frac,
            n_clusters=t.n_clusters, local_epochs=t.local_epochs, lr=t.lr,
            deadline=t.deadline, sampler=t.sampler, mode=t.mode,
            strategy=t.strategy, strategy_params=dict(t.strategy_params),
            buffer_size=a.buffer_size, staleness_alpha=a.staleness_alpha,
            server_lr=a.server_lr, concurrency=a.concurrency,
            total_reward=c.total_reward, rho=c.rho,
            initial_stake=c.initial_stake, eval_every=e.every,
            eval_clients=e.clients, eval_examples=e.examples,
            hidden=tuple(t.hidden), rep_dim=t.rep_dim, engine=self.engine,
            mesh_shards=self.mesh.shards, mesh_cohort=self.mesh.cohort,
            seed=self.seed)

    @classmethod
    def from_flat(cls, data: DataSpec | None = None, **flat) -> "ExperimentSpec":
        """Build a nested spec from flat ``SimConfig``-style kwargs — the
        migration path for CLIs and benchmarks that accumulate flat knobs."""
        from repro.sim.driver import SimConfig
        return SimConfig._internal(**flat).to_spec(data=data)

    # ------------------------------------------------------------------ #
    # JSON round trip + digest
    # ------------------------------------------------------------------ #

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["train"]["hidden"] = list(self.train.hidden)
        d["train"]["strategy_params"] = dict(self.train.strategy_params)
        d["mesh"]["xla_flags"] = list(self.mesh.xla_flags)
        for f in _FAULT_TUPLE_FIELDS:
            d["faults"][f] = list(getattr(self.faults, f))
        return d

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        if "async" in d:                      # friendly alias for the
            d["async_"] = d.pop("async")      # keyword-escaped field name
        unknown = set(d) - set(_SUB_SPECS) - {"engine", "seed"}
        if unknown:
            # silently dropping a misspelt section would run defaults under a
            # digest the author never configured — reject loudly instead
            raise ValueError(
                f"unknown spec section(s) {sorted(unknown)}; expected "
                f"{sorted(_SUB_SPECS)} + ['engine', 'seed']")
        kw: dict[str, Any] = {}
        for name, sub_cls in _SUB_SPECS.items():
            sub = dict(d.get(name, {}))
            if name == "train" and "hidden" in sub:
                sub["hidden"] = tuple(sub["hidden"])
            if name == "mesh" and "xla_flags" in sub:
                sub["xla_flags"] = tuple(sub["xla_flags"])
            if name == "faults":
                for f in _FAULT_TUPLE_FIELDS:
                    if f in sub:
                        sub[f] = tuple(sub[f])
            kw[name] = sub_cls(**sub)
        for name in ("engine", "seed"):
            if name in d:
                kw[name] = d[name]
        return cls(**kw)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def config_digest(self) -> str:
        """Stable SHA-256 over the canonical JSON form — the reproducibility
        stamp every run manifest carries.

        The ``obs`` and ``checkpoint`` sections are excluded: both are
        out-of-band by contract — observability times and counts but never
        perturbs, and checkpointing snapshots state without changing the
        trajectory (the resume tests pin bit-identical manifests with
        checkpointing on, off, and resumed-from) — so such runs all share
        the same replay recipe.  ``faults`` IS included: an injected fault
        schedule perturbs the run it describes.
        """
        d = self.to_dict()
        d.pop("obs", None)
        d.pop("checkpoint", None)
        return hashlib.sha256(
            json.dumps(d, sort_keys=True).encode()).hexdigest()

    def resume_digest(self) -> str:
        """The experiment identity a checkpoint binds to: like
        ``config_digest`` but ALSO excluding ``faults``, so a crashed run can
        be resumed with its fault schedule cleared (a ``round_start`` crash
        fault would otherwise re-fire on every resume, forever) while any
        change to the underlying experiment is still rejected at restore."""
        d = self.to_dict()
        for section in ("obs", "checkpoint", "faults"):
            d.pop(section, None)
        return hashlib.sha256(
            json.dumps(d, sort_keys=True).encode()).hexdigest()

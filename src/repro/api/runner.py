"""`run(spec) -> ExperimentResult` — the one way to run an experiment.

Builds the population from ``spec.data``, drives the event-driven simulator
(every strategy goes through the fused, arena-backed round engine unless
``spec.engine=False``), and returns the report together with a *manifest*:
a flat, JSON-able record stamped with the spec's ``config_digest`` so any
result can be traced to — and replayed from — the exact configuration that
produced it.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any

from repro.api.spec import ExperimentSpec
from repro.obs import console_summary, write_chrome_trace, write_jsonl
from repro.sim import ClientPopulation, SimReport, SimulatedFederation


def event_log_digest(event_log) -> str:
    """SHA-256 over the full (virtual-time, kind, client) event stream —
    same seed + same spec ⇒ same digest, across engine on/off and mesh
    widths."""
    return hashlib.sha256(
        json.dumps(event_log, sort_keys=False).encode()).hexdigest()


@dataclass
class ExperimentResult:
    spec: ExperimentSpec
    report: SimReport
    manifest: dict[str, Any] = field(default_factory=dict)
    # the live simulator behind the run — carries the trained arena, the
    # chain, and the virtual clock so `repro.serve.snapshot/serve` can turn
    # a finished run into a serving tier.  Excluded from repr/comparison:
    # results compare by what they report, not by runtime identity.
    sim: Any = field(default=None, repr=False, compare=False)

    def summary(self) -> str:
        m = self.manifest
        line = (f"[{m['strategy']}/{m['mode']}] {self.report.summary()} "
                f"config_digest={m['config_digest'][:12]}")
        t = m.get("timing")
        if t:
            unit = "flush" if m.get("mode") == "async" else "round"
            line += (f"\n  timing: {unit} p50={t.get('round_ms_p50', 0):.1f}ms"
                     f" p99={t.get('round_ms_p99', 0):.1f}ms")
            if "chain_overhead_pct" in t:
                line += f" chain={t['chain_overhead_pct']:.1f}%"
            line += f" compiles={t.get('compiles', 0)}"
        return line


def build_manifest(spec: ExperimentSpec, sim: SimulatedFederation,
                   report: SimReport) -> dict[str, Any]:
    """The reproducibility record: config digest first, then everything a
    replay must reproduce bit for bit."""
    manifest: dict[str, Any] = {
        "config_digest": spec.config_digest(),
        "strategy": spec.train.strategy,
        "mode": spec.train.mode,
        "sampler": spec.train.sampler,
        "engine": spec.engine,
        "mesh_shards": spec.mesh.shards,
        "seed": spec.seed,
        "n_clients": sim.pop.n_clients,
        "rounds_run": len(report.history),
        "event_log_digest": event_log_digest(report.event_log),
        "block_hashes_digest": hashlib.sha256("".join(
            b.block_hash() for b in sim.trainer.chain.blocks
        ).encode()).hexdigest(),
        "n_blocks": report.n_blocks,
        "chain_valid": report.chain_valid,
        "ledger_conserved": report.ledger_conserved,
        "balances_digest": hashlib.sha256(
            report.balances.tobytes()).hexdigest(),
        "final_accuracy": report.final_accuracy,
    }
    if sim.engine is not None:
        manifest["engine_compile_counts"] = sim.engine.cache_sizes()
    if sim.ckpt is not None:
        manifest["checkpoints_written"] = sim._ckpt_written
        manifest["checkpoint_bytes"] = sim._ckpt_bytes
    if sim._resumed_from is not None:
        manifest["resumed_from"] = sim._resumed_from[0]
        manifest["resume_step"] = sim._resumed_from[1]
    return manifest


def format_manifest(manifest: dict[str, Any]) -> str:
    return "\n".join(f"  {k}: {v}" for k, v in manifest.items())


def run(spec: ExperimentSpec, population: ClientPopulation | None = None,
        resume_from: str | None = None) -> ExperimentResult:
    """Run one experiment end to end.

    ``population`` may be passed explicitly to reuse an already-materialised
    population across experiments (e.g. strategy sweeps over the same
    shards); by default it is built from ``spec.data`` with ``spec.seed``.
    A supplied population must match the spec — the manifest stamps the
    spec's ``config_digest`` as the replay recipe, which only holds if the
    population is the one ``spec.data``/``spec.seed`` would rebuild.

    ``resume_from`` restores a snapshot written by ``spec.checkpoint`` (a
    file path, or a checkpoint directory whose newest readable snapshot is
    used) and continues the run from that boundary.  The snapshot's stamped
    ``resume_digest`` must match the spec's — obs/checkpoint/faults sections
    are free to differ (so a crashed run can be resumed with its fault
    schedule cleared), everything else must be the same experiment.  A
    resumed run finishes with manifest digests bit-identical to the
    uninterrupted run's.
    """
    if population is None:
        population = ClientPopulation.from_spec(spec.population_spec())
    elif population.spec != spec.population_spec():
        raise ValueError(
            "supplied population was built from a different PopulationSpec "
            "than spec.data/spec.seed would rebuild — the manifest's "
            f"config_digest would not replay this run.\n  population: "
            f"{population.spec}\n  spec:       {spec.population_spec()}")
    sim = SimulatedFederation(population, spec)
    profile_dir = spec.obs.profile_dir if spec.obs.enabled else None
    if profile_dir is not None:
        import jax
        with jax.profiler.trace(profile_dir):
            report = sim.run(resume_from=resume_from)
    else:
        report = sim.run(resume_from=resume_from)
    manifest = build_manifest(spec, sim, report)
    if sim.obs.enabled:
        _emit_trace(spec, sim, manifest)
    return ExperimentResult(spec, report, manifest, sim=sim)


def _emit_trace(spec: ExperimentSpec, sim: SimulatedFederation,
                manifest: dict[str, Any]) -> None:
    """Flush the flight recorder's sinks and stamp the trace digest into the
    manifest.  Strictly post-run: by construction nothing here can perturb
    the simulation it describes."""
    obs = sim.obs
    meta = {k: manifest[k] for k in
            ("config_digest", "strategy", "mode", "engine", "mesh_shards",
             "seed", "n_clients", "rounds_run")}
    digest = write_jsonl(spec.obs.trace_path, meta, obs.records, obs.metrics)
    manifest["trace_path"] = spec.obs.trace_path
    manifest["trace_digest"] = digest
    manifest["timing"] = obs.timing_summary()
    if spec.obs.chrome_path is not None:
        write_chrome_trace(spec.obs.chrome_path, obs.records)
        manifest["chrome_trace_path"] = spec.obs.chrome_path
    if spec.obs.console:
        print(console_summary(
            obs.metrics, title=f"trace {spec.train.strategy}/"
            f"{spec.train.mode} -> {spec.obs.trace_path}"))

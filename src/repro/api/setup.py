"""Shared experiment wiring: dataset packing + model-bundle construction.

The three FL examples (`quickstart`, `train_federated`,
`simulate_population`) and the benchmark harness used to each carry their
own copy of the same setup dance — partition a dataset, pack rectangular
client shards, sample a probe batch, build the MLP ``ModelBundle``.  These
helpers are that dance, once.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import ModelBundle
from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.models import classifier as clf


class PackedClients(NamedTuple):
    """A partitioned classification dataset, stacked for the vmapped trainer."""
    cx: jnp.ndarray          # (n, n_batches, B, D) train
    cy: jnp.ndarray          # (n, n_batches, B)
    tx: np.ndarray           # (n, n_test, D) per-client local test
    ty: np.ndarray           # (n, n_test)
    test_x: jnp.ndarray      # shared global test split
    test_y: jnp.ndarray
    probe: jnp.ndarray       # (psi, D) PAA probe batch
    num_classes: int
    in_dim: int


def load_packed_clients(dataset: str, n_clients: int, bias: float, *,
                        n_batches: int = 4, batch_size: int = 64,
                        psi: int = 32, probe_category: int = 0,
                        seed: int = 0) -> PackedClients:
    """Dirichlet-partition ``dataset`` into ``n_clients`` rectangular shards
    plus the shared test split and the PAA probe batch."""
    (xt, yt), (xe, ye) = make_classification_dataset(dataset, seed=seed)
    parts = dirichlet_partition(yt, n_clients, bias, seed=seed)
    cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=n_batches,
                                  batch_size=batch_size, seed=seed)
    probe = sample_probe_batch(xt, yt, category=probe_category, psi=psi,
                               seed=seed)
    return PackedClients(
        cx=jnp.asarray(cx), cy=jnp.asarray(cy), tx=tx, ty=ty,
        test_x=jnp.asarray(xe), test_y=jnp.asarray(ye),
        probe=jnp.asarray(probe),
        num_classes=int(yt.max()) + 1, in_dim=int(xt.shape[1]))


def make_mlp_bundle(in_dim: int, num_classes: int, *,
                    hidden: tuple[int, ...] = (128,), rep_dim: int = 64,
                    ) -> tuple[clf.MLPConfig, ModelBundle]:
    """The FL classifier as (architecture config, architecture-agnostic
    bundle) — the pair every entry point needs."""
    cfg = clf.MLPConfig(in_dim=in_dim, hidden=tuple(hidden), rep_dim=rep_dim,
                        num_classes=num_classes)
    bundle = ModelBundle(functools.partial(clf.apply, cfg),
                         functools.partial(clf.embed, cfg), num_classes)
    return cfg, bundle

"""`repro.api` — the declarative experiment surface.

    from repro.api import ExperimentSpec, TrainSpec, run

    spec = ExperimentSpec(train=TrainSpec(strategy="fedavg", rounds=30))
    result = run(spec)
    print(result.summary())          # carries the spec's config_digest

One spec runs any registered strategy (BFLN, FedAvg, FedProx, FedProto,
FedHKD, or your own via :func:`register_strategy`) through the fused,
arena-backed round engine, the event-driven simulator, and — with
``MeshSpec(shards=N)`` — the client-sharded device mesh.  Specs round-trip
through JSON and stamp a ``config_digest`` into every run manifest.
"""
from repro.api.registry import (  # noqa: F401
    build_strategy,
    register_strategy,
    strategy_names,
)
from repro.api.runner import (  # noqa: F401
    ExperimentResult,
    build_manifest,
    event_log_digest,
    format_manifest,
    run,
)
from repro.api.setup import (  # noqa: F401
    PackedClients,
    load_packed_clients,
    make_mlp_bundle,
)
from repro.api.spec import (  # noqa: F401
    AsyncSpec,
    ChainSpec,
    CheckpointSpec,
    DataSpec,
    EvalSpec,
    ExperimentSpec,
    FaultSpec,
    MeshSpec,
    ObsSpec,
    TrainSpec,
)
from repro.serve import serve  # noqa: F401  (run(spec) -> serve(result))

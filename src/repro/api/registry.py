"""String-keyed strategy registry: the one place strategies are looked up.

Every federated strategy the system can run — BFLN and the paper's Table II
baselines — registers a *builder* under a short name.  A builder has the
uniform signature

    builder(bundle, *, probe, n_clusters, **params) -> Strategy

where ``bundle`` is the :class:`repro.core.ModelBundle`, ``probe`` is the
PAA probe batch (``None`` for strategies that don't use it), ``n_clusters``
the PAA/CACC cluster count, and ``params`` strategy-specific
hyper-parameters (e.g. FedProx ``mu``).  ``ExperimentSpec.train.strategy``
is validated against this registry at construction, and the simulator /
fused round engine build their strategy through :func:`build_strategy` — so
adding a scenario is ``register_strategy("mine", builder)`` plus a spec.
"""
from __future__ import annotations

from typing import Callable, Protocol

from repro.core.baselines import STRATEGY_FACTORIES, Strategy, make_bfln


class StrategyBuilder(Protocol):
    def __call__(self, bundle, *, probe, n_clusters, **params) -> Strategy: ...


_REGISTRY: dict[str, StrategyBuilder] = {}


def register_strategy(name: str, builder: StrategyBuilder,
                      overwrite: bool = False) -> None:
    """Register ``builder`` under ``name`` (ValueError on silent collision)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"strategy {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = builder


def strategy_names() -> list[str]:
    return sorted(_REGISTRY)


def build_strategy(name: str, bundle, *, probe=None, n_clusters: int = 5,
                   **params) -> Strategy:
    try:
        builder = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"registered: {strategy_names()}") from None
    return builder(bundle, probe=probe, n_clusters=n_clusters, **params)


# --------------------------------------------------------------------------- #
# built-ins: BFLN + the paper's four baselines
# --------------------------------------------------------------------------- #

def _bfln(bundle, *, probe, n_clusters, **params):
    if probe is None:
        raise ValueError("bfln needs a PAA probe batch (probe=...)")
    if n_clusters < 1:
        raise ValueError(f"bfln needs n_clusters >= 1, got {n_clusters}")
    return make_bfln(bundle, probe, n_clusters, **params)


def _plain(make: Callable) -> StrategyBuilder:
    def builder(bundle, *, probe=None, n_clusters=0, **params):
        return make(bundle, **params)
    return builder


register_strategy("bfln", _bfln)
# the probe-less baselines come straight from the factory table in
# repro.core.baselines — ONE list of strategies, not two to keep in sync
for _name, _make in STRATEGY_FACTORIES.items():
    register_strategy(_name, _plain(_make))

"""Pytree utilities shared across the framework.

Most of the BFLN core operates on *stacked* pytrees: every leaf carries a
leading ``n_clients`` axis so that all federated clients can be trained and
aggregated with a single vmapped / collective program instead of a Python
loop over clients (the TPU-native replacement for the paper's sequential
client loop — see DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def tree_stack(trees: list[Pytree]) -> Pytree:
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Pytree, n: int) -> list[Pytree]:
    """Inverse of :func:`tree_stack`."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_index(tree: Pytree, i) -> Pytree:
    """Select index ``i`` along the leading (client) axis of every leaf."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_zeros_like(tree: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    """Global inner product of two pytrees."""
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return functools.reduce(jnp.add, jax.tree.leaves(leaves))


def tree_sq_norm(tree: Pytree) -> jax.Array:
    return tree_dot(tree, tree)


def tree_size(tree: Pytree) -> int:
    """Total number of scalar parameters in the tree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree)))


def tree_bytes(tree: Pytree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def tree_flatten_vector(tree: Pytree, dtype=jnp.float32) -> jax.Array:
    """Flatten a pytree into a single 1-D vector (used for hashing / clustering
    diagnostics, not for the hot aggregation path)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([jnp.ravel(x).astype(dtype) for x in leaves])


def tree_cast(tree: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_map_stacked(fn: Callable, tree: Pytree) -> Pytree:
    """vmap ``fn`` over the leading client axis of ``tree``."""
    return jax.vmap(fn)(tree)


def tree_any_nan(tree: Pytree) -> jax.Array:
    flags = [jnp.any(jnp.isnan(x)) for x in jax.tree.leaves(tree)]
    return functools.reduce(jnp.logical_or, flags, jnp.asarray(False))


def tree_weighted_mean(tree: Pytree, weights: jax.Array) -> Pytree:
    """Weighted mean over the leading client axis. ``weights`` shape (n,)."""
    wsum = jnp.sum(weights)

    def leaf(x):
        w = weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * w, axis=0) / wsum.astype(x.dtype)

    return jax.tree.map(leaf, tree)

"""Synthetic classification datasets standing in for CIFAR10/CIFAR100/SVHN.

The container is offline, so the paper's datasets are replaced by Gaussian
mixture-of-prototypes tasks with the *same class counts* (10 / 100 / 10) and a
difficulty knob (`margin`): each class k has a mean µ_k on a scaled sphere;
samples are µ_k + noise, passed through a fixed random nonlinearity so a
linear model cannot saturate and local training dynamics resemble a small
vision task.  Determinism: everything derives from the seed.

Registered specs:  synth10 (CIFAR10 stand-in), synth100 (CIFAR100 stand-in),
synthdigits (SVHN stand-in — easier: larger margin, mirroring the paper's
observation that SVHN is 'relatively simpler').
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticSpec:
    name: str
    num_classes: int
    dim: int
    margin: float         # class-mean separation (difficulty knob, higher=easier)
    noise: float
    n_train: int
    n_test: int


SPECS = {
    "synth10": SyntheticSpec("synth10", 10, 64, 1.0, 1.0, 20000, 4000),
    "synth100": SyntheticSpec("synth100", 100, 64, 0.8, 1.0, 30000, 6000),
    "synthdigits": SyntheticSpec("synthdigits", 10, 64, 1.8, 1.0, 20000, 4000),
}


def make_classification_dataset(spec: SyntheticSpec | str, seed: int = 0):
    """Returns ((x_train, y_train), (x_test, y_test)) as float32/int32 numpy."""
    if isinstance(spec, str):
        spec = SPECS[spec]
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(spec.num_classes, spec.dim)).astype(np.float32)
    means *= spec.margin / np.linalg.norm(means, axis=1, keepdims=True)
    means *= np.sqrt(spec.dim)
    # fixed random feature warp: x -> 0.5*(x + tanh(Wx)) keeps the task
    # non-linear but well-conditioned
    W = rng.normal(size=(spec.dim, spec.dim)).astype(np.float32) / np.sqrt(spec.dim)

    def sample(n):
        y = rng.integers(0, spec.num_classes, size=n).astype(np.int32)
        x = means[y] + spec.noise * rng.normal(size=(n, spec.dim)).astype(np.float32)
        x = 0.5 * (x + np.tanh(x @ W))
        return x.astype(np.float32), y

    return sample(spec.n_train), sample(spec.n_test)

from repro.data.partition import dirichlet_partition, pack_clients  # noqa: F401
from repro.data.synthetic import SyntheticSpec, make_classification_dataset  # noqa: F401
from repro.data.lm import make_token_stream  # noqa: F401

"""Synthetic token streams for language-model training examples.

A first-order Markov chain with Zipf-distributed stationary mass gives a
non-trivial next-token structure (learnable; loss drops measurably within a
few hundred steps) without any external corpus.
"""
from __future__ import annotations

import numpy as np


def make_token_stream(vocab_size: int, n_tokens: int, seed: int = 0,
                      branching: int = 8) -> np.ndarray:
    """Each token deterministically restricts its successors to ``branching``
    candidates (hash-derived), sampled Zipf-weighted -> learnable bigram task."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, branching + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    # successor table: vocab_size x branching, derived from a hashed congruence
    base = (np.arange(vocab_size, dtype=np.int64)[:, None] * 2654435761
            + np.arange(branching, dtype=np.int64)[None, :] * 40503)
    succ = np.abs(base) % vocab_size

    out = np.empty(n_tokens, dtype=np.int32)
    tok = int(rng.integers(vocab_size))
    choices = rng.choice(branching, size=n_tokens, p=probs)
    for i in range(n_tokens):
        out[i] = tok
        tok = int(succ[tok, choices[i]])
    return out


def batch_stream(tokens: np.ndarray, batch: int, seq_len: int, n_steps: int,
                 seed: int = 0):
    """Yield (tokens, labels) batches of shape (batch, seq_len)."""
    rng = np.random.default_rng(seed)
    max_start = len(tokens) - seq_len - 1
    for _ in range(n_steps):
        starts = rng.integers(0, max_start, size=batch)
        x = np.stack([tokens[s:s + seq_len] for s in starts])
        y = np.stack([tokens[s + 1:s + seq_len + 1] for s in starts])
        yield x, y

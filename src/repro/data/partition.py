"""Non-IID client partitioning (the paper's 'data label bias' protocol).

``dirichlet_partition`` implements the standard label-skew split: for each
class, the per-client share is drawn from Dir(β·1).  Small β (0.1) ⇒ highly
skewed clients holding few classes; β = 0.5 is mild skew.  This matches the
bias levels {0.1, 0.3, 0.5} of Table II.

``pack_clients`` turns ragged per-client index lists into the rectangular
stacked layout the vmapped trainer needs: every client is resampled (with
replacement when short) to exactly ``n_batches × batch_size`` examples plus a
fixed-size local test split drawn from the same distribution — Table II's
metric is mean personalized accuracy on each client's own distribution.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, beta: float,
                        seed: int = 0, min_per_client: int = 2) -> list[np.ndarray]:
    """Returns one index array per client. Every sample is assigned exactly once."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for k in range(n_classes):
        idx = np.flatnonzero(labels == k)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, beta))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            client_idx[cid].extend(part.tolist())
    # guarantee a minimum number of samples per client (steal from the largest)
    sizes = [len(c) for c in client_idx]
    for cid in range(n_clients):
        while len(client_idx[cid]) < min_per_client:
            donor = int(np.argmax([len(c) for c in client_idx]))
            client_idx[cid].append(client_idx[donor].pop())
    return [np.asarray(sorted(c), dtype=np.int64) for c in client_idx]


def pack_clients(
    x: np.ndarray,
    y: np.ndarray,
    parts: list[np.ndarray],
    n_batches: int,
    batch_size: int,
    test_frac: float = 0.2,
    seed: int = 0,
):
    """Rectangularise ragged client shards.

    Returns ``(cx, cy, tx, ty)`` with shapes
    cx (m, n_batches, B, ...), cy (m, n_batches, B),
    tx (m, n_test, ...), ty (m, n_test) — per-client local test split.
    """
    rng = np.random.default_rng(seed)
    m = len(parts)
    need_train = n_batches * batch_size
    n_test = max(int(need_train * test_frac), 8)

    cx = np.zeros((m, need_train) + x.shape[1:], x.dtype)
    cy = np.zeros((m, need_train), y.dtype)
    tx = np.zeros((m, n_test) + x.shape[1:], x.dtype)
    ty = np.zeros((m, n_test), y.dtype)

    for cid, idx in enumerate(parts):
        idx = idx.copy()
        rng.shuffle(idx)
        split = max(int(len(idx) * (1 - test_frac)), 1)
        tr, te = idx[:split], idx[split:] if len(idx) > split else idx[:1]
        tr_sel = rng.choice(tr, size=need_train, replace=len(tr) < need_train)
        te_sel = rng.choice(te, size=n_test, replace=len(te) < n_test)
        cx[cid], cy[cid] = x[tr_sel], y[tr_sel]
        tx[cid], ty[cid] = x[te_sel], y[te_sel]

    cx = cx.reshape(m, n_batches, batch_size, *x.shape[1:])
    cy = cy.reshape(m, n_batches, batch_size)
    return cx, cy, tx, ty


def sample_probe_batch(x: np.ndarray, y: np.ndarray, category: int,
                       psi: int, seed: int = 0) -> np.ndarray:
    """The aggregation client's probe: ψ samples of one category (paper §IV-B)."""
    rng = np.random.default_rng(seed)
    idx = np.flatnonzero(y == category)
    sel = rng.choice(idx, size=psi, replace=len(idx) < psi)
    return x[sel]

"""Pallas TPU kernel: RWKV6 wkv recurrence with data-dependent decay.

    y_t = r_t · (S_{t-1} + (u ⊙ k_t) vᵀ_t)
    S_t = diag(w_t) · S_{t-1} + k_t vᵀ_t

One program per (batch, head): the full (T, hd) r/k/v/w slices live in VMEM
(T ≤ a few thousand per call; longer sequences are chunked by the ops wrapper
carrying S across calls), the (hd, hd) state is a VMEM scratch accumulator
updated with VPU outer products over a ``fori_loop`` in time.  This is the
TPU-native adaptation of the CUDA wkv kernel shipped with the paper: instead
of one thread per channel with shared-memory staging, lanes are the v-columns
of the state tile and the recurrence is a (hd,1)×(1,hd) broadcast-multiply.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, T: int, hd: int):
    s_scr[...] = s0_ref[0, 0]

    def step(t, _):
        r = r_ref[0, 0, t].astype(jnp.float32)       # (hd,)
        k = k_ref[0, 0, t].astype(jnp.float32)
        v = v_ref[0, 0, t].astype(jnp.float32)
        w = w_ref[0, 0, t].astype(jnp.float32)
        u = u_ref[0].astype(jnp.float32)
        s = s_scr[...]                               # (hd_k, hd_v)
        kv = k[:, None] * v[None, :]
        y = jnp.sum(r[:, None] * (s + u[:, None] * kv), axis=0)   # (hd_v,)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        s_scr[...] = s * w[:, None] + kv
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    sT_ref[0, 0] = s_scr[...]


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, s0: jax.Array, *, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """r/k/v/w: (B, H, T, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (y (B, H, T, hd), s_T (B, H, hd, hd)).
    """
    B, H, T, hd = r.shape
    kernel = functools.partial(_wkv_kernel, T=T, hd=hd)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, hd), lambda b, h: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT

"""Pallas TPU kernel: Pearson correlation matrix between prototype vectors.

PAA computes Ξ[i,j] = corr(𝔙_i, 𝔙_j) over (m, D) prototypes every round.
TPU-native formulation: center+normalize each row once (VPU), then a blocked
gram matmul on the MXU.  The D (feature) axis is tiled through VMEM with a
running accumulator so arbitrarily wide prototype matrices stream through
without spilling; row statistics are computed in a first pass over the same
tiles.

Grid: (m_tiles_i, m_tiles_j); each program owns a (BM, BM) output tile and
loops the D axis in BD-sized VMEM blocks (multiples of 128 for MXU lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pearson_kernel(x_i_ref, x_j_ref, out_ref, *, bd: int, d: int, eps: float):
    """x_i_ref (BM, D), x_j_ref (BM, D) VMEM tiles; out_ref (BM, BM)."""
    nb = d // bd

    def stats(x_ref):
        # mean over the full feature axis, streamed in BD blocks
        def body(k, acc):
            blk = x_ref[:, pl.dslice(k * bd, bd)].astype(jnp.float32)
            return acc + jnp.sum(blk, axis=1)

        s = jax.lax.fori_loop(0, nb, body, jnp.zeros((x_ref.shape[0],), jnp.float32))
        mean = s / d

        def body2(k, acc):
            blk = x_ref[:, pl.dslice(k * bd, bd)].astype(jnp.float32)
            c = blk - mean[:, None]
            return acc + jnp.sum(c * c, axis=1)

        ss = jax.lax.fori_loop(0, nb, body2, jnp.zeros((x_ref.shape[0],), jnp.float32))
        return mean, jnp.maximum(jnp.sqrt(ss), eps)

    mean_i, norm_i = stats(x_i_ref)
    mean_j, norm_j = stats(x_j_ref)

    def gram(k, acc):
        bi = x_i_ref[:, pl.dslice(k * bd, bd)].astype(jnp.float32) - mean_i[:, None]
        bj = x_j_ref[:, pl.dslice(k * bd, bd)].astype(jnp.float32) - mean_j[:, None]
        return acc + jax.lax.dot_general(
            bi, bj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    m_i = x_i_ref.shape[0]
    m_j = x_j_ref.shape[0]
    acc = jax.lax.fori_loop(0, nb, gram, jnp.zeros((m_i, m_j), jnp.float32))
    corr = acc / (norm_i[:, None] * norm_j[None, :])
    out_ref[...] = jnp.clip(corr, -1.0, 1.0)


def pearson_matrix_pallas(protos: jax.Array, *, block_m: int = 128,
                          block_d: int = 512, eps: float = 1e-8,
                          interpret: bool = False) -> jax.Array:
    """(m, D) -> (m, m) Pearson correlation.  Pads m to block_m and D to
    block_d (padding columns are mean-neutralised by construction: padded
    zeros are excluded via padding with the row mean would bias stats, so we
    instead require D % block_d == 0 after padding and correct the mean by
    tracking the true D)."""
    m, d = protos.shape
    mp = -(-m // block_m) * block_m
    bd = min(block_d, -(-d // 128) * 128)
    dp = -(-d // bd) * bd
    x = protos.astype(jnp.float32)
    # pad rows with zeros; pad features by REPLICATING each row's last value?
    # No: pad features with the row's own mean so centered values are 0 and
    # neither covariance nor variance changes.
    row_mean = jnp.mean(x, axis=1, keepdims=True)
    if dp != d:
        pad = jnp.broadcast_to(row_mean, (m, dp - d))
        x = jnp.concatenate([x, pad], axis=1)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))

    grid = (mp // block_m, mp // block_m)
    out = pl.pallas_call(
        functools.partial(_pearson_kernel, bd=bd, d=dp, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=interpret,
    )(x, x)
    return out[:m, :m]

"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs in Python on the host, which validates correctness against
the ref.py oracles; on TPU the same calls compile via Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.cluster_agg import cluster_agg_pallas, mixing_matrix  # noqa: F401
from repro.kernels.fingerprint import fingerprint_pallas
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pearson import pearson_matrix_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("block_m", "block_d"))
def pearson(protos: jax.Array, block_m: int = 128, block_d: int = 512) -> jax.Array:
    """Pearson correlation matrix (m, D) -> (m, m)."""
    return pearson_matrix_pallas(protos, block_m=block_m, block_d=block_d,
                                 interpret=_on_cpu())


@partial(jax.jit, static_argnames=("n_clusters", "block_n"))
def cluster_aggregate(flat: jax.Array, labels: jax.Array, n_clusters: int,
                      block_n: int = 2048) -> jax.Array:
    """Cluster-masked FedAvg over stacked flattened client params."""
    mix = mixing_matrix(labels, n_clusters)
    return cluster_agg_pallas(flat, mix, block_n=block_n, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Flash attention (causal / SWA, GQA)."""
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=_on_cpu())


@jax.jit
def rwkv6_wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """RWKV6 wkv recurrence; returns (y, final state)."""
    return _rwkv6(r, k, v, w, u, s0, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def fingerprint(flat_u32: jax.Array, block_m: int = 8,
                block_n: int = 2048) -> jax.Array:
    """Per-client polynomial fingerprint residues (m, N)u32 -> (m, 2)u32."""
    return fingerprint_pallas(flat_u32, block_m=block_m, block_n=block_n,
                              interpret=_on_cpu())

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pearson_ref(protos: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """(m, D) -> (m, m); numerically identical formulation to the kernel."""
    x = protos.astype(jnp.float32)
    c = x - jnp.mean(x, axis=1, keepdims=True)
    n = jnp.maximum(jnp.linalg.norm(c, axis=1, keepdims=True), eps)
    return jnp.clip((c / n) @ (c / n).T, -1.0, 1.0)


def cluster_agg_ref(flat: jnp.ndarray, mix: jnp.ndarray) -> jnp.ndarray:
    """(m, N), (m, m) -> (m, N)."""
    return (mix @ flat.astype(jnp.float32)).astype(flat.dtype)


def fingerprint_ref(flat_u32: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(m, N) uint32 bits, (2, N) uint32 weight rows -> (m, 2) residues.

    Exact mod-2^32 polynomial fingerprint (natural uint32 wraparound);
    bit-identical to the Pallas kernel — integer math has no rounding, so
    the reduction order is irrelevant.  The xor-shift pre-mix folds high
    bits into low ones: float32 bit patterns of smooth params share long
    trailing-zero runs, which a bare ``v·r^j`` sum would propagate into
    the residues' low bits."""
    x = flat_u32.astype(jnp.uint32)
    x = x ^ (x >> 16)                  # mix(0) == 0, so zero padding stays neutral
    a = jnp.sum(x * weights[0][None, :], axis=1, dtype=jnp.uint32)
    b = jnp.sum(x * weights[1][None, :], axis=1, dtype=jnp.uint32)
    return jnp.stack([a, b], axis=1)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive softmax attention with GQA; (B,S,Hq,hd)x(B,S,Hkv,hd)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32) / (hd ** 0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """lax.scan oracle.  r/k/v/w (B,H,T,hd); u (H,hd); s0 (B,H,hd,hd)."""
    rt = r.transpose(2, 0, 1, 3).astype(jnp.float32)
    kt = k.transpose(2, 0, 1, 3).astype(jnp.float32)
    vt = v.transpose(2, 0, 1, 3).astype(jnp.float32)
    wt = w.transpose(2, 0, 1, 3).astype(jnp.float32)

    def step(s, x):
        r_, k_, v_, w_ = x
        kv = k_[..., :, None] * v_[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_, s + u[None, :, :, None] * kv)
        s = s * w_[..., :, None] + kv
        return s, y

    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rt, kt, vt, wt))
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), sT

"""Pure-jnp oracles for every Pallas kernel (the allclose targets), plus
pure-numpy single-device oracles for the deterministic tree reductions
(the BITWISE targets — IEEE-754 elementwise adds/muls round identically in
numpy and XLA, so these pin the exact result the sharded engine must
reproduce at every mesh width)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pearson_ref(protos: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """(m, D) -> (m, m); numerically identical formulation to the kernel."""
    x = protos.astype(jnp.float32)
    c = x - jnp.mean(x, axis=1, keepdims=True)
    n = jnp.maximum(jnp.linalg.norm(c, axis=1, keepdims=True), eps)
    return jnp.clip((c / n) @ (c / n).T, -1.0, 1.0)


def cluster_agg_ref(flat: jnp.ndarray, mix: jnp.ndarray) -> jnp.ndarray:
    """(m, N), (m, m) -> (m, N)."""
    return (mix @ flat.astype(jnp.float32)).astype(flat.dtype)


def fingerprint_ref(flat_u32: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(m, N) uint32 bits, (2, N) uint32 weight rows -> (m, 2) residues.

    Exact mod-2^32 polynomial fingerprint (natural uint32 wraparound);
    bit-identical to the Pallas kernel — integer math has no rounding, so
    the reduction order is irrelevant.  The xor-shift pre-mix folds high
    bits into low ones: float32 bit patterns of smooth params share long
    trailing-zero runs, which a bare ``v·r^j`` sum would propagate into
    the residues' low bits."""
    x = flat_u32.astype(jnp.uint32)
    x = x ^ (x >> 16)                  # mix(0) == 0, so zero padding stays neutral
    a = jnp.sum(x * weights[0][None, :], axis=1, dtype=jnp.uint32)
    b = jnp.sum(x * weights[1][None, :], axis=1, dtype=jnp.uint32)
    return jnp.stack([a, b], axis=1)


def tree_sum_ref(x: np.ndarray, axis: int = 0) -> np.ndarray:
    """Single-device oracle for ``repro.core.aggregation.tree_sum``: the
    same fixed-order adjacent-pair binary tree (pad to the next power of two
    with +0.0), evaluated in numpy.  Elementwise IEEE adds have one correct
    rounding, so this matches the jitted tree bit for bit — provided the
    jitted reduction runs with the reduced axis replicated, the engine's
    combine discipline (``tests/test_tree_reduction.py``)."""
    x = np.moveaxis(np.asarray(x), axis, 0)
    m = x.shape[0]
    p = 1 if m <= 1 else 1 << (m - 1).bit_length()
    if p != m:
        x = np.concatenate(
            [x, np.zeros((p - m,) + x.shape[1:], x.dtype)], axis=0)
    while x.shape[0] > 1:
        a = x.reshape((x.shape[0] // 2, 2) + x.shape[1:])
        x = a[:, 0] + a[:, 1]
    return x[0]


def masked_tree_sum_ref(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Oracle for ``masked_tree_sum`` over axis 0: where-guarded weighted
    contributions (+0.0 for zero-weight slots) tree-summed."""
    x = np.asarray(x)
    wb = np.asarray(w, x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
    contrib = np.where(wb > 0, x * wb, x.dtype.type(0.0))
    return tree_sum_ref(contrib, axis=0)


def tree_cluster_mean_ref(rows: np.ndarray, labels: np.ndarray,
                          n_clusters: int,
                          weights: np.ndarray | None = None) -> np.ndarray:
    """Oracle for ``tree_cluster_mean_params`` on a flat (m, N) matrix:
    per-cluster where-guarded tree segment sums, clamped denominator,
    gather-back by label."""
    rows = np.asarray(rows, np.float32)
    m = rows.shape[0]
    labels = np.asarray(labels)
    w = np.ones((m,), np.float32) if weights is None \
        else np.asarray(weights, np.float32)
    onehot = np.zeros((m, n_clusters), np.float32)
    onehot[np.arange(m), labels] = 1.0
    wo = onehot * w[:, None]                                        # (m, C)
    denom = np.maximum(tree_sum_ref(wo, axis=0), np.float32(1e-9))  # (C,)
    means = np.stack([masked_tree_sum_ref(rows, wo[:, c]) / denom[c]
                      for c in range(n_clusters)])                  # (C, N)
    return means[labels]


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive softmax attention with GQA; (B,S,Hq,hd)x(B,S,Hkv,hd)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32) / (hd ** 0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, hd).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """lax.scan oracle.  r/k/v/w (B,H,T,hd); u (H,hd); s0 (B,H,hd,hd)."""
    rt = r.transpose(2, 0, 1, 3).astype(jnp.float32)
    kt = k.transpose(2, 0, 1, 3).astype(jnp.float32)
    vt = v.transpose(2, 0, 1, 3).astype(jnp.float32)
    wt = w.transpose(2, 0, 1, 3).astype(jnp.float32)

    def step(s, x):
        r_, k_, v_, w_ = x
        kv = k_[..., :, None] * v_[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_, s + u[None, :, :, None] * kv)
        s = s * w_[..., :, None] + kv
        return s, y

    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rt, kt, vt, wt))
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), sT

"""Pallas TPU kernel: batched per-client model fingerprints.

The BFLN commitment layer (Fig. 1 steps 2/5/6) needs one digest per cohort
member per round.  The original ``hash_params`` path pulled every full model
to the host (`O(cohort · N_params)` bytes, a Python loop of `device_get` +
SHA-256) — the dominant host cost of ``repro.sim`` at 1000-client
populations.  This kernel computes all digests on device in one streamed
pass and ships `O(cohort)` digest bytes instead.

Scheme — a blocked Rabin-style polynomial fingerprint over the raw bit
pattern of the stacked-flattened cohort params ``V`` (shape (m, N) uint32,
one row per client):

    A_i = Σ_j mix(V[i, j]) · r^(j+1)      (mod 2^32)
    B_i = Σ_j mix(V[i, j]) · r^(2(j+1))   (mod 2^32)

with ``r`` a fixed odd base and ``mix(v) = v ^ (v >> 16)`` (a bijection
folding high bits into low ones — float32 bit patterns of smooth params
share long trailing-zero runs that a bare weighted sum would propagate
into the residues); the per-client digest is the pair ``(A_i, B_i)`` plus
the length ``N`` (so zero-extension cannot collide).  ``B`` is
the same polynomial at base ``r²`` — two independent 32-bit residues from a
single streamed weight row.  Weights are precomputed once per ``N`` (natural
uint32 wraparound) and streamed through VMEM alongside the data, so the
kernel is a pure VPU multiply-accumulate:

    grid (m_tiles, n_tiles); each program owns a (BM, 128) lane accumulator
    and folds its (BM, BN) data/weight tiles as (BM, BN//128, 128) partial
    sums.  The final 128-lane fold is exact because r^j already encodes the
    lane offset (j = 128·t + l), so cross-lane combination is plain modular
    addition — done in jnp on the tiny (m, 128) output.

Zero padding of the N axis is neutral by construction (0 · w = 0), so
non-aligned N needs no masking.  This is a *fingerprint* (tamper-evidence
for the simulated chain, linear over GF-style residues), not a
cryptographic hash; sender binding and Merkle commitment live in
``repro.blockchain.commit``.

Oracle: ``repro.kernels.ref.fingerprint_ref`` (bit-identical — integer
arithmetic is exact, so kernel, interpret mode and oracle all agree).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.runtime.arena import ArenaLayout

Pytree = Any

# Odd base (from MurmurHash3's c1); order mod 2^32 divides 2^30 — weights
# cycle only past N ≈ 10^9, far beyond any stacked model here.
FINGERPRINT_BASE = np.uint32(0x85EBCA77)


@functools.lru_cache(maxsize=8)
def poly_weights(n: int, base: int = int(FINGERPRINT_BASE)) -> np.ndarray:
    """(2, n) uint32: rows ``r^(j+1)`` and ``r^(2(j+1))`` mod 2^32."""
    with np.errstate(over="ignore"):
        w1 = np.cumprod(np.full((n,), np.uint32(base), dtype=np.uint32))
        w2 = w1 * w1
    return np.stack([w1, w2])


def stack_flatten_u32(stacked_params: Pytree) -> jax.Array:
    """Stacked pytree (leading client axis) -> (m, N) uint32 bit matrix.

    Leaves are raveled per client in canonical (path-sorted) order and
    bitcast so the fingerprint sees exact bit patterns.  Delegates to the
    shared :class:`repro.runtime.arena.ArenaLayout` so fingerprinting,
    cluster aggregation and the round engine all use ONE leaf layout.
    """
    return ArenaLayout.from_stacked(stacked_params).flatten_u32(stacked_params)


def _fingerprint_kernel(x_ref, w_ref, out_ref, *, bn: int):
    """x (BM, BN) uint32; w (2, BN); out (BM, 256) lane accumulators
    (lanes 0:128 base r, lanes 128:256 base r²), revisited across the
    n-tile grid axis."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    x = x ^ (x >> 16)                  # bit mix; mix(0) == 0 keeps padding neutral
    bm = x.shape[0]
    folds = x.reshape(bm, bn // 128, 128)
    w = w_ref[...].reshape(2, bn // 128, 128)
    acc1 = jnp.sum(folds * w[0][None], axis=1)     # (BM, 128), wraps mod 2^32
    acc2 = jnp.sum(folds * w[1][None], axis=1)
    out_ref[:, :128] += acc1
    out_ref[:, 128:] += acc2


def fingerprint_pallas(flat_u32: jax.Array, *, block_m: int = 8,
                       block_n: int = 2048,
                       interpret: bool = False) -> jax.Array:
    """(m, N) uint32 -> (m, 2) uint32 per-client polynomial residues."""
    m, n = flat_u32.shape
    mp = -(-m // block_m) * block_m
    bn = min(block_n, -(-n // 128) * 128)
    np_ = -(-n // bn) * bn
    x = flat_u32
    if np_ != n:
        x = jnp.pad(x, ((0, 0), (0, np_ - n)))      # zero pad: weight-neutral
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    w = jnp.asarray(poly_weights(np_))

    lanes = pl.pallas_call(
        functools.partial(_fingerprint_kernel, bn=bn),
        grid=(mp // block_m, np_ // bn),
        in_specs=[
            pl.BlockSpec((block_m, bn), lambda i, j: (i, j)),
            pl.BlockSpec((2, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, 256), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 256), jnp.uint32),
        interpret=interpret,
    )(x, w)
    # exact cross-lane fold (modular addition commutes)
    return jnp.stack([jnp.sum(lanes[:m, :128], axis=1, dtype=jnp.uint32),
                      jnp.sum(lanes[:m, 128:], axis=1, dtype=jnp.uint32)],
                     axis=1)


def fingerprint_rows(flat_u32: jax.Array, *, use_pallas: bool | None = None,
                     interpret: bool = False) -> jax.Array:
    """(m, N) uint32 bit matrix -> (m, 2) residues, jit-safe.

    The arena fast path: the fused round engine bitcasts its (already flat)
    parameter rows and calls this inside ONE jitted program — no re-stacking,
    no extra flatten.  ``use_pallas=None`` auto-selects the Mosaic kernel on
    accelerators and the bit-identical jnp oracle on CPU.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() != "cpu"
    if use_pallas:
        return fingerprint_pallas(flat_u32, interpret=interpret)
    from repro.kernels.ref import fingerprint_ref
    return fingerprint_ref(flat_u32,
                           jnp.asarray(poly_weights(flat_u32.shape[1])))


@jax.jit
def _digest_pipeline(stacked_params: Pytree) -> jax.Array:
    flat = stack_flatten_u32(stacked_params)
    return fingerprint_rows(flat, use_pallas=False)


def format_digest(residues, n_params: int) -> str:
    """(2,) uint32 residues + length -> canonical digest string."""
    a, b = (int(v) & 0xFFFFFFFF for v in residues)
    return f"{a:08x}{b:08x}{n_params:08x}"


def cohort_digests(stacked_params: Pytree, *, use_pallas: bool | None = None,
                   interpret: bool = False) -> list[str]:
    """Per-client digest strings for a cohort-stacked pytree — ONE jitted
    device program + an `O(cohort)` host transfer (2 uint32 per client).

    ``use_pallas=None`` auto-selects: the Mosaic kernel on accelerators, the
    bit-identical jnp oracle on CPU (integer math is exact, so digests never
    depend on the path taken).  Tests force ``use_pallas=True`` with
    ``interpret=True`` to validate the kernel body on CPU.
    """
    n_params = int(sum(int(np.prod(x.shape[1:]))
                       for x in jax.tree.leaves(stacked_params)))
    if use_pallas is None:
        use_pallas = jax.default_backend() != "cpu"
    if use_pallas:
        flat = jax.jit(stack_flatten_u32)(stacked_params)
        res = fingerprint_pallas(flat, interpret=interpret)
    else:
        res = _digest_pipeline(stacked_params)
    res = np.asarray(jax.device_get(res))
    return [format_digest(row, n_params) for row in res]

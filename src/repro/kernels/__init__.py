"""Pallas TPU kernels (validated on CPU via interpret=True; Mosaic on TPU).

| kernel            | role                                             | oracle                |
|-------------------|--------------------------------------------------|-----------------------|
| pearson           | PAA prototype similarity (center+normalize+gram) | ref.pearson_ref       |
| cluster_agg       | PAA cluster-masked FedAvg (mix @ stacked params) | ref.cluster_agg_ref   |
| fingerprint       | per-client model commitment digests (chain)      | ref.fingerprint_ref   |
| flash_attention   | causal/SWA GQA attention, online softmax         | ref.attention_ref     |
| rwkv6_scan        | RWKV6 wkv recurrence, data-dependent decay       | ref.rwkv6_scan_ref    |
"""
from repro.kernels import ops, ref  # noqa: F401

"""Pallas TPU kernel: cluster-masked FedAvg over stacked client parameters.

This is PAA's aggregation collective: clients in the same spectral cluster
receive the mean of that cluster's parameters,

    out[i] = Σ_j mix[i, j] · flat[j],   mix = onehot·diag(1/size)·onehotᵀ,

i.e. an (m × m) mixing matmul against the (m × N_params) stacked-flattened
parameter matrix.  N_params is huge (everything the clients train), so the
kernel streams the parameter axis through VMEM in MXU-aligned tiles while the
small mixing matrix stays resident — one pass over HBM.

Grid: (n_param_tiles,); block = (m_pad, BN).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(mix_ref, x_ref, out_ref):
    """mix (M, M) resident; x (M, BN) tile -> out (M, BN) tile."""
    mix = mix_ref[...]
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        mix, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def mixing_matrix(labels: jax.Array, n_clusters: int,
                  weights: jax.Array | None = None) -> jax.Array:
    """(m,) labels -> (m, m) cluster-mean mixing matrix (fp32)."""
    m = labels.shape[0]
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)
    w = jnp.ones((m,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wo = onehot * w[:, None]
    denom = jnp.maximum(jnp.sum(wo, axis=0), 1e-9)
    return (onehot / denom[None, :]) @ wo.T


def cluster_agg_pallas(flat: jax.Array, mix: jax.Array, *, block_n: int = 2048,
                       interpret: bool = False) -> jax.Array:
    """flat (m, N) stacked client params; mix (m, m) -> (m, N) aggregated."""
    m, n = flat.shape
    mp = max(8, -(-m // 8) * 8)
    bn = min(block_n, -(-n // 128) * 128)
    np_ = -(-n // bn) * bn
    x = flat
    if np_ != n:
        x = jnp.pad(x, ((0, 0), (0, np_ - n)))
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
        mix = jnp.pad(mix, ((0, mp - m), (0, mp - m)))

    out = pl.pallas_call(
        _agg_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((mp, mp), lambda i: (0, 0)),   # mixing matrix resident
            pl.BlockSpec((mp, bn), lambda i: (0, i)),   # stream param tiles
        ],
        out_specs=pl.BlockSpec((mp, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), flat.dtype),
        interpret=interpret,
    )(mix, x)
    return out[:m, :n]

"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA).

Online-softmax attention with the canonical TPU schedule: grid
(B, Hq, nq, nk) iterated sequentially in the minor (nk) dimension, carrying
running (max, sum, accumulator) in VMEM scratch; the output tile is written
when the last kv block finishes.  Causal and sliding-window dead blocks are
skipped via ``pl.when`` (no MXU work issued) — the kernel-level counterpart
of the XLA-level ``skip_masked_chunks`` optimisation in
repro.models.attention.

Block shapes are MXU-aligned (q/k blocks multiples of 128 where the shape
allows; head_dim rides along).  fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int,
                  scale: float):
    i = pl.program_id(2)     # q block
    j = pl.program_id(3)     # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # dead-block test — no MXU work for fully-masked blocks
    q_lo = i * bq
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    live = jnp.asarray(True)
    if causal:
        live = live & (k_lo <= q_hi)
    if window > 0:
        live = live & (q_lo - k_hi < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = ok & (k_pos <= q_pos)
        if window > 0:
            ok = ok & (q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B, S, Hq, hd), k/v (B, S, Hkv, hd) -> (B, S, Hq, hd).

    GQA: q head h reads kv head ``h // (Hq // Hkv)``.
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / (hd ** 0.5)

    qt = q.transpose(0, 2, 1, 3)     # (B, Hq, S, hd)
    kt = k.transpose(0, 2, 1, 3)     # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running sum
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)

from repro.faults.injector import (  # noqa: F401
    NULL_INJECTOR,
    FaultInjector,
    InjectedCrash,
    NullInjector,
)
from repro.faults.spec import CRASH_MODES, CRASH_PHASES, FaultSpec  # noqa: F401

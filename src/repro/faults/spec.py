"""`FaultSpec` — declarative, seeded fault-injection schedule.

Jax-free, like :mod:`repro.obs.spec`, so :mod:`repro.api.spec` imports it
without pulling in the runtime.  Every fault is scheduled deterministically:
round-indexed knobs fire at exactly the listed round/flush, and any
randomness (which commit to drop, retry latency jitter) comes from a
*dedicated* injector generator seeded with ``seed`` — never from the
simulator's own streams — so (a) the default all-off spec leaves seeded
replay bit-identical to a build without fault injection at all, and (b) a
faulted run is itself exactly replayable and resumable (the injector's RNG
state is part of every checkpoint).

Fault classes (see :class:`repro.faults.FaultInjector` for the handling):

* **process crash** — ``crash_round``/``crash_phase``/``crash_mode``: die at
  a chosen point; ``"sigkill"`` kills the process outright (the
  kill-and-resume tests), ``"exception"`` raises ``InjectedCrash``.
* **checkpoint corruption** — ``corrupt_checkpoint_round`` /
  ``truncate_checkpoint_round``: damage the snapshot just written, so
  resume must fall back to the previous keep-last-K snapshot.
* **producer failure** — ``producer_fail_rounds``: the selected block
  producer dies mid-pack; the driver fails over to the next consensus
  candidate.
* **bad block** — ``bad_block_rounds``: the producer emits a
  digest-mismatched block; the chain quarantines it and re-packs.
* **commit delivery** — ``drop_commit_rounds`` / ``delay_commit_rounds``:
  one arrived client's ``model_hash`` transaction is lost, or delivered
  into a later round's block (where verification ignores it).
* **retry** — bounded retry-with-backoff for dropped cohort slots
  (``retry``/``retry_max``/``retry_backoff``), surfacing as ``round.retry``
  spans.

``FaultSpec`` perturbs the trajectory, so unlike ``obs``/``checkpoint`` it
IS part of ``ExperimentSpec.config_digest()`` — but it is excluded from
``resume_digest()``, so a crashed run can be resumed with its fault
schedule cleared (otherwise a ``round_start`` crash would re-fire on every
resume, forever).
"""
from __future__ import annotations

from dataclasses import dataclass


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


#: Where inside a round/flush a crash fires.  ``round_start`` and
#: ``pre_chain`` take the index of the round being executed;
#: ``post_checkpoint`` takes the *boundary* index — the number of completed
#: rounds/flushes — and fires right after that boundary's snapshot lands.
CRASH_PHASES = ("round_start", "pre_chain", "post_checkpoint")

CRASH_MODES = ("exception", "sigkill")


@dataclass(frozen=True)
class FaultSpec:
    """Fault-injection schedule (``ExperimentSpec.faults``); default all-off."""
    seed: int = 0                     # injector RNG stream (independent)
    crash_round: int = -1             # -1 = never crash
    crash_phase: str = "post_checkpoint"
    crash_mode: str = "exception"     # "exception" | "sigkill"
    corrupt_checkpoint_round: int = -1   # bit-flip the snapshot at boundary N
    truncate_checkpoint_round: int = -1  # truncate the snapshot at boundary N
    producer_fail_rounds: tuple[int, ...] = ()
    bad_block_rounds: tuple[int, ...] = ()
    drop_commit_rounds: tuple[int, ...] = ()
    delay_commit_rounds: tuple[int, ...] = ()
    retry: bool = False               # bounded retry for dropped cohort slots
    retry_max: int = 2
    retry_backoff: float = 2.0        # latency multiplier per attempt

    def __post_init__(self):
        _check(self.crash_phase in CRASH_PHASES,
               f"crash_phase must be one of {CRASH_PHASES}, "
               f"got {self.crash_phase!r}")
        _check(self.crash_mode in CRASH_MODES,
               f"crash_mode must be one of {CRASH_MODES}, "
               f"got {self.crash_mode!r}")
        for name in ("producer_fail_rounds", "bad_block_rounds",
                     "drop_commit_rounds", "delay_commit_rounds"):
            v = getattr(self, name)
            _check(isinstance(v, tuple) and all(
                isinstance(r, int) and r >= 0 for r in v),
                f"{name} must be a tuple of round indices >= 0, got {v!r}")
        _check(self.retry_max >= 1,
               f"retry_max must be >= 1, got {self.retry_max}")
        _check(self.retry_backoff >= 1.0,
               f"retry_backoff must be >= 1, got {self.retry_backoff}")

    @property
    def enabled(self) -> bool:
        """True iff any fault (or the retry policy) is configured."""
        return (self.crash_round >= 0
                or self.corrupt_checkpoint_round >= 0
                or self.truncate_checkpoint_round >= 0
                or bool(self.producer_fail_rounds)
                or bool(self.bad_block_rounds)
                or bool(self.drop_commit_rounds)
                or bool(self.delay_commit_rounds)
                or self.retry)

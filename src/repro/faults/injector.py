"""`FaultInjector` — the runtime half of the fault-injection harness.

One injector rides along one run, mirroring the flight-recorder pattern
(:mod:`repro.obs`): fault-free runs bind the shared :data:`NULL_INJECTOR`
whose methods are no-ops returning "no fault", so the hot path never
branches on whether injection is configured and the default behaviour is
bit-identical to a build without the harness.

Determinism contract: every random choice (which commit to drop, retry
jitter) draws from the injector's OWN seeded generator, never the
simulator's — and that generator's state is captured into every checkpoint
(:meth:`FaultInjector.state_dict`), so faulted runs replay and resume
exactly.  Every injected fault is emitted as a ``fault.*`` event + counter
through the bound recorder, so the trace shows each fault the run absorbed.
"""
from __future__ import annotations

import os
import signal

import numpy as np

from repro.faults.spec import FaultSpec
from repro.obs import NULL_RECORDER


class InjectedCrash(RuntimeError):
    """Raised by ``crash_mode="exception"`` — a simulated process death that
    in-process tests can catch (``"sigkill"`` kills the interpreter)."""


class NullInjector:
    """Shared no-op injector bound when no faults are configured.  Keeps the
    exact `FaultInjector` surface so instrumented code never branches."""

    __slots__ = ()
    enabled = False
    retry = False
    spec = FaultSpec()

    def maybe_crash(self, boundary: int, phase: str) -> None:
        pass

    def will_crash(self, boundary: int, phase: str) -> bool:
        return False

    def producer_fails(self, round_idx: int) -> bool:
        return False

    def bad_block(self, round_idx: int) -> bool:
        return False

    def commit_drop_slot(self, round_idx: int, n_arrived: int) -> int:
        return -1

    def commit_delay_slot(self, round_idx: int, n_arrived: int) -> int:
        return -1

    def hold_commit(self, tx) -> None:
        raise RuntimeError("NullInjector cannot hold a commit")

    def release_commits(self) -> list:
        return []

    def retry_succeeds(self, dropout_p: float) -> bool:
        return False

    def retry_latency(self, base: float, attempt: int) -> float:
        return base

    def corrupt_checkpoint(self, path: str, boundary: int) -> None:
        pass

    def state_dict(self) -> dict | None:
        return None

    def load_state(self, state: dict | None) -> None:
        pass


NULL_INJECTOR = NullInjector()


class FaultInjector:
    """Live injector: executes one :class:`FaultSpec` schedule."""

    enabled = True

    def __init__(self, spec: FaultSpec, obs=NULL_RECORDER):
        self.spec = spec
        self.obs = obs
        self.rng = np.random.default_rng(spec.seed)
        self.retry = spec.retry
        self._held: list = []         # delayed commit txs awaiting delivery
        self._crashed = False

    # ------------------------------------------------------------------ #
    # process crash
    # ------------------------------------------------------------------ #

    def maybe_crash(self, boundary: int, phase: str) -> None:
        """Die here if the schedule says so.  ``boundary`` is the round index
        for ``round_start``/``pre_chain`` and the completed-rounds count for
        ``post_checkpoint`` (see :data:`repro.faults.spec.CRASH_PHASES`)."""
        s = self.spec
        if self._crashed or boundary != s.crash_round or phase != s.crash_phase:
            return
        self._crashed = True
        self.obs.event("fault.crash", round=boundary, phase=phase,
                       mode=s.crash_mode)
        self.obs.inc("fault.crash")
        if s.crash_mode == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(
            f"injected crash at boundary {boundary} ({phase})")

    def will_crash(self, boundary: int, phase: str) -> bool:
        """True iff :meth:`maybe_crash` would die at this point.  The driver
        uses it to make the in-flight background snapshot durable *before* a
        scheduled ``post_checkpoint`` crash, keeping the crash contract
        ("the boundary's snapshot landed, then the process died") exact."""
        s = self.spec
        return (not self._crashed and boundary == s.crash_round
                and phase == s.crash_phase)

    # ------------------------------------------------------------------ #
    # chain-level faults
    # ------------------------------------------------------------------ #

    def producer_fails(self, round_idx: int) -> bool:
        if round_idx not in self.spec.producer_fail_rounds:
            return False
        self.obs.event("fault.producer_fail", round=round_idx)
        self.obs.inc("fault.producer_fail")
        return True

    def bad_block(self, round_idx: int) -> bool:
        return round_idx in self.spec.bad_block_rounds

    def commit_drop_slot(self, round_idx: int, n_arrived: int) -> int:
        """Arrived-slot index whose commit tx is lost in transit, or -1."""
        if round_idx not in self.spec.drop_commit_rounds or n_arrived == 0:
            return -1
        return int(self.rng.integers(n_arrived))

    def commit_delay_slot(self, round_idx: int, n_arrived: int) -> int:
        """Arrived-slot index whose commit tx arrives a round late, or -1."""
        if round_idx not in self.spec.delay_commit_rounds or n_arrived == 0:
            return -1
        return int(self.rng.integers(n_arrived))

    def hold_commit(self, tx) -> None:
        self._held.append(tx)

    def release_commits(self) -> list:
        """Delayed commits now being delivered (into the current block)."""
        held, self._held = self._held, []
        return held

    # ------------------------------------------------------------------ #
    # retry policy (dropped cohort slots)
    # ------------------------------------------------------------------ #

    def retry_succeeds(self, dropout_p: float) -> bool:
        return float(self.rng.random()) >= float(dropout_p)

    def retry_latency(self, base: float, attempt: int) -> float:
        """Backoff-scaled re-attempt latency with injector-seeded jitter."""
        jitter = float(self.rng.uniform(0.9, 1.1))
        return float(base) * (self.spec.retry_backoff ** attempt) * jitter

    # ------------------------------------------------------------------ #
    # checkpoint corruption
    # ------------------------------------------------------------------ #

    def corrupt_checkpoint(self, path: str, boundary: int) -> None:
        """Damage the snapshot just written at ``boundary`` (bit-flip or
        truncation) so the reader's integrity check must catch it and fall
        back to the previous keep-last-K snapshot."""
        s = self.spec
        if boundary == s.corrupt_checkpoint_round:
            size = os.path.getsize(path)
            with open(path, "r+b") as f:       # flip one payload byte
                f.seek(size - max(1, size // 4))
                b = f.read(1)
                f.seek(-1, os.SEEK_CUR)
                f.write(bytes([b[0] ^ 0xFF]))
            self.obs.event("fault.ckpt_corrupted", round=boundary, path=path)
            self.obs.inc("fault.ckpt_corrupted")
        if boundary == s.truncate_checkpoint_round:
            size = os.path.getsize(path)
            os.truncate(path, size // 2)
            self.obs.event("fault.ckpt_truncated", round=boundary, path=path)
            self.obs.inc("fault.ckpt_truncated")

    # ------------------------------------------------------------------ #
    # checkpoint/resume of the injector itself
    # ------------------------------------------------------------------ #

    def state_dict(self) -> dict:
        return {"rng": self.rng.bit_generator.state,
                "held": list(self._held),
                "crashed": self._crashed}

    def load_state(self, state: dict | None) -> None:
        if state is None:
            return                     # snapshot came from a fault-free run
        self.rng.bit_generator.state = state["rng"]
        self._held = list(state["held"])
        self._crashed = bool(state["crashed"])

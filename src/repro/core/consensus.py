"""CACC — Consensus Algorithm based on Cluster Centroids (paper §IV-C).

From the spectral partition, the client whose Pearson-row is Euclidean-closest
to its cluster's centroid (Eqs. 4–6) becomes that cluster's *representative*.
Representatives join the DPoS-style packing queue: they take turns producing
blocks and act as the aggregation client for their turn.

Centroid selection is jittable; queue rotation is trivially host-side (it is
consumed by the blockchain layer, `repro.blockchain`).
"""
from __future__ import annotations

from functools import partial
from typing import Collection, NamedTuple

import jax
import jax.numpy as jnp


class CentroidResult(NamedTuple):
    representatives: jax.Array   # (n_clusters,) client index per cluster, -1 if empty
    distances: jax.Array         # (m,) distance of each client to its cluster centroid
    centroids: jax.Array         # (n_clusters, m) mean Pearson row per cluster


@partial(jax.jit, static_argnames=("n_clusters",))
def select_centroid_clients(corr: jax.Array, labels: jax.Array, n_clusters: int) -> CentroidResult:
    """Paper Eqs. 4–6 on the Pearson matrix.

    Each client i is represented by its correlation profile Ξ[i, :] (the paper's
    𝔭 — "each point in the cluster").  The cluster centroid is the mean profile
    (Eq. 4); each member's Euclidean distance to it is Eq. 5–6; the argmin
    member becomes the cluster's packing-queue representative.
    """
    m = corr.shape[0]
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)          # (m, C)
    counts = jnp.sum(onehot, axis=0)                                        # (C,)
    sums = onehot.T @ corr.astype(jnp.float32)                              # (C, m)
    centroids = sums / jnp.maximum(counts, 1.0)[:, None]                    # Eq. 4

    diff = corr.astype(jnp.float32) - centroids[labels]                     # Eq. 5
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=1))                           # Eq. 6

    # per-cluster argmin over members only
    big = jnp.finfo(jnp.float32).max
    masked = jnp.where(onehot.T > 0, dist[None, :], big)                    # (C, m)
    reps = jnp.argmin(masked, axis=1)
    reps = jnp.where(counts > 0, reps, -1)
    return CentroidResult(reps.astype(jnp.int32), dist, centroids)


def packing_queue(representatives: jax.Array) -> list[int]:
    """Host-side: ordered block-producer queue for the next epoch (empty
    clusters dropped).  Order is cluster index — deterministic, so every
    validator derives the same queue (DPoS slot schedule)."""
    reps = [int(r) for r in jax.device_get(representatives)]
    return [r for r in reps if r >= 0]


def producer_for_round(queue: list[int], round_idx: int,
                       active: Collection[int] | None = None) -> int:
    """Round-robin slot assignment (paper: representatives 'take turns').

    ``active`` (optional) restricts the slot to clients that are actually
    online this round — under partial participation (``repro.sim``) a
    representative may be a straggler or have dropped out, in which case its
    slot deterministically falls through to the next queue member, exactly as
    every validator would compute it from the same arrival set.
    """
    if not queue:
        raise ValueError("empty packing queue")
    if active is None:
        return queue[round_idx % len(queue)]
    start = round_idx % len(queue)
    for off in range(len(queue)):
        cand = queue[(start + off) % len(queue)]
        if cand in active:
            return cand
    raise ValueError("no active producer in packing queue")

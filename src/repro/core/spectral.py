"""Spectral clustering of client prototype vectors (paper §IV-B).

Fully jittable: normalized graph Laplacian → ``jnp.linalg.eigh`` → k-means on
the spectral embedding with a deterministic farthest-first initialisation and a
fixed iteration count (``lax.fori_loop``).  The matrix is m×m with m = number
of clients (20 in the paper), so this is never a hot spot — it stays XLA.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def normalized_laplacian(affinity: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """L_sym = I - D^{-1/2} A D^{-1/2} with zeroed self-loops."""
    m = affinity.shape[0]
    a = affinity * (1.0 - jnp.eye(m, dtype=affinity.dtype))
    deg = jnp.sum(a, axis=1)
    d_isqrt = 1.0 / jnp.sqrt(jnp.maximum(deg, eps))
    return jnp.eye(m, dtype=affinity.dtype) - a * d_isqrt[:, None] * d_isqrt[None, :]


def spectral_embedding(affinity: jnp.ndarray, n_clusters: int) -> jnp.ndarray:
    """Rows of the k smallest-eigenvalue eigenvectors of L_sym, row-normalised
    (Ng–Jordan–Weiss)."""
    lap = normalized_laplacian(affinity.astype(jnp.float32))
    _, vecs = jnp.linalg.eigh(lap)  # ascending eigenvalues
    emb = vecs[:, :n_clusters]
    norms = jnp.linalg.norm(emb, axis=1, keepdims=True)
    return emb / jnp.maximum(norms, 1e-8)


def _farthest_first_init(points: jnp.ndarray, k: int) -> jnp.ndarray:
    """Deterministic k-means init: start at point 0, greedily add the point
    farthest from the chosen set.  Deterministic so FL rounds are replayable
    (a requirement for blockchain verification — every validator must reproduce
    the same clustering from the same prototypes)."""
    m = points.shape[0]

    def body(i, state):
        centers, mind = state
        d = jnp.sum((points - centers[i - 1][None, :]) ** 2, axis=1)
        mind = jnp.minimum(mind, d)
        nxt = jnp.argmax(mind)
        centers = centers.at[i].set(points[nxt])
        return centers, mind

    centers0 = jnp.zeros((k, points.shape[1]), points.dtype).at[0].set(points[0])
    mind0 = jnp.full((m,), jnp.inf, points.dtype)
    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, mind0))
    return centers


@partial(jax.jit, static_argnames=("n_clusters", "n_iters"))
def kmeans(points: jnp.ndarray, n_clusters: int, n_iters: int = 25) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's algorithm with fixed iterations.  Returns (labels (m,), centers (k, D)).

    Empty clusters keep their previous center (guarded mean), matching
    sklearn-style behaviour closely enough for m≈20 client workloads.
    """
    centers = _farthest_first_init(points, n_clusters)

    def step(_, centers):
        d = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
        labels = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(labels, n_clusters, dtype=points.dtype)  # (m, k)
        counts = jnp.sum(onehot, axis=0)  # (k,)
        sums = onehot.T @ points  # (k, D)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        return jnp.where((counts > 0)[:, None], new, centers)

    centers = jax.lax.fori_loop(0, n_iters, step, centers)
    d = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
    labels = jnp.argmin(d, axis=1)
    return labels, centers


def spectral_cluster(affinity: jnp.ndarray, n_clusters: int, n_iters: int = 25) -> jnp.ndarray:
    """Full pipeline: affinity (m, m) -> labels (m,)."""
    emb = spectral_embedding(affinity, n_clusters)
    labels, _ = kmeans(emb, n_clusters, n_iters)
    return labels

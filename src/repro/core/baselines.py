"""Federated learning strategies: the paper's four baselines + BFLN itself.

A :class:`Strategy` is a bundle of pure functions consumed by
``repro.core.round`` (the legacy full-participation trainer) and
``repro.core.engine`` (the fused, arena-backed round engine):

    round_extras(stacked_params, cx, cy) -> extras   # what the server ships
    local_loss(params, x, y, extras) -> scalar       # client objective
    aggregate(stacked_params, cx, cy) -> AggOut      # server aggregation
    aggregate_cohort(stacked_params, cx, cy, arrived_w) -> CohortAggOut

``extras`` always carries a leading client axis (it is vmapped alongside the
client during local training).  Every baseline is a real implementation, not a
stub — the paper compares against all four in Table II.

``aggregate_cohort`` is the *engine-facing* aggregation stage: jittable,
fixed-shape, and mask-weighted.  ``arrived_w`` is a (k,) 0/1 float arrival
mask over the cohort slots — slots that missed the round contribute zero
aggregation weight but still occupy their slot (no dynamic shapes, so the
fused round program compiles exactly once per cohort size).  Every strategy
also returns a ``(k,)`` cluster-label vector and a ``(k, k)`` affinity
matrix for the blockchain's CACC consensus: BFLN computes them from its PAA
pipeline; flat strategies report the single-cluster view (zeros / identity),
exactly like the async FedBuff path always has.

Sharded-cohort contract: ``aggregate_cohort`` decomposes into two stages so
the engine can run the cohort axis sharded across a device mesh —

    cohort_partial(stacked_params, cx, cy, arrived_w) -> partial | None
    cohort_combine(stacked_params, partial, arrived_w, k) -> CohortAggOut

``cohort_partial`` is the shard-local half: per-slot values with a leading
cohort axis (BFLN: client prototypes), computable on each device's cohort
slice.  ``cohort_combine`` is the deterministic half: it may receive ``m >=
k`` slots (the engine pads the cohort to a shard multiple; slots ``>= k``
carry zero arrival weight) and must return a :class:`CohortAggOut` over the
first ``k`` slots with bits INVARIANT to the padding and to how the slot
axis was sharded — every cohort-axis float reduction inside it goes through
the fixed-order tree primitives in ``repro.core.aggregation``.
``aggregate_cohort`` is derived by :func:`compose_cohort`, so the
single-device legacy oracle and the sharded engine literally share the same
stage functions — replay parity holds by construction.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import (
    masked_tree_sum,
    paa_round,
    tree_cluster_mean_params,
    tree_sum,
)
from repro.core.pearson import pearson_affinity, pearson_matrix
from repro.core.prototypes import classwise_prototypes, client_prototypes
from repro.core.spectral import spectral_cluster
from repro.utils.tree import tree_sq_norm, tree_sub

Pytree = Any


class ModelBundle(NamedTuple):
    """The model as the FL layer sees it (architecture-agnostic)."""
    apply_fn: Callable[[Pytree, jax.Array], jax.Array]   # params, x -> logits
    embed_fn: Callable[[Pytree, jax.Array], jax.Array]   # params, x -> representations
    num_classes: int


class AggOut(NamedTuple):
    stacked_params: Pytree
    labels: jax.Array | None = None          # cluster assignment (BFLN only)
    cluster_sizes: jax.Array | None = None   # (C,) (BFLN only)
    corr: jax.Array | None = None            # Pearson matrix (BFLN only)


class CohortAggOut(NamedTuple):
    """Engine-facing aggregation output (all fixed-shape, jit-friendly)."""
    stacked_params: Pytree       # (k, ...) per-slot aggregated params
    labels: jax.Array            # (k,) cluster assignment (zeros if unclustered)
    corr: jax.Array              # (k, k) affinity for CACC (eye if unclustered)


class Strategy(NamedTuple):
    name: str
    round_extras: Callable[[Pytree, jax.Array, jax.Array], Any]
    local_loss: Callable[[Pytree, jax.Array, jax.Array, Any], jax.Array]
    aggregate: Callable[[Pytree, jax.Array, jax.Array], AggOut]
    # jittable mask-weighted aggregation consumed by the fused round engine;
    # (stacked_params, cx, cy, arrived_w) -> CohortAggOut — derived from the
    # two-stage contract below via compose_cohort()
    aggregate_cohort: Callable[
        [Pytree, jax.Array, jax.Array, jax.Array], "CohortAggOut"] | None = None
    # True: round_extras returns ONE pytree shared by every client (no
    # leading client axis) — local_train broadcasts it via in_axes=None
    # instead of shipping k redundant copies through the vmap
    shared_extras: bool = False
    # sharded-cohort stages (see module docstring): per-slot partial values
    # computable on a cohort shard, and the deterministic combine that
    # tolerates zero-weight padding slots beyond k
    cohort_partial: Callable[
        [Pytree, jax.Array, jax.Array, jax.Array], Any] | None = None
    cohort_combine: Callable[
        [Pytree, Any, jax.Array, int], "CohortAggOut"] | None = None


def _xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def _flatten_batches(cx: jax.Array, cy: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(m, nb, B, ...) -> (m, nb*B, ...)."""
    m = cx.shape[0]
    return (cx.reshape(m, -1, *cx.shape[3:]), cy.reshape(m, -1))


def _global_mean(stacked_params: Pytree) -> Pytree:
    mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked_params)
    m = jax.tree.leaves(stacked_params)[0].shape[0]
    return jax.tree.map(lambda g: jnp.broadcast_to(g[None], (m,) + g.shape), mean)


def _tree_masked_mean(stacked_params: Pytree, arrived_w: jax.Array,
                      k: int) -> Pytree:
    """Mask-weighted global mean, broadcast back to the first ``k`` slots.

    The fixed-shape form of FedAvg under partial participation: slots with
    zero arrival weight contribute nothing, and the denominator is the
    arrived count (clamped, so an empty round degrades to zeros harmlessly —
    the engine's scatter mask drops those rows anyway).  Tree-ordered
    reductions keep the bits invariant to cohort sharding and to
    zero-weight padding slots beyond ``k``.
    """
    w = arrived_w.astype(jnp.float32)
    denom = jnp.maximum(tree_sum(w), 1.0)

    def leaf(x):
        mean = masked_tree_sum(x.astype(jnp.float32), w) / denom
        return jnp.broadcast_to(mean[None], (k,) + mean.shape).astype(x.dtype)

    return jax.tree.map(leaf, stacked_params)


def compose_cohort(partial_fn: Callable, combine_fn: Callable) -> Callable:
    """Derive the one-shot ``aggregate_cohort`` from the two sharded-cohort
    stages.  The legacy oracle driver calls this composition with ``m == k``
    while the sharded engine calls the stages separately with ``m >= k`` —
    same functions, same bits (the combine is padding/partition-invariant by
    contract), so engine-vs-oracle replay parity needs no extra proof."""

    def aggregate_cohort(stacked_params, cx, cy, arrived_w):
        part = partial_fn(stacked_params, cx, cy, arrived_w)
        stacked_params, part = barrier_combine_inputs(stacked_params, part)
        return combine_fn(stacked_params, part, arrived_w, cx.shape[0])

    return aggregate_cohort


def barrier_combine_inputs(stacked_params: Pytree, partial: Any):
    """Pin the combine stage's inputs with an optimization barrier.

    Without it, XLA is free to clone the producer math (local training, the
    partial stage) into each consumer's fusion, and the clones can vectorise
    differently — ULP-different inputs to the combine, which breaks the
    bit-identical-replay-across-partitionings contract.  The barrier forces
    ONE materialisation that every consumer reads, so the combine's
    fixed-order trees see the same bits in the fused single-device program,
    the sharded program, and the legacy oracle."""
    if partial is None:
        return jax.lax.optimization_barrier(stacked_params), None
    return jax.lax.optimization_barrier((stacked_params, partial))


def _no_partial(stacked_params, cx, cy, arrived_w):
    """Shard-local stage for strategies whose combine needs only the trained
    params themselves (fedavg/fedprox/fedhkd mask-weighted mean, fedproto
    identity)."""
    return None


def _single_cluster_view(m: int) -> tuple[jax.Array, jax.Array]:
    """CACC inputs for unclustered strategies: one cluster, identity affinity
    — the exact view the async FedBuff path has always fed the chain."""
    return jnp.zeros((m,), jnp.int32), jnp.eye(m, dtype=jnp.float32)


# --------------------------------------------------------------------------- #
# FedAvg (McMahan et al., 2017)
# --------------------------------------------------------------------------- #

def make_fedavg(model: ModelBundle) -> Strategy:
    def round_extras(stacked_params, cx, cy):
        m = cx.shape[0]
        return jnp.zeros((m,), jnp.float32)  # no server payload

    def local_loss(params, x, y, extras):
        return _xent(model.apply_fn(params, x), y)

    def aggregate(stacked_params, cx, cy):
        return AggOut(_global_mean(stacked_params))

    def cohort_combine(stacked_params, partial, arrived_w, k):
        return CohortAggOut(_tree_masked_mean(stacked_params, arrived_w, k),
                            *_single_cluster_view(k))

    return Strategy("fedavg", round_extras, local_loss, aggregate,
                    compose_cohort(_no_partial, cohort_combine),
                    cohort_partial=_no_partial, cohort_combine=cohort_combine)


# --------------------------------------------------------------------------- #
# FedProx (Li et al., 2018): CE + (µ/2)‖w − w_global‖²
# --------------------------------------------------------------------------- #

def make_fedprox(model: ModelBundle, mu: float = 0.01) -> Strategy:
    def round_extras(stacked_params, cx, cy):
        # ONE shared anchor (no per-client broadcast): the prox gradient
        # µ·(w − w_global) then reads a single (N,) anchor inside the fused
        # step instead of k identical copies (shared_extras=True below)
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked_params)

    def local_loss(params, x, y, anchor):
        ce = _xent(model.apply_fn(params, x), y)
        prox = 0.5 * mu * tree_sq_norm(tree_sub(params, anchor))
        return ce + prox

    def aggregate(stacked_params, cx, cy):
        return AggOut(_global_mean(stacked_params))

    def cohort_combine(stacked_params, partial, arrived_w, k):
        return CohortAggOut(_tree_masked_mean(stacked_params, arrived_w, k),
                            *_single_cluster_view(k))

    return Strategy("fedprox", round_extras, local_loss, aggregate,
                    compose_cohort(_no_partial, cohort_combine),
                    shared_extras=True,
                    cohort_partial=_no_partial, cohort_combine=cohort_combine)


# --------------------------------------------------------------------------- #
# FedProto (Tan et al., 2022): only class prototypes are shared; models stay
# personal.  Local objective: CE + λ‖proto_c(batch) − global_proto_c‖².
# --------------------------------------------------------------------------- #

def make_fedproto(model: ModelBundle, lam: float = 1.0) -> Strategy:
    K = model.num_classes

    def _client_protos(stacked_params, cx, cy):
        fx, fy = _flatten_batches(cx, cy)

        def one(params, x, y):
            return classwise_prototypes(model.embed_fn, params, x, y, K)

        return jax.vmap(one)(stacked_params, fx, fy)  # (m, K, D), (m, K)

    def round_extras(stacked_params, cx, cy):
        protos, counts = _client_protos(stacked_params, cx, cy)
        w = counts / jnp.maximum(jnp.sum(counts, axis=0, keepdims=True), 1.0)
        global_protos = jnp.sum(protos * w[..., None], axis=0)  # (K, D)
        m = cx.shape[0]
        return jnp.broadcast_to(global_protos[None], (m,) + global_protos.shape)

    def local_loss(params, x, y, global_protos):
        logits = model.apply_fn(params, x)
        ce = _xent(logits, y)
        protos, counts = classwise_prototypes(model.embed_fn, params, x, y, K)
        mask = (counts > 0).astype(jnp.float32)
        d = jnp.sum(jnp.square(protos - global_protos), axis=-1)  # (K,)
        align = jnp.sum(d * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + lam * align

    def aggregate(stacked_params, cx, cy):
        return AggOut(stacked_params)  # models are never averaged

    def cohort_combine(stacked_params, partial, arrived_w, k):
        # personal models: arrived slots keep their freshly trained params
        # (the engine's scatter mask drops non-arrived rows on its own);
        # slicing to k drops the engine's shard-padding slots
        return CohortAggOut(jax.tree.map(lambda x: x[:k], stacked_params),
                            *_single_cluster_view(k))

    return Strategy("fedproto", round_extras, local_loss, aggregate,
                    compose_cohort(_no_partial, cohort_combine),
                    cohort_partial=_no_partial, cohort_combine=cohort_combine)


# --------------------------------------------------------------------------- #
# FedHKD (Chen & Vikalo, 2023): clients ship "hyper-knowledge" — per-class
# mean representations AND mean soft predictions; the server aggregates both
# and clients distil against them.  Built on FedAvg model averaging.
# --------------------------------------------------------------------------- #

def make_fedhkd(model: ModelBundle, lam_rep: float = 0.05,
                lam_soft: float = 0.05, temp: float = 2.0) -> Strategy:
    K = model.num_classes

    def _hyper_knowledge(stacked_params, cx, cy):
        fx, fy = _flatten_batches(cx, cy)

        def one(params, x, y):
            protos, counts = classwise_prototypes(model.embed_fn, params, x, y, K)
            soft = jax.nn.softmax(model.apply_fn(params, x) / temp, axis=-1)
            onehot = jax.nn.one_hot(y, K, dtype=soft.dtype)
            soft_per_class = (onehot.T @ soft) / jnp.maximum(counts, 1.0)[:, None]
            return protos, soft_per_class, counts

        return jax.vmap(one)(stacked_params, fx, fy)

    def round_extras(stacked_params, cx, cy):
        protos, softs, counts = _hyper_knowledge(stacked_params, cx, cy)
        w = counts / jnp.maximum(jnp.sum(counts, axis=0, keepdims=True), 1.0)
        H = jnp.sum(protos * w[..., None], axis=0)        # (K, D)
        Q = jnp.sum(softs * w[..., None], axis=0)         # (K, K)
        m = cx.shape[0]
        return (jnp.broadcast_to(H[None], (m,) + H.shape),
                jnp.broadcast_to(Q[None], (m,) + Q.shape))

    def local_loss(params, x, y, extras):
        H, Q = extras
        logits = model.apply_fn(params, x)
        ce = _xent(logits, y)
        reps = model.embed_fn(params, x)
        rep_loss = jnp.mean(jnp.sum(jnp.square(reps - H[y]), axis=-1))
        logp = jax.nn.log_softmax(logits / temp, axis=-1)
        q = jnp.maximum(Q[y], 1e-8)
        kd = jnp.mean(jnp.sum(q * (jnp.log(q) - logp), axis=-1))
        return ce + lam_rep * rep_loss + lam_soft * kd

    def aggregate(stacked_params, cx, cy):
        return AggOut(_global_mean(stacked_params))

    def cohort_combine(stacked_params, partial, arrived_w, k):
        return CohortAggOut(_tree_masked_mean(stacked_params, arrived_w, k),
                            *_single_cluster_view(k))

    return Strategy("fedhkd", round_extras, local_loss, aggregate,
                    compose_cohort(_no_partial, cohort_combine),
                    cohort_partial=_no_partial, cohort_combine=cohort_combine)


# --------------------------------------------------------------------------- #
# BFLN (this paper): plain CE locally; PAA clustered aggregation server-side.
# The probe batch (ψ same-category samples, paper §IV-B) is sampled by the
# aggregation client and closed over per round by the caller.
# --------------------------------------------------------------------------- #

def make_bfln(model: ModelBundle, probe_x: jax.Array, n_clusters: int,
              kmeans_iters: int = 25) -> Strategy:
    def round_extras(stacked_params, cx, cy):
        m = cx.shape[0]
        return jnp.zeros((m,), jnp.float32)

    def local_loss(params, x, y, extras):
        return _xent(model.apply_fn(params, x), y)

    def aggregate(stacked_params, cx, cy):
        res = paa_round(model.embed_fn, stacked_params, probe_x, n_clusters,
                        kmeans_iters=kmeans_iters)
        return AggOut(res.new_stacked_params, res.labels, res.cluster_sizes, res.corr)

    def cohort_partial(stacked_params, cx, cy, arrived_w):
        # per-slot prototypes (m, D): the ONLY cross-slot input the combine
        # needs — each device embeds the shared probe batch through its own
        # cohort slice, and only this small matrix gets replicated
        return client_prototypes(model.embed_fn, stacked_params, probe_x)

    def cohort_combine(stacked_params, protos, arrived_w, k):
        # PAA with the arrival mask as aggregation weights.  Pearson +
        # spectral run on the REAL k slots only (slicing the Pearson input
        # is per-entry exact, and the (k, k) spectral problem must match the
        # single-device program op for op); the cluster means run over ALL
        # m >= k slots through the fixed-order tree segment sums — padding
        # slots carry zero weight, so their garbage params and arbitrary
        # labels contribute exactly +0.0
        corr = pearson_matrix(protos[:k])
        labels = spectral_cluster(pearson_affinity(corr), n_clusters,
                                  kmeans_iters)
        m = protos.shape[0]
        labels_m = labels if m == k else jnp.concatenate(
            [labels, jnp.zeros((m - k,), labels.dtype)])
        new_params = tree_cluster_mean_params(stacked_params, labels_m,
                                              n_clusters, weights=arrived_w)
        if m != k:
            new_params = jax.tree.map(lambda x: x[:k], new_params)
        return CohortAggOut(new_params, labels, corr)

    return Strategy("bfln", round_extras, local_loss, aggregate,
                    compose_cohort(cohort_partial, cohort_combine),
                    cohort_partial=cohort_partial,
                    cohort_combine=cohort_combine)


STRATEGY_FACTORIES = {
    "fedavg": make_fedavg,
    "fedprox": make_fedprox,
    "fedproto": make_fedproto,
    "fedhkd": make_fedhkd,
}

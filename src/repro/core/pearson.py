"""Pearson correlation between client prototype vectors (paper Eq. 2–3).

The paper's stated reason for Pearson over cosine: it reflects the *strength*
of linear similarity (centering removes per-model representation offsets), not
just direction.  The m×m matrix Ξ feeds spectral clustering in PAA.

The pure-jnp implementation here is the oracle; ``repro.kernels.pearson`` is
the Pallas MXU version used on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def pearson_matrix(protos: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Ξ[i, j] = corr(𝔙_i, 𝔙_j) over feature dim.  ``protos``: (m, D) -> (m, m).

    Implemented as center → normalize → gram, which is exactly Eq. 2 vectorised:
    cov(a,b)/(σ_a σ_b) = <â, b̂> with â = (a-µ_a)/‖a-µ_a‖.
    """
    protos = protos.astype(jnp.float32)
    centered = protos - jnp.mean(protos, axis=1, keepdims=True)
    norms = jnp.linalg.norm(centered, axis=1, keepdims=True)
    normalized = centered / jnp.maximum(norms, eps)
    corr = normalized @ normalized.T
    return jnp.clip(corr, -1.0, 1.0)


def pearson_affinity(corr: jnp.ndarray) -> jnp.ndarray:
    """Map correlations [-1, 1] to a non-negative affinity [0, 1] for spectral
    clustering (anti-correlated models should be *maximally dissimilar*)."""
    return (corr + 1.0) * 0.5

"""Federated local-training substrate.

All clients train **simultaneously** via ``vmap`` over the leading client axis
(stacked params, stacked data) — the TPU-native replacement for the paper's
sequential 20-client loop.  Local optimisation is a ``lax.scan`` over
(epochs × batches), so a full federated round is a single jitted program.

Data layout: ``x (m, n_batches, B, ...)``, ``y (m, n_batches, B)``.  The
Dirichlet partitioner (repro.data) resamples every client to the same number
of batches so the stacked layout is rectangular.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer

Pytree = Any
# loss_fn(params, x, y, extras) -> scalar
LossFn = Callable[[Pytree, jax.Array, jax.Array, Any], jax.Array]


class LocalTrainResult(NamedTuple):
    params: Pytree        # stacked (m, ...)
    opt_state: Pytree
    mean_loss: jax.Array  # (m,)


def local_train(
    loss_fn: LossFn,
    opt: Optimizer,
    stacked_params: Pytree,
    stacked_opt_state: Pytree,
    x: jax.Array,
    y: jax.Array,
    extras: Any,
    epochs: int,
    shared_extras: bool = False,
) -> LocalTrainResult:
    """Run ``epochs`` passes of minibatch SGD on every client in parallel.

    ``extras`` is an arbitrary pytree of auxiliary inputs consumed by the
    strategy's loss — e.g. the anchor params for FedProx, global prototypes
    for FedProto.  Per-client by default (leading client axis on every leaf,
    vmapped alongside the client); ``shared_extras=True`` instead broadcasts
    ONE extras pytree to every client (``in_axes=None``), so a cohort-wide
    anchor never materialises k redundant copies.
    """
    grad_fn = jax.value_and_grad(loss_fn)

    def one_client(params, opt_state, cx, cy, cextras):
        nb = cx.shape[0]

        def step(carry, idx):
            params, opt_state = carry
            bx, by = cx[idx % nb], cy[idx % nb]
            loss, grads = grad_fn(params, bx, by, cextras)
            params, opt_state = opt.update(params, grads, opt_state)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), jnp.arange(epochs * nb))
        return params, opt_state, jnp.mean(losses)

    params, opt_state, losses = jax.vmap(
        one_client, in_axes=(0, 0, 0, 0, None if shared_extras else 0))(
        stacked_params, stacked_opt_state, x, y, extras)
    return LocalTrainResult(params, opt_state, losses)


def evaluate(
    predict_fn: Callable[[Pytree, jax.Array], jax.Array],
    stacked_params: Pytree,
    x: jax.Array,
    y: jax.Array,
) -> jax.Array:
    """Per-client accuracy on (m, N, ...) eval data -> (m,)."""

    def one(params, cx, cy):
        logits = predict_fn(params, cx)
        return jnp.mean((jnp.argmax(logits, axis=-1) == cy).astype(jnp.float32))

    return jax.vmap(one)(stacked_params, x, y)


def masked_global_evaluate(
    predict_fn: Callable[[Pytree, jax.Array], jax.Array],
    stacked_params: Pytree,
    x: jax.Array,
    y: jax.Array,
    mask: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Fixed-shape, arrival-masked mean client accuracy.

    ``global_evaluate`` over a dynamically-sized sub-cohort forces one jit
    recompile per distinct arrival count (the leading dim changes round to
    round).  Here the cohort shape stays fixed and non-arrived slots are
    weighted out: returns ``(masked mean accuracy, per-client accuracies)``.
    """

    def one(params):
        logits = predict_fn(params, x)
        return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))

    accs = jax.vmap(one)(stacked_params)                       # (m,)
    w = mask.astype(jnp.float32)
    return jnp.sum(accs * w) / jnp.maximum(jnp.sum(w), 1.0), accs


def global_evaluate(
    predict_fn: Callable[[Pytree, jax.Array], jax.Array],
    stacked_params: Pytree,
    x: jax.Array,
    y: jax.Array,
) -> jax.Array:
    """Mean accuracy of each client's personalized model on the *shared* test
    set (the paper's Table II metric is mean client accuracy)."""

    def one(params):
        logits = predict_fn(params, x)
        return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))

    return jnp.mean(jax.vmap(one)(stacked_params))

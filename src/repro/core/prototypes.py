"""Prototype extraction (paper Eq. 1 and the Preliminary §III-B definition).

A *prototype* is the mean representation vector a model produces over a probe
batch of ψ same-category samples.  The aggregation client holds the probe batch
and feeds the **same** inputs through every client's local model (this is the
key difference vs FedProto-style methods where each client computes prototypes
on its own data — here prototypes are comparable because the inputs are shared).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


def prototype(embed_fn: Callable, params: Pytree, probe_x: jax.Array) -> jax.Array:
    """Paper Eq. 1:  𝔙 = (1/ψ) Σ_i  LM(x_i).

    ``embed_fn(params, x) -> (ψ, D)`` representation vectors; returns (D,).
    """
    reps = embed_fn(params, probe_x)
    return jnp.mean(reps, axis=0)


def client_prototypes(
    embed_fn: Callable,
    stacked_params: Pytree,
    probe_x: jax.Array,
) -> jax.Array:
    """Prototypes for every client at once.

    ``stacked_params`` has a leading ``n_clients`` axis on every leaf. The probe
    batch is broadcast (the aggregation client samples it once per round and
    feeds the *same* data to each local model — paper §IV-B).  Returns
    ``(n_clients, D)``.
    """
    return jax.vmap(lambda p: prototype(embed_fn, p, probe_x))(stacked_params)


def classwise_prototypes(
    embed_fn: Callable,
    params: Pytree,
    x: jax.Array,
    y: jax.Array,
    num_classes: int,
) -> tuple[jax.Array, jax.Array]:
    """Per-class prototypes (used by the FedProto baseline).

    Returns ``(protos (K, D), counts (K,))``; classes absent from the batch get
    a zero prototype and a zero count (callers mask on counts).
    """
    reps = embed_fn(params, x)  # (B, D)
    onehot = jax.nn.one_hot(y, num_classes, dtype=reps.dtype)  # (B, K)
    sums = jnp.einsum("bk,bd->kd", onehot, reps)
    counts = jnp.sum(onehot, axis=0)
    protos = sums / jnp.maximum(counts, 1.0)[:, None]
    return protos, counts

"""BFLN core: the paper's contribution as composable JAX modules.

* PAA  — prototype extraction + Pearson similarity + spectral clustering +
         cluster-masked FedAvg (`aggregation.paa_round`)
* CACC — centroid-representative selection + DPoS packing queue (`consensus`)
* Incentives — cluster-size-superlinear reward allocation (`incentives`)
* Baselines — FedAvg / FedProx / FedProto / FedHKD (`baselines`)
* Round driver — jitted FL round + host-side blockchain protocol (`round`)
"""
from repro.core.aggregation import PAAResult, cluster_mean_params, paa_round  # noqa: F401
from repro.core.baselines import (  # noqa: F401
    CohortAggOut,
    ModelBundle,
    Strategy,
    make_bfln,
    make_fedavg,
    make_fedhkd,
    make_fedproto,
    make_fedprox,
)
from repro.core.consensus import packing_queue, producer_for_round, select_centroid_clients  # noqa: F401
from repro.core.engine import RoundEngine, SyncRoundOut  # noqa: F401
from repro.core.incentives import RewardAllocation, allocate_rewards  # noqa: F401
from repro.core.pearson import pearson_affinity, pearson_matrix  # noqa: F401
from repro.core.prototypes import classwise_prototypes, client_prototypes, prototype  # noqa: F401
from repro.core.round import ChainRoundResult, FederatedTrainer, RoundRecord, digest_of  # noqa: F401
from repro.core.spectral import kmeans, spectral_cluster, spectral_embedding  # noqa: F401

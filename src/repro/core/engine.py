"""Fused, buffer-donated federated round engine over the parameter arena.

BFLN's hot path (paper Fig. 1 steps 3–5) used to be a chain of separate
device programs with host round-trips between them: an eager per-leaf cohort
gather, the jitted train+PAA program, a second jitted fingerprint pipeline,
an eager per-leaf scatter that reallocated the full population params, and a
``global_evaluate`` whose leading dim varied with the arrival count — one
jit recompile per distinct count.

The engine collapses all of it into ONE jitted, ``donate_argnums``-donated
program per (mode, cohort_size):

    arena gather → local_train → strategy cohort aggregation (BFLN: PAA —
    prototypes, Pearson, spectral, cluster-masked mean; baselines:
    mask-weighted means / personal models) → cohort fingerprint residues →
    masked scatter-back into the donated arena

The engine is **strategy-generic**: every registered strategy
(`repro.api.registry`) fuses into the same donated step through its
cohort aggregation stages — BFLN keeps its exact PAA op sequence, while the
Table II baselines get fixed-shape mask-weighted aggregation and the
single-cluster CACC view (labels = zeros, affinity = identity).

Arrival is a fixed-shape mask everywhere — no ``np.flatnonzero`` dynamic
indexing, no varying leading dims — so the jit cache hits every round and
the arena buffer is updated in place (donation) instead of reallocating
O(n_clients · N_params) bytes.  Only O(cohort) bytes cross the host
boundary per round: fingerprint residues, cluster labels, the Pearson
matrix for CACC, and scalar loss/accuracy.

Evaluation entries are split so each compiles exactly once: a fixed-shape
mask-weighted cohort eval (round metric), a single-row global eval (async),
and a population eval with its own entry (final metric) so the final pass
never retraces the round-eval program.

Mesh mode (``sharding=`` a client-axis ``NamedSharding`` from
``repro.runtime.arena.ShardedParamArena``): the arena rows stay sharded
across the device mesh — each device holds ``n/shards`` rows and the full
O(n_clients · N_params) matrix never materialises on one device.  The
COHORT axis is sharded end-to-end too (``cohort_mode="sharded"``): the
cohort is padded to a shard multiple (padding slots gather row 0, train on
zero data, and carry zero arrival weight), each device trains its slice and
computes its slice of the batched fingerprints, and aggregation splits into
a shard-local per-slot partial (``Strategy.cohort_partial``; BFLN: client
prototypes) plus a deterministic combine (``Strategy.cohort_combine``) that
runs on the REPLICATED trained cohort block — its cohort-axis reductions
are fixed-order trees / pre-sorted segment sums (``repro.core.aggregation``)
whose replicated program is device-local and matches the single-device
composition bit for bit, and zero-weight padding slots are where-guarded to
contribute exactly +0.0, so seeded replay stays bit-identical to the
single-device engine.  Server payloads that reduce over the cohort
(``Strategy.round_extras`` — the fedprox anchor, fedproto/fedhkd global
prototypes) are computed replicated on the REAL ``[:k]`` slots with the
exact single-device op sequence, then re-padded per client.  The masked
scatter-back writes only the real cohort indices into the rows each device
owns.  ``cohort_mode="replicated"`` keeps the PR 4 behaviour (every device
runs the identical full-shape cohort program) for A/B comparison — it costs
shards× redundant compute.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import barrier_combine_inputs
from repro.core.fl import local_train
from repro.kernels.fingerprint import fingerprint_rows, format_digest
from repro.obs import NULL_RECORDER
from repro.runtime.arena import ArenaLayout, bitcast_u32

Pytree = Any

COHORT_MODES = ("sharded", "replicated")


class SyncRoundOut(NamedTuple):
    """Device outputs of one fused sync round (all O(cohort) or smaller)."""
    labels: jax.Array       # (k,) cluster assignment
    corr: jax.Array         # (k, k) Pearson matrix (CACC input)
    residues: jax.Array     # (k, 2) uint32 fingerprint residues
    mean_loss: jax.Array    # scalar
    new_rows: jax.Array     # (k, N) the cohort's post-scatter arena rows —
                            # eval reads THESE, never the full arena, so the
                            # next round's donation has no pending consumer


class RoundEngine:
    """Jitted entry points for arena-backed federated rounds.

    One instance per simulation; jax caches one executable per entry point
    and cohort size (shapes are otherwise fixed by construction, so varying
    *arrival counts* never retrace).  ``sync_step`` donates the arena —
    callers must rebind, e.g.
    ``arena.data = engine.sync_step(arena.data, ...)[0]``.
    """

    def __init__(
        self,
        layout: ArenaLayout,
        *,
        apply_fn: Callable,
        strategy,                       # repro.core.baselines.Strategy
        opt,                            # repro.optim.Optimizer
        n_clusters: int,
        local_epochs: int,
        stacked_apply_fn: Callable | None = None,
        sharding=None,                  # client-axis NamedSharding (mesh mode)
        cohort_mode: str = "sharded",   # mesh mode: "sharded" | "replicated"
        obs=NULL_RECORDER,              # repro.obs flight recorder
    ):
        if strategy.aggregate_cohort is None:
            raise ValueError(
                f"strategy {strategy.name!r} has no aggregate_cohort stage — "
                "the fused round engine needs the jittable mask-weighted "
                "aggregation (see repro.core.baselines.Strategy)")
        if cohort_mode not in COHORT_MODES:
            raise ValueError(
                f"cohort_mode must be one of {COHORT_MODES}, "
                f"got {cohort_mode!r}")
        self.layout = layout
        self.n_clusters = n_clusters
        self.strategy_name = strategy.name
        self.sharding = sharding
        shards = sharding.mesh.devices.size if sharding is not None else 1
        sharded_cohort = sharding is not None and shards > 1 \
            and cohort_mode == "sharded"
        if sharded_cohort and (strategy.cohort_partial is None
                               or strategy.cohort_combine is None):
            raise ValueError(
                f"strategy {strategy.name!r} has no cohort_partial/"
                "cohort_combine stages — sharded cohort mode needs the "
                "two-stage contract (see repro.core.baselines); use "
                "MeshSpec(cohort='replicated') to fall back to the "
                "replicated cohort program")
        # resolved mode, readable by the driver/bench for obs metadata
        self.cohort_mode = "sharded" if sharded_cohort else (
            "replicated" if sharding is not None else "single")
        self.cohort_shards = shards if sharded_cohort else 1
        pad_mult = self.cohort_shards

        if sharding is not None:
            from repro.launch.sharding import cohort_shardings
            cshard, replicated = cohort_shardings(sharding.mesh)

            def _rep(x):
                """Pin a value replicated: every device holds (and computes)
                the identical full-shape array — the bit-identity anchor for
                the combine stage and all O(k)-sized outputs."""
                return jax.lax.with_sharding_constraint(x, replicated)

            def _shd(x):
                """Pin the population arena to its row sharding."""
                return jax.lax.with_sharding_constraint(x, sharding)

            def _csh(x):
                """Pin a (k_pad, ...) per-slot value to the cohort-axis
                sharding: each device touches only its cohort slice."""
                return jax.lax.with_sharding_constraint(x, cshard)
        else:
            _rep = _shd = _csh = lambda x: x

        def _pad0(x, pad):
            """Append ``pad`` zero slots along the leading (cohort) axis."""
            if pad == 0:
                return x
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)

        def _cohort_pad(k: int) -> int:
            return (-(-k // pad_mult) * pad_mult) - k if sharded_cohort else 0

        def _client_accs(params, ex, ey):
            """(m,) per-client accuracy on the shared eval batch.  Uses the
            model's width-concatenated stacked forward when available — the
            vmap form broadcasts the shared batch into a batched dot that
            XLA CPU lowers ~2.5× slower at 100-client cohorts."""
            if stacked_apply_fn is not None:
                logits = stacked_apply_fn(params, ex)          # (m, B, C)
            else:
                logits = jax.vmap(lambda p: apply_fn(p, ex))(params)
            hits = (jnp.argmax(logits, axis=-1) == ey[None, :])
            return jnp.mean(hits.astype(jnp.float32), axis=1)

        def _train(cohort_params, cx, cy, extras):
            opt_state = jax.vmap(opt.init)(cohort_params)
            res = local_train(strategy.local_loss, opt, cohort_params,
                              opt_state, cx, cy, extras, local_epochs,
                              shared_extras=strategy.shared_extras)
            # pin the trained params: every downstream consumer (fingerprint,
            # partial, combine, scatter) must read ONE materialisation — XLA
            # otherwise clones training math into consumer fusions that can
            # vectorise differently, and ULP-divergent clones break replay
            # bit-identity across partitionings (see
            # repro.core.baselines.barrier_combine_inputs)
            return jax.lax.optimization_barrier(res)

        def _pad_extras(extras, pad):
            """Per-client server payloads get zero padding slots; a shared
            payload (no client axis) ships as-is."""
            if strategy.shared_extras or pad == 0:
                return extras
            return jax.tree.map(lambda e: _pad0(e, pad), extras)

        def _sync_step(arena, cohort_idx, cx, cy, arrived):
            k = cohort_idx.shape[0]
            pad = _cohort_pad(k)
            if sharded_cohort:
                # padding slots gather row 0 (any valid row — their outputs
                # are sliced away and their arrival weight is zero)
                idx_p = jnp.concatenate(
                    [cohort_idx, jnp.zeros((pad,), cohort_idx.dtype)]) \
                    if pad else cohort_idx
                # shard-aware gather: each device receives only its cohort
                # slice — no replicated (k, N) block materialises
                rows = _csh(arena[idx_p])
                cx_p, cy_p = _csh(_pad0(cx, pad)), _csh(_pad0(cy, pad))
                arrived_p = _pad0(arrived, pad)
                # server payload on the replicated REAL slots with the exact
                # single-device op sequence: round_extras may reduce over
                # the cohort (fedprox anchor, fedproto/fedhkd global
                # prototypes) and must never see padding slots.  Inputs AND
                # outputs are pinned replicated — leaving the output free
                # lets GSPMD back-propagate the training consumer's cohort
                # sharding through the broadcast into the reduction,
                # rewriting it into partial sums + all-reduce (ULP flips)
                rows_real = _rep(rows[:k])
                extras = _pad_extras(jax.tree.map(_rep, strategy.round_extras(
                    layout.unflatten(rows_real), _rep(cx), _rep(cy))), pad)
                res = _train(layout.unflatten(rows), cx_p, cy_p, extras)
                # shard-local per-slot partial (BFLN: prototypes); only this
                # small matrix replicates into the deterministic combine —
                # whose cohort-axis reductions are fixed-order trees, so the
                # bits match the single-device composition exactly
                partial = strategy.cohort_partial(res.params, cx_p, cy_p,
                                                  arrived_p)
                if partial is not None:
                    partial = jax.tree.map(_rep, partial)
                # the combine runs fully REPLICATED: left cohort-sharded,
                # GSPMD rewrites the fixed-order tree levels into pair
                # all-reduces whose rounding path diverges from the
                # single-device composition by 1 ULP at near-halfway cases.
                # Replicating first keeps every combine op device-local and
                # bit-identical to mesh_shards=1; only the small (k_pad, N)
                # cohort block replicates, never the (n, N) arena.
                sp_rep = jax.tree.map(_rep, res.params)
                sp_b, partial_b = barrier_combine_inputs(sp_rep, partial)
                # named scope -> HLO metadata op_name: lets the compiled-
                # artifact audit (repro.analysis.hlo_audit) attribute any
                # collective inside the combine phase and fail the build —
                # an all-reduce here IS the partial-sum drift bug.  The
                # OUTPUTS are pinned replicated as well: constraining only
                # the inputs leaves GSPMD free to propagate the row-sharded
                # scatter layout backwards and partition the combine body
                # (kmeans/eigh dots pick up partial-sum all-reduces); with
                # both ends pinned the body compiles device-local and any
                # resharding happens after the scope, on the small outputs
                with jax.named_scope("cohort_combine"):
                    # arrived_p also feeds the cohort-SHARDED partial stage;
                    # the combine gets its own replicated pin, or GSPMD
                    # propagates the sharding through the arrival weighting
                    # into the clustering interior
                    agg = strategy.cohort_combine(sp_b, partial_b,
                                                  _rep(arrived_p), k)
                    agg = jax.tree.map(_rep, agg)
                local_rows = layout.flatten(res.params)    # (k_pad, N) sharded
                residues = fingerprint_rows(bitcast_u32(local_rows))[:k]
                mean_loss = jnp.mean(res.mean_loss[:k])
                prev_rows = rows[:k]
            else:
                rows = _rep(arena[cohort_idx])
                extras = strategy.round_extras(layout.unflatten(rows), cx, cy)
                res = _train(layout.unflatten(rows), cx, cy, extras)
                # aggregation over ALL cohort slots (stragglers burn local
                # compute too); only the aggregation weights honour the
                # arrival mask
                with jax.named_scope("cohort_combine"):
                    agg = strategy.aggregate_cohort(res.params, cx, cy,
                                                    arrived)
                local_rows = layout.flatten(res.params)
                residues = fingerprint_rows(bitcast_u32(local_rows))
                mean_loss = jnp.mean(res.mean_loss)
                prev_rows = rows
            new_rows = layout.flatten(agg.stacked_params)
            # masked scatter-back: arrived slots adopt their aggregated
            # params, everyone else keeps their previous personalized row.
            # Only the k REAL indices are written (a padded scatter would
            # race its duplicate row-0 slots), and each device lands only
            # the rows it owns — the donated arena stays row-sharded.
            upd = jnp.where(arrived[:, None] > 0, new_rows, prev_rows)
            arena = _shd(arena.at[cohort_idx].set(upd))
            return arena, SyncRoundOut(agg.labels, agg.corr, residues,
                                       mean_loss, upd)

        def _async_step(base_rows, cx, cy):
            """FedBuff flush batch: local updates + digests, no aggregation.
            The merge is gated by chain verification (a host decision) and
            reuses the same jitted ``weighted_delta_mean`` collective as the
            legacy driver — a fixed-order tree over replicated buffer rows,
            so sharing the executable keeps replay bit-identical across
            engine on/off and across mesh widths."""
            k = base_rows.shape[0]
            pad = _cohort_pad(k)
            if sharded_cohort:
                rows = _csh(_pad0(base_rows, pad))
                cx_p, cy_p = _csh(_pad0(cx, pad)), _csh(_pad0(cy, pad))
                # extras replicated end-to-end, as in the sync step: the
                # flush-batch rows feed both the sharded training gather and
                # the cohort-reducing server payload, and the latter must
                # keep the single-device op sequence
                extras = _pad_extras(jax.tree.map(_rep, strategy.round_extras(
                    layout.unflatten(_rep(base_rows)), _rep(cx), _rep(cy))),
                    pad)
                res = _train(layout.unflatten(rows), cx_p, cy_p, extras)
                local_rows_p = layout.flatten(res.params)
                residues = fingerprint_rows(bitcast_u32(local_rows_p))[:k]
                local_rows = _rep(local_rows_p[:k])
                mean_loss = jnp.mean(res.mean_loss[:k])
                return local_rows, residues, mean_loss
            extras = strategy.round_extras(layout.unflatten(base_rows),
                                           cx, cy)
            res = _train(layout.unflatten(base_rows), cx, cy, extras)
            local_rows = layout.flatten(res.params)
            residues = fingerprint_rows(bitcast_u32(local_rows))
            return local_rows, residues, jnp.mean(res.mean_loss)

        def _eval_cohort(cohort_rows, arrived, labels, ex, ey):
            """Fixed-shape mask-weighted cohort accuracy (the jnp-generic
            reference is ``repro.core.fl.masked_global_evaluate``).  Takes
            the cohort's (k, N) rows — NOT the arena — so a deferred eval
            never blocks the next round's arena donation.  In sharded mode
            the per-client forwards shard over the cohort axis; the scalar
            combine runs on the replicated (k,) accuracies with the exact
            single-device op sequence."""
            k = cohort_rows.shape[0]
            pad = _cohort_pad(k)
            if sharded_cohort:
                rows = _csh(_pad0(cohort_rows, pad))
                accs = _rep(_client_accs(layout.unflatten(rows), ex, ey)[:k])
            else:
                accs = _client_accs(layout.unflatten(cohort_rows), ex, ey)
            w = arrived.astype(jnp.float32)
            acc = jnp.sum(accs * w) / jnp.maximum(jnp.sum(w), 1.0)
            onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32) \
                * w[:, None]
            sizes = jnp.sum(onehot, axis=0)                   # (C,) arrived
            cacc = jnp.sum(onehot * accs[:, None], axis=0) \
                / jnp.maximum(sizes, 1.0)
            return acc, cacc

        def _eval_global(global_row, ex, ey):
            return _client_accs(layout.unflatten(global_row[None]), ex, ey)[0]

        def _eval_population(arena, ids, ex, ey):
            n = ids.shape[0]
            pad = _cohort_pad(n)
            if sharded_cohort:
                # duplicate id 0 into the padding slots; their accuracies
                # are sliced away before the mean
                ids_p = jnp.concatenate(
                    [ids, jnp.zeros((pad,), ids.dtype)]) if pad else ids
                rows = _csh(arena[ids_p])
                accs = _rep(_client_accs(layout.unflatten(rows), ex, ey)[:n])
                return jnp.mean(accs)
            rows = _rep(arena[ids])       # replicate only the sampled rows
            return jnp.mean(_client_accs(layout.unflatten(rows), ex, ey))

        self.obs = obs
        self.sync_step = jax.jit(_sync_step, donate_argnums=(0,))
        self.async_step = jax.jit(_async_step)
        self.eval_cohort = jax.jit(_eval_cohort)
        self.eval_global = jax.jit(_eval_global)
        self.eval_population = jax.jit(_eval_population)
        # raw jitted fns — cache_sizes() must read _cache_size() on these
        # even when the public attributes are wrapped with call counters
        self._entries = {
            "sync_step": self.sync_step,
            "async_step": self.async_step,
            "eval_cohort": self.eval_cohort,
            "eval_global": self.eval_global,
            "eval_population": self.eval_population,
        }
        if obs.enabled:
            # per-entry call counters (metrics only — timing lives in the
            # caller's spans, which know the round index)
            def _counted(name, fn):
                def wrapper(*a, **kw):
                    obs.inc(f"engine.calls.{name}")
                    return fn(*a, **kw)
                return wrapper
            for name, fn in self._entries.items():
                setattr(self, name, _counted(name, fn))

    # ------------------------------------------------------------------ #

    def cache_sizes(self) -> dict[str, int]:
        """Compiled-executable count per entry point (jit cache sizes).

        The engine's contract is ONE compile per entry per (mode,
        cohort_size) — arrival-count variation must never retrace.  The
        round benchmark and the cache-stability regression test assert on
        this dict.
        """
        return {name: fn._cache_size() for name, fn in self._entries.items()}

    def entry_names(self) -> list[str]:
        """The engine's jitted entry points, in a fixed order."""
        return list(self._entries)

    def lower_entry(self, name: str, *args):
        """Lower (without executing) the RAW jitted entry ``name`` on
        ``args`` — the hook the compiled-artifact audit uses to inspect the
        exact programs the driver runs.  Bypasses the obs call-count
        wrappers so lowering never shows up as an engine call."""
        return self._entries[name].lower(*args)

    def format_digests(self, residues) -> list[str]:
        """(k, 2) uint32 residues -> per-client digest strings (host side)."""
        res = np.asarray(jax.device_get(residues))
        return [format_digest(row, self.layout.n_params) for row in res]

"""Fused, buffer-donated federated round engine over the parameter arena.

BFLN's hot path (paper Fig. 1 steps 3–5) used to be a chain of separate
device programs with host round-trips between them: an eager per-leaf cohort
gather, the jitted train+PAA program, a second jitted fingerprint pipeline,
an eager per-leaf scatter that reallocated the full population params, and a
``global_evaluate`` whose leading dim varied with the arrival count — one
jit recompile per distinct count.

The engine collapses all of it into ONE jitted, ``donate_argnums``-donated
program per (mode, cohort_size):

    arena gather → local_train → strategy.aggregate_cohort (BFLN: PAA —
    prototypes, Pearson, spectral, cluster-masked mean; baselines:
    mask-weighted means / personal models) → cohort fingerprint residues →
    masked scatter-back into the donated arena

The engine is **strategy-generic**: every registered strategy
(`repro.api.registry`) fuses into the same donated step through its
``aggregate_cohort`` stage — BFLN keeps its exact PAA op sequence (seeded
replay stays bit-identical to the BFLN-only engine), while the Table II
baselines get fixed-shape mask-weighted aggregation and the single-cluster
CACC view (labels = zeros, affinity = identity).

Arrival is a fixed-shape mask everywhere — no ``np.flatnonzero`` dynamic
indexing, no varying leading dims — so the jit cache hits every round and
the arena buffer is updated in place (donation) instead of reallocating
O(n_clients · N_params) bytes.  Only O(cohort) bytes cross the host
boundary per round: fingerprint residues, cluster labels, the Pearson
matrix for CACC, and scalar loss/accuracy.

Evaluation entries are split so each compiles exactly once: a fixed-shape
mask-weighted cohort eval (round metric), a single-row global eval (async),
and a population eval with its own entry (final metric) so the final pass
never retraces the round-eval program.

Mesh mode (``sharding=`` a client-axis ``NamedSharding`` from
``repro.runtime.arena.ShardedParamArena``): the arena rows stay sharded
across the device mesh — each device holds ``n/shards`` rows and the full
O(n_clients · N_params) matrix never materialises on one device.  The
cohort gather is constrained to a *replicated* (k, N) block, so every
device runs exactly the single-device cohort program (train, PAA,
fingerprints — identical shapes, identical arithmetic, bit-identical
seeded replay), and the masked scatter-back lands only on the rows each
device owns.  Per-round collective traffic is O(k · N): the cohort
all-gather in, the row updates out.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl import local_train
from repro.kernels.fingerprint import fingerprint_rows, format_digest
from repro.obs import NULL_RECORDER
from repro.runtime.arena import ArenaLayout, bitcast_u32

Pytree = Any


class SyncRoundOut(NamedTuple):
    """Device outputs of one fused sync round (all O(cohort) or smaller)."""
    labels: jax.Array       # (k,) cluster assignment
    corr: jax.Array         # (k, k) Pearson matrix (CACC input)
    residues: jax.Array     # (k, 2) uint32 fingerprint residues
    mean_loss: jax.Array    # scalar
    new_rows: jax.Array     # (k, N) the cohort's post-scatter arena rows —
                            # eval reads THESE, never the full arena, so the
                            # next round's donation has no pending consumer


class RoundEngine:
    """Jitted entry points for arena-backed federated rounds.

    One instance per simulation; jax caches one executable per entry point
    and cohort size (shapes are otherwise fixed by construction, so varying
    *arrival counts* never retrace).  ``sync_step`` donates the arena —
    callers must rebind, e.g.
    ``arena.data = engine.sync_step(arena.data, ...)[0]``.
    """

    def __init__(
        self,
        layout: ArenaLayout,
        *,
        apply_fn: Callable,
        strategy,                       # repro.core.baselines.Strategy
        opt,                            # repro.optim.Optimizer
        n_clusters: int,
        local_epochs: int,
        stacked_apply_fn: Callable | None = None,
        sharding=None,                  # client-axis NamedSharding (mesh mode)
        obs=NULL_RECORDER,              # repro.obs flight recorder
    ):
        if strategy.aggregate_cohort is None:
            raise ValueError(
                f"strategy {strategy.name!r} has no aggregate_cohort stage — "
                "the fused round engine needs the jittable mask-weighted "
                "aggregation (see repro.core.baselines.Strategy)")
        self.layout = layout
        self.n_clusters = n_clusters
        self.strategy_name = strategy.name
        self.sharding = sharding
        if sharding is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            replicated = NamedSharding(sharding.mesh, PartitionSpec())

            def _rep(x):
                """Pin cohort-sized values replicated: every device computes
                the identical full-shape program — the bit-identity anchor."""
                return jax.lax.with_sharding_constraint(x, replicated)

            def _shd(x):
                """Pin the population arena to its row sharding."""
                return jax.lax.with_sharding_constraint(x, sharding)
        else:
            _rep = _shd = lambda x: x

        def _client_accs(params, ex, ey):
            """(m,) per-client accuracy on the shared eval batch.  Uses the
            model's width-concatenated stacked forward when available — the
            vmap form broadcasts the shared batch into a batched dot that
            XLA CPU lowers ~2.5× slower at 100-client cohorts."""
            if stacked_apply_fn is not None:
                logits = stacked_apply_fn(params, ex)          # (m, B, C)
            else:
                logits = jax.vmap(lambda p: apply_fn(p, ex))(params)
            hits = (jnp.argmax(logits, axis=-1) == ey[None, :])
            return jnp.mean(hits.astype(jnp.float32), axis=1)

        def _train(cohort_params, cx, cy):
            opt_state = jax.vmap(opt.init)(cohort_params)
            extras = strategy.round_extras(cohort_params, cx, cy)
            return local_train(strategy.local_loss, opt, cohort_params,
                               opt_state, cx, cy, extras, local_epochs,
                               shared_extras=strategy.shared_extras)

        def _sync_step(arena, cohort_idx, cx, cy, arrived):
            # (k, N) gather; mesh mode all-gathers ONLY the cohort rows to a
            # replicated block (O(k·N) bytes), never the arena
            rows = _rep(arena[cohort_idx])
            res = _train(layout.unflatten(rows), cx, cy)
            # aggregation over ALL cohort slots (stragglers burn local compute
            # too); only the aggregation weights honour the arrival mask.
            # BFLN's stage keeps cluster-masked FedAvg per-leaf (same dot
            # shapes as the legacy driver -> same GEMM blocking ->
            # bit-identical replay at every cohort size; the flat
            # `cluster_mean_rows` form is the same math but a (C,k)x(k,N)
            # contraction blocks differently at k≈100 — it remains the TPU
            # cluster_agg kernel path).
            agg = strategy.aggregate_cohort(res.params, cx, cy, arrived)
            local_rows = layout.flatten(res.params)
            residues = fingerprint_rows(bitcast_u32(local_rows))
            new_rows = layout.flatten(agg.stacked_params)
            # masked scatter-back: arrived slots adopt their aggregated
            # params, everyone else keeps their previous personalized row
            upd = jnp.where(arrived[:, None] > 0, new_rows, rows)
            # mesh mode: each device scatters only into the rows it owns, so
            # the donated arena stays row-sharded end to end
            arena = _shd(arena.at[cohort_idx].set(upd))
            return arena, SyncRoundOut(agg.labels, agg.corr, residues,
                                       jnp.mean(res.mean_loss), upd)

        def _async_step(base_rows, cx, cy):
            """FedBuff flush batch: local updates + digests, no aggregation.
            The merge is gated by chain verification (a host decision) and
            reuses the same jitted ``weighted_delta_mean`` collective as the
            legacy driver — it is O(k·N) and sharing the executable keeps
            replay bit-identical across engine on/off."""
            res = _train(layout.unflatten(base_rows), cx, cy)
            local_rows = layout.flatten(res.params)
            residues = fingerprint_rows(bitcast_u32(local_rows))
            return local_rows, residues, jnp.mean(res.mean_loss)

        def _eval_cohort(cohort_rows, arrived, labels, ex, ey):
            """Fixed-shape mask-weighted cohort accuracy (the jnp-generic
            reference is ``repro.core.fl.masked_global_evaluate``).  Takes
            the cohort's (k, N) rows — NOT the arena — so a deferred eval
            never blocks the next round's arena donation."""
            params = layout.unflatten(cohort_rows)
            accs = _client_accs(params, ex, ey)
            w = arrived.astype(jnp.float32)
            acc = jnp.sum(accs * w) / jnp.maximum(jnp.sum(w), 1.0)
            onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32) \
                * w[:, None]
            sizes = jnp.sum(onehot, axis=0)                   # (C,) arrived
            cacc = jnp.sum(onehot * accs[:, None], axis=0) \
                / jnp.maximum(sizes, 1.0)
            return acc, cacc

        def _eval_global(global_row, ex, ey):
            return _client_accs(layout.unflatten(global_row[None]), ex, ey)[0]

        def _eval_population(arena, ids, ex, ey):
            rows = _rep(arena[ids])       # replicate only the sampled rows
            return jnp.mean(_client_accs(layout.unflatten(rows), ex, ey))

        self.obs = obs
        self.sync_step = jax.jit(_sync_step, donate_argnums=(0,))
        self.async_step = jax.jit(_async_step)
        self.eval_cohort = jax.jit(_eval_cohort)
        self.eval_global = jax.jit(_eval_global)
        self.eval_population = jax.jit(_eval_population)
        # raw jitted fns — cache_sizes() must read _cache_size() on these
        # even when the public attributes are wrapped with call counters
        self._entries = {
            "sync_step": self.sync_step,
            "async_step": self.async_step,
            "eval_cohort": self.eval_cohort,
            "eval_global": self.eval_global,
            "eval_population": self.eval_population,
        }
        if obs.enabled:
            # per-entry call counters (metrics only — timing lives in the
            # caller's spans, which know the round index)
            def _counted(name, fn):
                def wrapper(*a, **kw):
                    obs.inc(f"engine.calls.{name}")
                    return fn(*a, **kw)
                return wrapper
            for name, fn in self._entries.items():
                setattr(self, name, _counted(name, fn))

    # ------------------------------------------------------------------ #

    def cache_sizes(self) -> dict[str, int]:
        """Compiled-executable count per entry point (jit cache sizes).

        The engine's contract is ONE compile per entry per (mode,
        cohort_size) — arrival-count variation must never retrace.  The
        round benchmark and the cache-stability regression test assert on
        this dict.
        """
        return {name: fn._cache_size() for name, fn in self._entries.items()}

    def format_digests(self, residues) -> list[str]:
        """(k, 2) uint32 residues -> per-client digest strings (host side)."""
        res = np.asarray(jax.device_get(residues))
        return [format_digest(row, self.layout.n_params) for row in res]

"""PAA — Prototype-based Aggregation Algorithm (paper §IV-B).

Pipeline per round (all jittable, fixed shapes):

    stacked local params ──embed probe batch──▶ prototypes (m, D)
    prototypes ──Pearson──▶ Ξ (m, m) ──spectral──▶ labels (m,)
    labels + stacked params ──cluster-masked FedAvg──▶ per-client new params

"Cluster-masked FedAvg" is the collective at the heart of the paper: clients in
the same cluster receive the mean of that cluster's parameters.  With stacked
parameters it is a one-hot membership matmul — the pure-jnp form below is the
oracle for the ``repro.kernels.cluster_agg`` Pallas kernel.

Deterministic tree reductions (``tree_sum`` / ``masked_tree_sum`` /
``tree_cluster_mean_params``): every cohort-axis float reduction consumed by
the fused round engine is a fixed-order adjacent-pair binary tree of explicit
elementwise adds.  ``jnp.sum`` / ``tensordot`` leave the reduction order to
the backend — the tree pins it in the math graph itself, so the jitted
program matches the pure-numpy oracle bit for bit, and zero-weight (masked /
padding) slots are where-guarded to contribute exactly +0.0 — appending them
never changes a single output bit.  One discipline applies on a mesh: the
reduced axis must be REPLICATED before the tree runs (the engine's combine
stage does this).  Reducing a still-sharded axis lets GSPMD rewrite tree
levels into cross-device collectives whose CPU codegen rounds differently
than the single-device program — ULP drift that breaks seeded replay
(``tests/test_tree_reduction.py`` pins both facts).  Oracles live in
``repro.kernels.ref`` (``tree_sum_ref`` / ``tree_cluster_mean_ref``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pearson import pearson_affinity, pearson_matrix
from repro.core.prototypes import client_prototypes
from repro.core.spectral import spectral_cluster

Pytree = Any


class PAAResult(NamedTuple):
    new_stacked_params: Pytree     # per-client aggregated params (personalized)
    labels: jax.Array              # (m,) cluster assignment
    corr: jax.Array                # (m, m) Pearson matrix Ξ
    prototypes: jax.Array          # (m, D)
    cluster_sizes: jax.Array       # (n_clusters,)


# --------------------------------------------------------------------------- #
# deterministic fixed-order tree reductions (replicate-then-reduce bit identity)
# --------------------------------------------------------------------------- #

def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def tree_sum(x: jax.Array, axis: int = 0) -> jax.Array:
    """Fixed-order adjacent-pair binary-tree sum along ``axis``.

    The reduction is unrolled into an explicit chain of elementwise adds
    (padding the axis to the next power of two with +0.0), so the float
    rounding sequence is a property of the *graph*: the jitted program and
    the numpy oracle agree bit for bit, and padding within the same
    power-of-two width is a no-op.  Callers on a mesh must replicate the
    reduced axis first — over a sharded axis GSPMD turns tree levels into
    cross-device collectives with different rounding (see module docstring).
    """
    x = jnp.moveaxis(x, axis, 0)
    m = x.shape[0]
    p = _next_pow2(m)
    if p != m:
        x = jnp.concatenate(
            [x, jnp.zeros((p - m,) + x.shape[1:], x.dtype)], axis=0)
    while x.shape[0] > 1:
        h = x.shape[0] // 2
        a = x.reshape((h, 2) + x.shape[1:])
        x = a[:, 0] + a[:, 1]
    return x[0]


def masked_tree_sum(x: jax.Array, w: jax.Array, axis: int = 0) -> jax.Array:
    """Weighted tree sum where zero-weight slots contribute EXACTLY +0.0.

    ``where(w > 0, w·x, +0.0)`` guards against the two ways a dead slot
    could still flip bits: ``-0.0`` contributions (which turn a +0.0 partial
    into -0.0) and ``0·inf = NaN`` from garbage values in padding slots.
    Appending zero-weight slots is therefore a bitwise no-op, which is what
    lets the engine pad the cohort to a shard multiple.
    """
    wb = jnp.moveaxis(
        w.astype(x.dtype).reshape(w.shape + (1,) * (x.ndim - 1)), 0, axis)
    contrib = jnp.where(wb > 0, x * wb, jnp.zeros((), x.dtype))
    return tree_sum(contrib, axis=axis)


def tree_cluster_mean_params(stacked_params: Pytree, labels: jax.Array,
                             n_clusters: int,
                             weights: jax.Array | None = None) -> Pytree:
    """Cluster-masked FedAvg via fixed-order tree segment sums.

    Same semantics as :func:`cluster_mean_params` (every slot receives its
    cluster's weighted mean, denominator clamped so an all-masked cluster
    degrades to zeros), but each cluster's sum is a where-guarded tree over
    the slot axis instead of a one-hot contraction — run on a replicated
    slot axis (the engine's combine discipline) the bits match the numpy
    oracle exactly and appending zero-weight slots is a no-op.  The
    gather-back is a ``take`` (no second contraction).
    """
    m = labels.shape[0]
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)      # (m, C)
    w = jnp.ones((m,), jnp.float32) if weights is None \
        else weights.astype(jnp.float32)
    wo = onehot * w[:, None]                                            # (m, C)
    denom = jnp.maximum(tree_sum(wo, axis=0), 1e-9)                     # (C,)

    def leaf(x):
        xf = x.astype(jnp.float32)
        woT = wo.T.reshape((n_clusters, m) + (1,) * (xf.ndim - 1))
        contrib = jnp.where(woT > 0, woT * xf[None],
                            jnp.zeros((), jnp.float32))                 # (C, m, ...)
        sums = tree_sum(contrib, axis=1)                                # (C, ...)
        means = sums / denom.reshape((n_clusters,) + (1,) * (xf.ndim - 1))
        return jnp.take(means, labels, axis=0).astype(x.dtype)          # (m, ...)

    return jax.tree.map(leaf, stacked_params)


def _cluster_weights(labels: jax.Array, n_clusters: int,
                     weights: jax.Array | None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared membership weights: (onehot (m,C), weighted onehot, denom (C,))."""
    m = labels.shape[0]
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)      # (m, C)
    w = jnp.ones((m,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wo = onehot * w[:, None]                                            # (m, C)
    denom = jnp.maximum(jnp.sum(wo, axis=0), 1e-9)                      # (C,)
    return onehot, wo, denom


def cluster_mean_rows(rows: jax.Array, labels: jax.Array, n_clusters: int,
                      weights: jax.Array | None = None) -> jax.Array:
    """Cluster-masked FedAvg over **arena rows** — the flat (m, N) form of
    ``cluster_mean_params`` (same two-step math, identical sums).

    The stacked params already live as one ``(m, N_params)`` matrix
    (``repro.runtime.arena``), so the whole FedAvg is two matmuls instead of
    a per-leaf tree map — exactly the input shape ``kernels.cluster_agg``
    streams on TPU.  Note: a single (C,m)×(m,N) contraction may block its
    m-loop differently than the per-leaf dots at large m, so results can
    drift from ``cluster_mean_params`` by float ulps; the fused round engine
    therefore keeps the per-leaf form for bit-identical legacy replay and
    this form is the TPU kernel-path input.
    """
    onehot, wo, denom = _cluster_weights(labels, n_clusters, weights)
    reduce_w = (wo / denom[None, :]).T                                  # (C, m)
    means = jnp.tensordot(reduce_w, rows.astype(jnp.float32), axes=(1, 0))
    return jnp.tensordot(onehot, means, axes=(1, 0)).astype(rows.dtype)


def cluster_mean_params(stacked_params: Pytree, labels: jax.Array, n_clusters: int,
                        weights: jax.Array | None = None,
                        method: str = "two_step") -> Pytree:
    """FedAvg within each cluster, broadcast back to members.

    For every leaf ``x`` of shape (m, ...):
        out[i] = mean_{j : labels[j]==labels[i]} x[j]
    Optionally weighted (paper uses |D_i|/n weights inside FedAvg; with equal
    client data volumes this reduces to the plain mean).

    ``method``:
      * ``"mix"`` — one (m × m) mixing matmul.  On a client-sharded mesh this
        all-reduces the FULL stacked parameter set (the contraction axis is
        the sharded one) — O(m·N_params) collective bytes.
      * ``"two_step"`` (default) — reduce to the C cluster means first, then
        gather back: O(C·N_params) collective bytes, an m/C× win measured in
        EXPERIMENTS.md §Perf.  Mathematically identical (same sums).
    """
    onehot, wo, denom = _cluster_weights(labels, n_clusters, weights)

    if method == "mix":
        # membership[i, j] = w_j * [labels_i == labels_j] / sum_cluster_w
        mix = (onehot / denom[None, :]) @ wo.T                      # (m, m)

        def leaf(x):
            # tensordot over the client axis — no reshape, so sharded layouts
            # survive intact on a pod mesh (launch/fl_target)
            out = jnp.tensordot(mix, x.astype(jnp.float32), axes=(1, 0))
            return out.astype(x.dtype)
    elif method in ("two_step", "two_step_bf16"):
        reduce_w = (wo / denom[None, :]).T                          # (C, m)
        # bf16 variant: cross-shard partial sums travel in bf16 — halves the
        # collective bytes; fine for means of ≤m values (§Perf iteration 2)
        tdt = jnp.bfloat16 if method == "two_step_bf16" else jnp.float32

        def leaf(x):
            means = jnp.tensordot(reduce_w.astype(tdt), x.astype(tdt), axes=(1, 0))
            out = jnp.tensordot(onehot.astype(tdt), means, axes=(1, 0))  # (m, ...)
            return out.astype(x.dtype)
    else:
        raise ValueError(method)

    return jax.tree.map(leaf, stacked_params)


def cluster_sizes(labels: jax.Array, n_clusters: int) -> jax.Array:
    return jnp.sum(jax.nn.one_hot(labels, n_clusters, dtype=jnp.int32), axis=0)


def paa_round(
    embed_fn: Callable,
    stacked_params: Pytree,
    probe_x: jax.Array,
    n_clusters: int,
    weights: jax.Array | None = None,
    kmeans_iters: int = 25,
    agg_method: str = "two_step",
) -> PAAResult:
    """One full PAA aggregation (paper steps 3–5 of Fig. 1)."""
    protos = client_prototypes(embed_fn, stacked_params, probe_x)      # (m, D)
    corr = pearson_matrix(protos)                                      # (m, m)
    labels = spectral_cluster(pearson_affinity(corr), n_clusters, kmeans_iters)
    new_params = cluster_mean_params(stacked_params, labels, n_clusters, weights,
                                     method=agg_method)
    sizes = cluster_sizes(labels, n_clusters)
    return PAAResult(new_params, labels, corr, protos, sizes)

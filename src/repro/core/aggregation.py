"""PAA — Prototype-based Aggregation Algorithm (paper §IV-B).

Pipeline per round (all jittable, fixed shapes):

    stacked local params ──embed probe batch──▶ prototypes (m, D)
    prototypes ──Pearson──▶ Ξ (m, m) ──spectral──▶ labels (m,)
    labels + stacked params ──cluster-masked FedAvg──▶ per-client new params

"Cluster-masked FedAvg" is the collective at the heart of the paper: clients in
the same cluster receive the mean of that cluster's parameters.  With stacked
parameters it is a one-hot membership matmul — the pure-jnp form below is the
oracle for the ``repro.kernels.cluster_agg`` Pallas kernel.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pearson import pearson_affinity, pearson_matrix
from repro.core.prototypes import client_prototypes
from repro.core.spectral import spectral_cluster

Pytree = Any


class PAAResult(NamedTuple):
    new_stacked_params: Pytree     # per-client aggregated params (personalized)
    labels: jax.Array              # (m,) cluster assignment
    corr: jax.Array                # (m, m) Pearson matrix Ξ
    prototypes: jax.Array          # (m, D)
    cluster_sizes: jax.Array       # (n_clusters,)


def _cluster_weights(labels: jax.Array, n_clusters: int,
                     weights: jax.Array | None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared membership weights: (onehot (m,C), weighted onehot, denom (C,))."""
    m = labels.shape[0]
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32)      # (m, C)
    w = jnp.ones((m,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    wo = onehot * w[:, None]                                            # (m, C)
    denom = jnp.maximum(jnp.sum(wo, axis=0), 1e-9)                      # (C,)
    return onehot, wo, denom


def cluster_mean_rows(rows: jax.Array, labels: jax.Array, n_clusters: int,
                      weights: jax.Array | None = None) -> jax.Array:
    """Cluster-masked FedAvg over **arena rows** — the flat (m, N) form of
    ``cluster_mean_params`` (same two-step math, identical sums).

    The stacked params already live as one ``(m, N_params)`` matrix
    (``repro.runtime.arena``), so the whole FedAvg is two matmuls instead of
    a per-leaf tree map — exactly the input shape ``kernels.cluster_agg``
    streams on TPU.  Note: a single (C,m)×(m,N) contraction may block its
    m-loop differently than the per-leaf dots at large m, so results can
    drift from ``cluster_mean_params`` by float ulps; the fused round engine
    therefore keeps the per-leaf form for bit-identical legacy replay and
    this form is the TPU kernel-path input.
    """
    onehot, wo, denom = _cluster_weights(labels, n_clusters, weights)
    reduce_w = (wo / denom[None, :]).T                                  # (C, m)
    means = jnp.tensordot(reduce_w, rows.astype(jnp.float32), axes=(1, 0))
    return jnp.tensordot(onehot, means, axes=(1, 0)).astype(rows.dtype)


def cluster_mean_params(stacked_params: Pytree, labels: jax.Array, n_clusters: int,
                        weights: jax.Array | None = None,
                        method: str = "two_step") -> Pytree:
    """FedAvg within each cluster, broadcast back to members.

    For every leaf ``x`` of shape (m, ...):
        out[i] = mean_{j : labels[j]==labels[i]} x[j]
    Optionally weighted (paper uses |D_i|/n weights inside FedAvg; with equal
    client data volumes this reduces to the plain mean).

    ``method``:
      * ``"mix"`` — one (m × m) mixing matmul.  On a client-sharded mesh this
        all-reduces the FULL stacked parameter set (the contraction axis is
        the sharded one) — O(m·N_params) collective bytes.
      * ``"two_step"`` (default) — reduce to the C cluster means first, then
        gather back: O(C·N_params) collective bytes, an m/C× win measured in
        EXPERIMENTS.md §Perf.  Mathematically identical (same sums).
    """
    onehot, wo, denom = _cluster_weights(labels, n_clusters, weights)

    if method == "mix":
        # membership[i, j] = w_j * [labels_i == labels_j] / sum_cluster_w
        mix = (onehot / denom[None, :]) @ wo.T                      # (m, m)

        def leaf(x):
            # tensordot over the client axis — no reshape, so sharded layouts
            # survive intact on a pod mesh (launch/fl_target)
            out = jnp.tensordot(mix, x.astype(jnp.float32), axes=(1, 0))
            return out.astype(x.dtype)
    elif method in ("two_step", "two_step_bf16"):
        reduce_w = (wo / denom[None, :]).T                          # (C, m)
        # bf16 variant: cross-shard partial sums travel in bf16 — halves the
        # collective bytes; fine for means of ≤m values (§Perf iteration 2)
        tdt = jnp.bfloat16 if method == "two_step_bf16" else jnp.float32

        def leaf(x):
            means = jnp.tensordot(reduce_w.astype(tdt), x.astype(tdt), axes=(1, 0))
            out = jnp.tensordot(onehot.astype(tdt), means, axes=(1, 0))  # (m, ...)
            return out.astype(x.dtype)
    else:
        raise ValueError(method)

    return jax.tree.map(leaf, stacked_params)


def cluster_sizes(labels: jax.Array, n_clusters: int) -> jax.Array:
    return jnp.sum(jax.nn.one_hot(labels, n_clusters, dtype=jnp.int32), axis=0)


def paa_round(
    embed_fn: Callable,
    stacked_params: Pytree,
    probe_x: jax.Array,
    n_clusters: int,
    weights: jax.Array | None = None,
    kmeans_iters: int = 25,
    agg_method: str = "two_step",
) -> PAAResult:
    """One full PAA aggregation (paper steps 3–5 of Fig. 1)."""
    protos = client_prototypes(embed_fn, stacked_params, probe_x)      # (m, D)
    corr = pearson_matrix(protos)                                      # (m, m)
    labels = spectral_cluster(pearson_affinity(corr), n_clusters, kmeans_iters)
    new_params = cluster_mean_params(stacked_params, labels, n_clusters, weights,
                                     method=agg_method)
    sizes = cluster_sizes(labels, n_clusters)
    return PAAResult(new_params, labels, corr, protos, sizes)

"""The BFLN federated round driver (paper Fig. 1, steps 1–6).

The jittable inner program (local training + aggregation) is wrapped by the
host-side blockchain protocol (hash commitments, block packing, consensus
verification, token settlement).  The same driver runs every baseline strategy
— baselines simply skip the chain (no clustering → no CACC queue).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.blockchain import Blockchain, TokenLedger, Transaction, TxPool, hash_params
from repro.core import consensus as cacc
from repro.core.baselines import AggOut, ModelBundle, Strategy
from repro.core.fl import LocalTrainResult, global_evaluate, local_train
from repro.core.incentives import allocate_rewards
from repro.optim import Optimizer
from repro.utils.tree import tree_index

Pytree = Any


@dataclass
class RoundRecord:
    round_idx: int
    mean_loss: float
    accuracy: float
    labels: np.ndarray | None = None
    cluster_sizes: np.ndarray | None = None
    rewards: np.ndarray | None = None
    balances: np.ndarray | None = None
    producer: int = -1
    verified_frac: float = 1.0


@dataclass
class FederatedTrainer:
    """Runs strategy rounds over stacked clients; BFLN adds the chain."""

    model: ModelBundle
    strategy: Strategy
    opt: Optimizer
    local_epochs: int = 5
    n_clusters: int = 0              # >0 enables CACC/chain (BFLN)
    total_reward: float = 20.0       # paper: "Local training total stake reward"
    rho: float = 2.0                 # paper Table I
    initial_stake: float = 5.0       # paper Table I
    use_chain: bool = True
    history: list[RoundRecord] = field(default_factory=list)

    def __post_init__(self):
        self.chain = Blockchain()
        self.pool = TxPool()
        self.ledger: TokenLedger | None = None
        self._queue: list[int] = []

        strategy = self.strategy

        @jax.jit
        def _train_round(stacked_params, stacked_opt, cx, cy):
            extras = strategy.round_extras(stacked_params, cx, cy)
            res: LocalTrainResult = local_train(
                strategy.local_loss, self.opt, stacked_params, stacked_opt,
                cx, cy, extras, self.local_epochs)
            agg: AggOut = strategy.aggregate(res.params, cx, cy)
            return res.params, agg, res.opt_state, jnp.mean(res.mean_loss)

        self._train_round = _train_round
        self._eval = jax.jit(partial(global_evaluate, self.model.apply_fn))

    # ------------------------------------------------------------------ #

    def init(self, stacked_params: Pytree) -> tuple[Pytree, Pytree]:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        if self.use_chain:
            self.ledger = TokenLedger(n, self.initial_stake)
        opt_state = jax.vmap(self.opt.init)(stacked_params)
        return stacked_params, opt_state

    def run_round(
        self,
        round_idx: int,
        stacked_params: Pytree,
        stacked_opt: Pytree,
        cx: jax.Array,
        cy: jax.Array,
        test_x: jax.Array,
        test_y: jax.Array,
        tamper: dict[int, Pytree] | None = None,
    ) -> tuple[Pytree, Pytree, RoundRecord]:
        """One full BFLN round.  ``tamper`` (tests only) swaps the params a
        client *claims* (hash-commits) for something else, exercising the
        consensus rejection path."""
        n = cx.shape[0]

        local_params, agg, stacked_opt, mean_loss = self._train_round(
            stacked_params, stacked_opt, cx, cy)

        record = RoundRecord(round_idx, float(mean_loss), 0.0)

        if self.use_chain and agg.labels is not None:
            # -- Fig.1 step 2: clients commit local-model hashes ----------- #
            hashes = []
            for i in range(n):
                committed = (tamper or {}).get(i, tree_index(local_params, i))
                h = hash_params(committed)
                hashes.append(hash_params(tree_index(local_params, i)))
                self.pool.submit(Transaction("model_hash", i, h, round_idx))

            # -- CACC: centroid representatives -> packing queue ----------- #
            cres = cacc.select_centroid_clients(agg.corr, agg.labels, self.n_clusters)
            self._queue = cacc.packing_queue(cres.representatives) or self._queue or [0]
            producer = cacc.producer_for_round(self._queue, round_idx)

            # -- Fig.1 step 5: producer records aggregated hashes ---------- #
            self.pool.submit(Transaction(
                "agg_hash", producer, json.dumps(sorted(hashes)), round_idx))
            block = self.chain.pack_block(round_idx, producer, self.pool)

            # -- Fig.1 step 6: consensus verification + incentives --------- #
            verified = self.chain.verify_round(block, n)
            alloc = allocate_rewards(agg.labels, self.n_clusters,
                                     self.total_reward, self.rho)
            assert self.ledger is not None
            self.ledger.mint_reward_pool(self.total_reward)
            self.ledger.settle_round(np.asarray(alloc.client_reward),
                                     float(alloc.fee), producer, verified)

            record.labels = np.asarray(agg.labels)
            record.cluster_sizes = np.asarray(agg.cluster_sizes)
            record.rewards = np.where(verified, np.asarray(alloc.client_reward), 0.0)
            record.balances = self.ledger.balances.copy()
            record.producer = producer
            record.verified_frac = float(verified.mean())

        record.accuracy = float(self._eval(agg.stacked_params, test_x, test_y))
        self.history.append(record)
        return agg.stacked_params, stacked_opt, record

    def fit(self, stacked_params: Pytree, cx, cy, test_x, test_y,
            rounds: int, log_every: int = 0,
            log_fn: Callable[[str], None] = print) -> Pytree:
        stacked_params, stacked_opt = self.init(stacked_params)
        for r in range(rounds):
            stacked_params, stacked_opt, rec = self.run_round(
                r, stacked_params, stacked_opt, cx, cy, test_x, test_y)
            if log_every and (r % log_every == 0 or r == rounds - 1):
                log_fn(f"[{self.strategy.name}] round {r:3d} "
                       f"loss={rec.mean_loss:.4f} acc={rec.accuracy:.4f}"
                       + (f" clusters={rec.cluster_sizes.tolist()}"
                          if rec.cluster_sizes is not None else ""))
        return stacked_params

"""The BFLN federated round driver (paper Fig. 1, steps 1–6).

The jittable inner program (local training + aggregation) is wrapped by the
host-side blockchain protocol (hash commitments, block packing, consensus
verification, token settlement).  The same driver runs every baseline strategy
— baselines simply skip the chain (no clustering → no CACC queue).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.blockchain import (
    AGG_COMMIT_KIND,
    Blockchain,
    RoundCommitments,
    TokenLedger,
    Transaction,
    TxPool,
)
from repro.core import consensus as cacc
from repro.core.baselines import AggOut, ModelBundle, Strategy
from repro.core.fl import LocalTrainResult, global_evaluate, local_train
from repro.core.incentives import allocate_rewards
from repro.faults import NULL_INJECTOR
from repro.kernels.fingerprint import cohort_digests
from repro.obs import NULL_RECORDER
from repro.optim import Optimizer

Pytree = Any


def digest_of(params: Pytree) -> str:
    """Fingerprint digest of ONE client's (unstacked) param pytree — the
    commitment a client would make for these params.  Convenience wrapper
    for tests/tamper payloads; the round hot path digests the whole cohort
    in one batched call instead."""
    stacked = jax.tree.map(lambda x: x[None], params)
    return cohort_digests(stacked)[0]


@dataclass
class RoundRecord:
    round_idx: int
    mean_loss: float
    accuracy: float
    labels: np.ndarray | None = None
    cluster_sizes: np.ndarray | None = None
    rewards: np.ndarray | None = None
    balances: np.ndarray | None = None
    producer: int = -1
    verified_frac: float = 1.0


@dataclass
class ChainRoundResult:
    """Outcome of the host-side blockchain protocol for one round's cohort."""
    producer: int               # global client id of the packing client
    verified: np.ndarray        # (n_cohort,) consensus verification mask
    rewards: np.ndarray         # (n_cohort,) settled rewards (0 if unverified)
    block: Any = None


@dataclass
class FederatedTrainer:
    """Runs strategy rounds over stacked clients; BFLN adds the chain.

    ``strategy`` may be a built :class:`Strategy` or a registry name
    (`repro.api.registry`) — a string is resolved at construction against
    ``model``/``probe``/``n_clusters``, so
    ``FederatedTrainer(bundle, "fedprox", opt)`` just works.
    """

    model: ModelBundle
    strategy: Strategy | str
    opt: Optimizer
    local_epochs: int = 5
    n_clusters: int = 0              # >0 enables CACC/chain (BFLN)
    total_reward: float = 20.0       # paper: "Local training total stake reward"
    rho: float = 2.0                 # paper Table I
    initial_stake: float = 5.0       # paper Table I
    use_chain: bool = True
    probe: Any = None                # PAA probe batch (name-resolved bfln)
    history: list[RoundRecord] = field(default_factory=list)

    def __post_init__(self):
        if isinstance(self.strategy, str):
            from repro.api.registry import build_strategy
            self.strategy = build_strategy(
                self.strategy, self.model, probe=self.probe,
                n_clusters=self.n_clusters)
        self.chain = Blockchain()
        self.pool = TxPool()
        self.ledger: TokenLedger | None = None
        self._queue: list[int] = []
        self.obs = NULL_RECORDER
        self.faults = NULL_INJECTOR

        strategy = self.strategy

        @jax.jit
        def _train_round(stacked_params, stacked_opt, cx, cy):
            extras = strategy.round_extras(stacked_params, cx, cy)
            res: LocalTrainResult = local_train(
                strategy.local_loss, self.opt, stacked_params, stacked_opt,
                cx, cy, extras, self.local_epochs,
                shared_extras=strategy.shared_extras)
            agg: AggOut = strategy.aggregate(res.params, cx, cy)
            return res.params, agg, res.opt_state, jnp.mean(res.mean_loss)

        self._train_round = _train_round
        self._eval = jax.jit(partial(global_evaluate, self.model.apply_fn))

    # ------------------------------------------------------------------ #

    def attach_obs(self, obs) -> None:
        """Bind a flight recorder (`repro.obs`) to the trainer and its chain
        components.  Called after construction so it also covers a ledger
        the simulator swapped in."""
        self.obs = obs
        self.chain.obs = obs
        if self.ledger is not None:
            self.ledger.obs = obs

    def attach_faults(self, faults) -> None:
        """Bind a fault injector (`repro.faults`) so the chain protocol can
        absorb injected producer failures, bad blocks, and commit-delivery
        faults.  Default: the shared no-op injector."""
        self.faults = faults

    def init(self, stacked_params: Pytree) -> tuple[Pytree, Pytree]:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        if self.use_chain:
            self.ledger = TokenLedger(n, self.initial_stake)
            self.ledger.obs = self.obs
        opt_state = jax.vmap(self.opt.init)(stacked_params)
        return stacked_params, opt_state

    def run_round(
        self,
        round_idx: int,
        stacked_params: Pytree,
        stacked_opt: Pytree,
        cx: jax.Array,
        cy: jax.Array,
        test_x: jax.Array,
        test_y: jax.Array,
        tamper: dict[int, Pytree] | None = None,
    ) -> tuple[Pytree, Pytree, RoundRecord]:
        """One full BFLN round.  ``tamper`` (tests only) swaps the params a
        client *claims* (hash-commits) for something else, exercising the
        consensus rejection path."""
        n = cx.shape[0]

        local_params, agg, stacked_opt, mean_loss = self._train_round(
            stacked_params, stacked_opt, cx, cy)

        record = RoundRecord(round_idx, float(mean_loss), 0.0)

        if self.use_chain and agg.labels is not None:
            cres = self.chain_round(round_idx, local_params, agg.labels,
                                    agg.corr, tamper=tamper)
            record.labels = np.asarray(agg.labels)
            record.cluster_sizes = np.asarray(agg.cluster_sizes)
            record.rewards = cres.rewards
            record.balances = self.ledger.balances.copy()
            record.producer = cres.producer
            record.verified_frac = float(cres.verified.mean())

        record.accuracy = float(self._eval(agg.stacked_params, test_x, test_y))
        self.history.append(record)
        return agg.stacked_params, stacked_opt, record

    def chain_round(
        self,
        round_idx: int,
        local_params: Pytree | None,
        labels: jax.Array,
        corr: jax.Array,
        cohort: np.ndarray | None = None,
        arrived: np.ndarray | None = None,
        tamper: dict[int, str | Pytree] | None = None,
        digests: list[str] | None = None,
    ) -> ChainRoundResult:
        """Host-side blockchain protocol (Fig. 1 steps 2/5/6) over one round's
        *cohort* — the clients that actually trained this round.

        ``local_params`` is cohort-stacked (slot axis); ``cohort`` maps slot →
        global client id (default: identity over the full population — the
        paper's 20-always-on-clients setting).  ``arrived`` masks slots whose
        update reached the producer before the block slot: stragglers and
        dropouts (``repro.sim``) never commit a digest and are never
        aggregated — they simply miss the round.  ``tamper`` (keyed by global
        client id) substitutes the digest a client *commits* — either a digest
        string directly or a param pytree to digest — exercising the consensus
        rejection path.

        Commitments are batched and device-side: ONE jitted fingerprint call
        digests the whole cohort, and the host pulls `O(cohort)` digest bytes
        — never per-client full params (`repro.kernels.fingerprint`).

        ``digests`` (per-slot digest strings) may be precomputed — the fused
        round engine (`repro.core.engine`) fingerprints the cohort inside its
        single jitted step, so the protocol here never touches params at all
        (``local_params`` may then be ``None``).
        """
        assert self.ledger is not None
        k = int(np.asarray(labels).shape[0])
        cohort = np.arange(k) if cohort is None else np.asarray(cohort)
        arrived = np.ones(k, bool) if arrived is None else np.asarray(arrived, bool)
        n_total = self.ledger.n_clients
        tamper = tamper or {}

        if not arrived.any():
            # nobody delivered an update: no block, the round's pool stays unminted
            return ChainRoundResult(-1, np.zeros(k, bool), np.zeros(k))

        obs = self.obs
        if digests is None:
            # one fingerprint pass over the cohort-stacked params (slot-indexed)
            with obs.span("chain.digests", cat="chain", round=round_idx):
                digests = cohort_digests(local_params)

        # -- Fig.1 step 2: arrived clients commit model digests ------------ #
        faults = self.faults
        with obs.span("chain.commit", cat="chain", round=round_idx) as sp:
            # commits a fault delayed in an earlier round arrive only now —
            # they land in THIS block, where verification ignores them
            # (model_hash txs from another round carry no weight)
            for late in faults.release_commits():
                self.pool.submit(late)
                obs.event("fault.commit_delivered_late", round=round_idx,
                          client=late.sender, from_round=late.round_idx)
            entries: list[tuple[int, str]] = []  # what the producer aggregated
            arrived_slots = [s for s in range(k) if arrived[s]]
            drop_i = faults.commit_drop_slot(round_idx, len(arrived_slots))
            delay_i = faults.commit_delay_slot(round_idx, len(arrived_slots))
            for j, slot in enumerate(arrived_slots):
                gid = int(cohort[slot])
                claimed = tamper.get(gid, digests[slot])
                if not isinstance(claimed, str):
                    claimed = digest_of(claimed)
                tx = Transaction("model_hash", gid, claimed, round_idx)
                if j == drop_i:
                    # lost in transit: the producer aggregated this client's
                    # update, but its commit never reaches the pool — the
                    # client fails verification and forfeits its reward
                    obs.event("fault.commit_dropped", round=round_idx,
                              client=gid)
                    obs.inc("fault.commit_dropped")
                elif j == delay_i:
                    faults.hold_commit(tx)
                    obs.event("fault.commit_delayed", round=round_idx,
                              client=gid)
                    obs.inc("fault.commit_delayed")
                else:
                    self.pool.submit(tx)
                entries.append((gid, digests[slot]))
            sp.set(n_commits=len(entries))

        # -- CACC: centroid representatives -> packing queue --------------- #
        with obs.span("chain.consensus", cat="chain", round=round_idx):
            sel = cacc.select_centroid_clients(corr, labels, self.n_clusters)
            queue = [int(cohort[slot])
                     for slot in cacc.packing_queue(sel.representatives)]
            self._queue = queue or self._queue or [int(cohort[0])]
            active = {int(g) for g in cohort[arrived]}
            try:
                producer = cacc.producer_for_round(self._queue, round_idx,
                                                   active)
            except ValueError:
                producer = min(active)  # no representative arrived this round
            if faults.producer_fails(round_idx):
                # producer death mid-pack: fail over to the next consensus
                # candidate, exactly as every validator would recompute the
                # slot from the same queue and the reduced active set
                remaining = active - {producer}
                if remaining:
                    failed = producer
                    try:
                        producer = cacc.producer_for_round(
                            self._queue, round_idx, remaining)
                    except ValueError:
                        producer = min(remaining)
                    obs.event("fault.producer_failover", round=round_idx,
                              failed=failed, successor=producer)
                    obs.inc("fault.producer_failover")
                # a sole active client has no successor: it keeps the slot

        # -- Fig.1 step 5: producer records sender-bound commitments ------- #
        commits = RoundCommitments(round_idx, tuple(entries))
        self.pool.submit(Transaction(
            AGG_COMMIT_KIND, producer, commits.to_payload(), round_idx))
        block = self.chain.pack_block(round_idx, producer, self.pool,
                                      faults=faults)

        # -- Fig.1 step 6: consensus verification + incentives ------------- #
        verified_total = self.chain.verify_round(block, n_total)
        with obs.span("chain.rewards", cat="chain", round=round_idx):
            alloc = allocate_rewards(labels, self.n_clusters,
                                     self.total_reward, self.rho,
                                     participating=jnp.asarray(arrived))
            rewards_total = np.zeros(n_total)
            rewards_total[cohort] = np.asarray(alloc.client_reward)
            self.ledger.mint_reward_pool(self.total_reward)
            self.ledger.settle_round(rewards_total, float(alloc.fee),
                                     producer, verified_total)

        verified = verified_total[cohort]
        rewards = np.where(verified, rewards_total[cohort], 0.0)
        return ChainRoundResult(producer, verified, rewards, block)

    def fit(self, stacked_params: Pytree, cx, cy, test_x, test_y,
            rounds: int, log_every: int = 0,
            log_fn: Callable[[str], None] = print) -> Pytree:
        stacked_params, stacked_opt = self.init(stacked_params)
        for r in range(rounds):
            stacked_params, stacked_opt, rec = self.run_round(
                r, stacked_params, stacked_opt, cx, cy, test_x, test_y)
            if log_every and (r % log_every == 0 or r == rounds - 1):
                log_fn(f"[{self.strategy.name}] round {r:3d} "
                       f"loss={rec.mean_loss:.4f} acc={rec.accuracy:.4f}"
                       + (f" clusters={rec.cluster_sizes.tolist()}"
                          if rec.cluster_sizes is not None else ""))
        return stacked_params

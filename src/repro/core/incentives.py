"""Incentive mechanism based on cluster membership size (paper §IV-C-1).

    Γ(n_i) = κ · n_i^ρ,   κ = ℜ / Σ_i n_i^ρ,   ρ > 1          (Eqs. 7–8)
    per-client reward  r = Γ(n_i) / n_i
    aggregation fee    g = κ / N                               (Eq. 9)

Properties (property-tested in tests/test_incentives.py):
  * ΣΓ(n_i) = ℜ exactly (token conservation),
  * per-capita reward κ·n_i^{ρ-1} strictly increases with cluster size for ρ>1,
  * clients in the same cluster receive equal shares.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class RewardAllocation(NamedTuple):
    cluster_reward: jax.Array   # (C,) Γ(n_i)
    client_reward: jax.Array    # (m,) r_k for every client
    kappa: jax.Array            # scalar κ
    fee: jax.Array              # scalar g = κ / N


@partial(jax.jit, static_argnames=("n_clusters", "total_reward", "rho"))
def allocate_rewards(
    labels: jax.Array,
    n_clusters: int,
    total_reward: float,
    rho: float = 2.0,
    participating: jax.Array | None = None,
) -> RewardAllocation:
    """Distribute the round's reward pool ℜ by cluster size.

    ``labels``: (m,) cluster assignment from PAA. Empty clusters get Γ=0 and
    do not absorb tokens (the denominator only sums over realised sizes, which
    matches Σ n_i = N in the paper since empty clusters have n_i = 0).

    ``participating``: optional (m,) boolean/0-1 mask for partial-participation
    rounds (client sampling, stragglers, dropouts — ``repro.sim``).  Cluster
    sizes n_i count only participants, non-participants receive zero reward,
    and the aggregation fee g = κ/N divides by the participant count, so the
    full pool is always allocated over exactly the clients that delivered an
    update.  ``None`` (the paper's full-participation setting) keeps the
    original Eqs. 7–9 semantics unchanged.
    """
    labels = labels.astype(jnp.int32)
    m = labels.shape[0]
    if participating is None:
        part = jnp.ones((m,), jnp.float32)
    else:
        part = participating.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, n_clusters, dtype=jnp.float32) * part[:, None]
    sizes = jnp.sum(onehot, axis=0)
    powered = jnp.where(sizes > 0, sizes ** rho, 0.0)
    denom = jnp.sum(powered)
    # zero participants ⇒ zero pool (not total_reward / ε): callers that skip
    # the empty-round check must never see an astronomical κ or fee
    kappa = jnp.where(denom > 0, total_reward / jnp.maximum(denom, 1e-12), 0.0)
    cluster_reward = kappa * powered                                  # Γ(n_i)
    per_capita = cluster_reward / jnp.maximum(sizes, 1.0)             # Γ/n_i
    client_reward = per_capita[labels] * part
    fee = kappa / jnp.maximum(jnp.sum(part), 1.0)                     # Eq. 9
    return RewardAllocation(cluster_reward, client_reward, kappa, fee)


def apply_round_settlement(
    balances: jax.Array,
    alloc: RewardAllocation,
    producer: jax.Array | int,
    verified: jax.Array,
) -> jax.Array:
    """Settle one round on the token ledger (jittable mirror of the blockchain
    ledger; `repro.blockchain.ledger` is the authoritative host-side copy).

    * every *verified* client receives its reward and pays the aggregation fee g,
    * the producer (aggregation client) collects the fees only if its OWN
      commitment verified — an unverified producer forfeits them (burned),
    * unverified clients (hash mismatch — paper's anti-freeriding rule) receive
      nothing and pay nothing; their reward is burned rather than re-allocated,
      matching the paper's "only if ... hash values match" wording.
    """
    verified = verified.astype(balances.dtype)
    fees = alloc.fee * verified                       # each verified client pays g
    credit = alloc.client_reward * verified
    balances = balances + credit - fees
    balances = balances.at[producer].add(jnp.sum(fees) * verified[producer])
    return balances

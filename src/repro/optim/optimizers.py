"""Pure-JAX optimizers (optax is not available in the container).

API mirrors the functional style: ``opt.init(params) -> state``,
``opt.update(params, grads, state) -> (params, state)``.  States are pytrees,
so they stack/shard exactly like parameters (the FL layer vmaps them over the
client axis; the launcher shards them over the mesh — ZeRO-style, every state
leaf inherits the parameter sharding).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        step = state["step"]
        eta = sched(step)
        new = jax.tree.map(lambda p, g: p - eta.astype(p.dtype) * g.astype(p.dtype),
                           params, grads)
        return new, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(params, grads, state):
        step, mu = state["step"], state["mu"]
        eta = sched(step)
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), mu, grads)
        if nesterov:
            d = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), mu, grads)
        else:
            d = mu
        new = jax.tree.map(lambda p, di: p - (eta * di).astype(p.dtype), params, d)
        return new, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay):
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(params, grads, state):
        step = state["step"] + 1
        eta = sched(step - 1)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def leaf(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return p - (eta * upd).astype(p.dtype)

        new = jax.tree.map(leaf, params, m, v)
        return new, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay)

"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay_schedule(lr: float, decay_steps: int, alpha: float = 0.0):
    def sched(step):
        t = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * ((1 - alpha) * cos + alpha)

    return sched


def warmup_cosine_schedule(lr: float, warmup_steps: int, decay_steps: int,
                           alpha: float = 0.0):
    cos = cosine_decay_schedule(lr, max(decay_steps - warmup_steps, 1), alpha)

    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return sched

from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adam,
    adamw,
    clip_by_global_norm,
    momentum,
    sgd,
)
from repro.optim.schedules import (  # noqa: F401
    constant_schedule,
    cosine_decay_schedule,
    warmup_cosine_schedule,
)

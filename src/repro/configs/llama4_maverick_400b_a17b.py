"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8,
head_dim=128) expert d_ff=8192 vocab=202048; 128 experts top-1 + shared
expert, MoE on alternating layers; iRoPE (every 4th layer NoPE/global,
others chunked-local window 8192).  [hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.models.transformer import ArchConfig, LayerSpec

DENSE_LOCAL = LayerSpec(mixer="attn", window=8192, rope=True, moe=False)
MOE_LOCAL = LayerSpec(mixer="attn", window=8192, rope=True, moe=True)
MOE_NOPE = LayerSpec(mixer="attn", window=0, rope=False, moe=True)

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=(DENSE_LOCAL, MOE_LOCAL, DENSE_LOCAL, MOE_NOPE),
    activation="swiglu",
    n_experts=128,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_shared_expert=True,
    rope_theta=500_000.0,
    tie_embeddings=True,
    sharding_mode="fsdp_tp",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free), channel-mix
d_ff=8960, vocab=65536; RWKV-6 "Finch" with data-dependent decay,
head_size 64 (40 heads).  [arXiv:2404.05892]
"""
from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6-3b",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # informational; rwkv_heads = d_model // rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    pattern=(LayerSpec(mixer="rwkv", rope=False),),
    activation="relu2",  # channel-mix uses squared ReLU internally
    rwkv_head_dim=64,
    rwkv_lora_rank=64,
    tie_embeddings=False,
    sharding_mode="tp",
    source="arXiv:2404.05892",
)

"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8, head_dim=128)
d_ff=16384 vocab=256000; pruned Nemotron-4 (squared-ReLU MLP).
[arXiv:2407.14679]
"""
from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="minitron-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    pattern=(LayerSpec(mixer="attn"),),
    activation="relu2",
    tie_embeddings=True,
    sharding_mode="tp",
    source="arXiv:2407.14679",
)

"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8, head_dim=120)
d_ff=10240 vocab=32000; llama+mistral mix with sliding-window attention
(window 8192).  [arXiv:2401.16818]
"""
from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    pattern=(LayerSpec(mixer="attn", window=8192),),
    activation="swiglu",
    tie_embeddings=False,
    sharding_mode="tp",
    source="arXiv:2401.16818",
)

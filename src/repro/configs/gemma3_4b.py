"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4, head_dim=256)
d_ff=10240 vocab=262144; 5:1 local(SWA-1024):global interleave, GeGLU,
QK-norm, 128k context.  [hf:google/gemma-3-1b-pt]
"""
from repro.models.transformer import ArchConfig, LayerSpec

LOCAL = LayerSpec(mixer="attn", window=1024, rope=True)
GLOBAL = LayerSpec(mixer="attn", window=0, rope=True)

CONFIG = ArchConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),   # 5:1 local:global
    activation="geglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sharding_mode="tp",
    source="hf:google/gemma-3-1b-pt",
)

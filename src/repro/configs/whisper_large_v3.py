"""whisper-large-v3 [audio] — enc-dec, 32+32L d_model=1280 20H (MHA kv=20,
head_dim=64) d_ff=5120 vocab=51866; mel-spectrogram conv frontend is a STUB
(input_specs provides 1500 frame embeddings).  [arXiv:2212.04356]
"""
from repro.models.transformer import ArchConfig, EncoderConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-large-v3",
    n_layers=32,                      # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    pattern=(LayerSpec(mixer="attn", rope=False, cross_attn=True),),
    activation="gelu",
    norm="layernorm",
    abs_pos=True,
    encoder=EncoderConfig(n_layers=32, n_heads=20, d_ff=5120, n_frames=1500),
    frontend="audio_stub",
    tie_embeddings=True,
    sharding_mode="tp",
    source="arXiv:2212.04356",
)

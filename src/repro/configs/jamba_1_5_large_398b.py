"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8,
head_dim=128) d_ff=24576 vocab=65536; Mamba:attention 7:1 interleave
(one attention layer per 8-layer block), MoE 16e top-2 every other layer.
[arXiv:2403.19887]
"""
from repro.models.transformer import ArchConfig, LayerSpec


def _spec(i: int) -> LayerSpec:
    mixer = "attn" if i == 3 else "mamba"          # 1 attn : 7 mamba per block
    return LayerSpec(mixer=mixer, moe=(i % 2 == 1), rope=False)


CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=tuple(_spec(i) for i in range(8)),      # 72 = 9 × 8, exact
    activation="swiglu",
    n_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    tie_embeddings=True,
    sharding_mode="fsdp_tp",
    source="arXiv:2403.19887",
)

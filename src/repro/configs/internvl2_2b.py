"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8, head_dim=128)
d_ff=8192 vocab=92553; InternLM2 language backbone; InternViT vision
encoder + projector are a STUB (input_specs provides patch embeddings).
[arXiv:2404.16821]
"""
from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-2b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    pattern=(LayerSpec(mixer="attn"),),
    activation="swiglu",
    frontend="vision_stub",
    tie_embeddings=True,
    sharding_mode="tp",
    source="arXiv:2404.16821",
)

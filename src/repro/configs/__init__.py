"""Architecture registry: the 10 assigned architectures (+ the paper's own
CIFAR-scale classifier via repro.models.classifier)."""
from __future__ import annotations

from repro.configs import (
    gemma3_4b,
    gemma_7b,
    grok_1_314b,
    h2o_danube_3_4b,
    internvl2_2b,
    jamba_1_5_large_398b,
    llama4_maverick_400b_a17b,
    minitron_8b,
    rwkv6_3b,
    whisper_large_v3,
)
from repro.configs.shapes import SHAPES, InputShape  # noqa: F401
from repro.models.transformer import ArchConfig

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma3_4b,
        gemma_7b,
        llama4_maverick_400b_a17b,
        grok_1_314b,
        jamba_1_5_large_398b,
        internvl2_2b,
        h2o_danube_3_4b,
        rwkv6_3b,
        whisper_large_v3,
        minitron_8b,
    )
}

# long_500k applicability (see DESIGN.md §5): sub-quadratic decode only.
LONG_CONTEXT_OK = {
    "gemma3-4b": True,            # 5:1 SWA-1024 : global
    "gemma-7b": False,            # pure full attention
    "llama4-maverick-400b-a17b": True,   # iRoPE chunked-local 3:1
    "grok-1-314b": False,         # pure full attention
    "jamba-1.5-large-398b": True,  # mamba-dominant hybrid
    "internvl2-2b": False,        # full-attention LM backbone
    "h2o-danube-3-4b": True,      # SWA-8192
    "rwkv6-3b": True,             # recurrent, O(1) state
    "whisper-large-v3": False,    # enc-dec, 448-token decoder spec
    "minitron-8b": False,         # pure full attention
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def shape_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable; returns (ok, reason_if_not)."""
    if shape == "long_500k" and not LONG_CONTEXT_OK[arch]:
        return False, "full-attention arch: no sub-quadratic decode variant (DESIGN.md §5)"
    return True, ""

"""gemma-7b [dense] — 28L d_model=3072 16H (MHA kv=16, head_dim=256)
d_ff=24576 vocab=256000; GeGLU.  [arXiv:2403.08295]
"""
from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=(LayerSpec(mixer="attn"),),
    activation="geglu",
    tie_embeddings=True,
    sharding_mode="tp",
    source="arXiv:2403.08295",
)

"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8, head_dim=128)
expert d_ff=32768 vocab=131072; 8 experts top-2, MoE every layer.
[hf:xai-org/grok-1]
"""
from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    pattern=(LayerSpec(mixer="attn", moe=True),),
    activation="geglu",   # gated MoE FFN — matches grok-1's 314B total at 8e×32768
    n_experts=8,
    moe_top_k=2,
    moe_d_ff=32768,
    tie_embeddings=True,
    sharding_mode="fsdp_tp",
    source="hf:xai-org/grok-1",
)

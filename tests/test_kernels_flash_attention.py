"""Flash-attention kernel: shape/dtype/GQA/window sweep vs naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(B, S, Hq, Hkv, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("S,bq,bk", [(128, 128, 128), (256, 128, 64), (512, 256, 128)])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_sweep(S, bq, bk, Hq, Hkv, dtype):
    q, k, v = _mk(2, S, Hq, Hkv, 32, dtype, seed=S + Hq)
    got = ops.attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [32, 64, 200])
def test_flash_sliding_window(window):
    q, k, v = _mk(1, 256, 4, 2, 32, jnp.float32, seed=window)
    got = ops.attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_noncausal():
    q, k, v = _mk(1, 128, 2, 2, 64, jnp.float32)
    got = ops.attention(q, k, v, causal=False)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_matches_model_attention_path():
    """Cross-validate against the XLA chunked implementation used in models."""
    from repro.models.attention import attend_chunked
    q, k, v = _mk(2, 256, 4, 2, 32, jnp.float32, seed=9)
    a = ops.attention(q, k, v, causal=True, window=48)
    b = attend_chunked(q, k, v, causal=True, window=48, q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

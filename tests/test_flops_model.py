"""Analytical cost model sanity: parameter counts reproduce the named model
sizes (the strongest available check that configs are faithful)."""
import pytest

from repro.configs import ARCHS, SHAPES
from repro.launch.flops import param_counts, step_cost


@pytest.mark.parametrize("arch,total_b,active_b,tol", [
    ("gemma3-4b", 4.3, 4.3, 0.45),            # 4B-class (vocab-heavy)
    ("gemma-7b", 9.3, 9.3, 0.25),             # gemma-7b is really ~8.5B
    ("llama4-maverick-400b-a17b", 400, 17, 0.25),
    ("grok-1-314b", 314, 86, 0.30),
    ("jamba-1.5-large-398b", 398, 98, 0.30),
    ("internvl2-2b", 2.2, 2.2, 0.35),
    ("h2o-danube-3-4b", 4.0, 4.0, 0.35),
    ("rwkv6-3b", 3.1, 3.1, 0.35),
    ("whisper-large-v3", 1.55, 1.55, 0.35),
    ("minitron-8b", 8.3, 8.3, 0.35),
])
def test_param_counts_match_model_cards(arch, total_b, active_b, tol):
    total, active = param_counts(ARCHS[arch])
    assert abs(total / 1e9 - total_b) / total_b < tol, total / 1e9
    assert abs(active / 1e9 - active_b) / active_b < tol, active / 1e9


def test_moe_active_far_below_total():
    total, active = param_counts(ARCHS["llama4-maverick-400b-a17b"])
    assert active < total / 10


def test_step_cost_monotonic_in_shape():
    cfg = ARCHS["gemma-7b"]
    small = step_cost(cfg, SHAPES["train_4k"])
    assert small.flops_total > small.flops_fwd
    decode = step_cost(cfg, SHAPES["decode_32k"])
    assert decode.flops_total < small.flops_total
    assert decode.state_bytes > 0


def test_swa_skip_reduces_flops():
    cfg = ARCHS["gemma3-4b"]
    base = step_cost(cfg, SHAPES["prefill_32k"])
    opt = step_cost(cfg, SHAPES["prefill_32k"], swa_skip=True)
    assert opt.flops_total < base.flops_total * 0.7

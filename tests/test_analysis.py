"""The invariant auditor (`repro.analysis`).

Layer 1: each AST rule gets a tripwire fixture (a tiny tree that MUST
fire it) and a clean twin (that must not) — the rules are themselves
code, and a rule that silently stopped matching would gate nothing.
Layer 2: the compiled-artifact audit runs against the REAL engine entries
(mesh 1 in-process; mesh 8 in-process when devices allow, else via the
self-forcing subprocess, same pattern as the sharded-engine tests), and a
deliberately partition-unsafe toy proves the combine detector actually
sees reduction collectives.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.analysis import (
    check_baseline,
    load_baseline,
    run_source_rules,
    write_baseline,
)
from repro.analysis.findings import Finding, build_report
from repro.launch.hlo import donated_params, f64_op_count

REPO_ROOT = Path(__file__).resolve().parents[1]
N_DEV = len(jax.devices())
mesh8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _scan(tmp_path, files, rules=None, trace_doc=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    doc = None
    if trace_doc is not None:
        d = tmp_path / "docs" / "TRACE_SCHEMA.md"
        d.parent.mkdir(parents=True, exist_ok=True)
        d.write_text(trace_doc)
        doc = str(d)
    return run_source_rules(str(tmp_path), trace_doc=doc, rule_ids=rules)


_NAMES_PY = """
    SPAN_NAMES = frozenset({"round.total"})
    EVENT_NAMES = frozenset({"compile"})
    COUNTER_NAMES = frozenset({"compiles"})
    GAUGE_NAMES = frozenset({"run.final_accuracy"})
    SERIES_NAMES = frozenset({"ledger.paid"})
    DYNAMIC_PREFIXES = ("engine.calls.",)
    METHOD_NAME_SETS = {"span": SPAN_NAMES, "event": EVENT_NAMES,
                        "inc": COUNTER_NAMES, "set_gauge": GAUGE_NAMES,
                        "observe": SERIES_NAMES, "point": SERIES_NAMES}
    ALL_NAMES = (SPAN_NAMES | EVENT_NAMES | COUNTER_NAMES | GAUGE_NAMES
                 | SERIES_NAMES)
    def is_registered(name, allowed=None):
        pool = ALL_NAMES if allowed is None else allowed
        if name in pool:
            return True
        return any(name.startswith(p) or p.startswith(name)
                   for p in DYNAMIC_PREFIXES)
"""


# --------------------------------------------------------------------------- #
# Layer 1 rules: tripwire + clean twin per rule
# --------------------------------------------------------------------------- #

def test_det_wallclock_fires_in_replay_module(tmp_path):
    fs = _scan(tmp_path, {"core/clock.py": """
        import time
        def stamp():
            return time.time()
    """}, rules=["det-wallclock"])
    assert [f.rule for f in fs] == ["det-wallclock"]
    assert "time.time" in fs[0].message


def test_det_wallclock_exempts_obs_and_clean_code(tmp_path):
    fs = _scan(tmp_path, {
        "obs/clock.py": "import time\ndef stamp():\n    return time.time()\n",
        "core/pure.py": "def f(x):\n    return x + 1\n",
    }, rules=["det-wallclock"])
    assert fs == []


def test_det_global_rng_fires_on_module_level_np_random(tmp_path):
    fs = _scan(tmp_path, {"core/noise.py": """
        import numpy as np
        X = np.random.rand(3)
    """}, rules=["det-global-rng"])
    assert [f.rule for f in fs] == ["det-global-rng"]


def test_det_global_rng_fires_on_bare_stdlib_random(tmp_path):
    fs = _scan(tmp_path, {"sim/jitter.py": """
        import random
        def f():
            return random.random()
    """}, rules=["det-global-rng"])
    assert len(fs) == 1


def test_det_global_rng_allows_seeded_generators(tmp_path):
    fs = _scan(tmp_path, {"core/rng.py": """
        import random
        import numpy as np
        G = np.random.default_rng(0)
        R = random.Random(0)
    """}, rules=["det-global-rng"])
    assert fs == []


_HOT_ENGINE = """
    import jax
    import numpy as np

    def helper(x):
        return np.asarray(x)          # host transfer, jit-reachable

    def cold(x):
        return np.asarray(x)          # same op, NOT reachable from a jit

    def _step(x):
        return helper(x)

    class Engine:
        def __init__(self):
            self.step = jax.jit(_step, donate_argnums=(0,))
"""


def test_hot_host_sync_flags_only_jit_reachable(tmp_path):
    fs = _scan(tmp_path, {"core/engine.py": _HOT_ENGINE},
               rules=["hot-host-sync"])
    assert len(fs) == 1
    assert "helper" in fs[0].message and "cold" not in fs[0].message


def test_hot_host_sync_cast_filter(tmp_path):
    fs = _scan(tmp_path, {"core/engine.py": """
        import jax

        def _step(x):
            bad = float(x)            # possibly-traced cast: flag
            ok = float(x.shape[0])    # static shape arithmetic: allow
            return bad + ok

        j = jax.jit(_step, donate_argnums=(0,))
    """}, rules=["hot-host-sync"])
    assert len(fs) == 1 and "`float()`" in fs[0].message


def test_jit_donation_flags_undonated_entry(tmp_path):
    fs = _scan(tmp_path, {"core/engine.py": """
        import jax
        def _a(x):
            return x
        def _b(x):
            return x
        j1 = jax.jit(_a, donate_argnums=(0,))
        j2 = jax.jit(_b)
    """}, rules=["jit-donation"])
    assert len(fs) == 1 and "_b" in fs[0].message


def test_tree_order_fires_on_unsorted_dict_reduction(tmp_path):
    fs = _scan(tmp_path, {
        "core/baselines.py": "def f(d):\n    return sum(d.values())\n",
        "utils/tree.py": """
            def g(d):
                acc = 0.0
                for v in d.values():
                    acc += v
                return acc
        """,
    }, rules=["tree-order"])
    assert {f.path for f in fs} == {"core/baselines.py", "utils/tree.py"}


def test_tree_order_allows_sorted_iteration(tmp_path):
    fs = _scan(tmp_path, {
        "core/baselines.py":
            "def f(d):\n    return sum(sorted(d.values()))\n",
        "utils/other.py":                 # outside the rule's modules
            "def g(d):\n    return sum(d.values())\n",
    }, rules=["tree-order"])
    assert fs == []


def test_trace_schema_flags_unregistered_recorder_name(tmp_path):
    fs = _scan(tmp_path, {
        "obs/names.py": _NAMES_PY,
        "sim/run.py": """
            def f(obs, n):
                obs.span("round.total")            # registered
                obs.inc(f"engine.calls.{n}")       # dynamic prefix, ok
                obs.span("bogus.name")             # NOT registered
        """,
    }, rules=["trace-schema"])
    assert len(fs) == 1 and "bogus.name" in fs[0].message


def test_trace_schema_doc_cross_check(tmp_path):
    ok_doc = ("`round.total` `compile` `compiles` `run.final_accuracy` "
              "`ledger.paid` `engine.calls.<entry>`")
    fs = _scan(tmp_path, {"obs/names.py": _NAMES_PY}, rules=["trace-schema"],
               trace_doc=ok_doc)
    assert fs == []
    # drop one registered name from the doc, add one unknown -> 2 findings
    bad_doc = ("`round.total` `compile` `compiles` `run.final_accuracy` "
               "`engine.calls.<entry>` `round.bogus`")
    fs = _scan(tmp_path, {"obs/names.py": _NAMES_PY}, rules=["trace-schema"],
               trace_doc=bad_doc)
    msgs = " | ".join(f.message for f in fs)
    assert "ledger.paid" in msgs and "round.bogus" in msgs


# --------------------------------------------------------------------------- #
# baseline + report plumbing
# --------------------------------------------------------------------------- #

def test_baseline_roundtrip_and_stale_detection(tmp_path):
    f1 = Finding("jit-donation", "core/engine.py", 3, "msg one")
    f2 = Finding("tree-order", "utils/tree.py", 9, "msg two")
    write_baseline(str(tmp_path), [f1, f2])
    entries = load_baseline(str(tmp_path))
    assert len(entries) == 2
    fresh, grand, stale = check_baseline([f1], entries)
    assert fresh == [] and grand == [f1]
    assert [e["match"] for e in stale] == ["msg two"]
    f3 = Finding("det-wallclock", "sim/x.py", 1, "new one")
    fresh, grand, stale = check_baseline([f1, f3], entries)
    assert fresh == [f3]


def test_baseline_rejects_missing_reason(tmp_path):
    (tmp_path / ".analysis-baseline.json").write_text(json.dumps({
        "schema": 1,
        "findings": [{"rule": "r", "path": "p", "match": "m", "reason": ""}],
    }))
    with pytest.raises(ValueError, match="reason"):
        load_baseline(str(tmp_path))


def test_report_digest_is_deterministic():
    fs = [Finding("tree-order", "utils/tree.py", 9, "m")]
    r1 = build_report(fs, [], [], rules=["tree-order"])
    r2 = build_report(fs, [], [], rules=["tree-order"])
    assert r1["report_digest"] == r2["report_digest"]
    r3 = build_report([], fs, [], rules=["tree-order"])
    assert r3["report_digest"] != r1["report_digest"]


def test_repo_is_green_against_committed_baseline():
    """The gate CI enforces: the real tree + the committed baseline."""
    findings = run_source_rules(
        str(REPO_ROOT / "src" / "repro"), prefix="src/repro/",
        trace_doc=str(REPO_ROOT / "docs" / "TRACE_SCHEMA.md"))
    fresh, _, stale = check_baseline(findings,
                                     load_baseline(str(REPO_ROOT)))
    assert fresh == [], "\n".join(f.format() for f in fresh)
    assert stale == [], f"stale baseline entries: {stale}"


# --------------------------------------------------------------------------- #
# HLO parsing helpers
# --------------------------------------------------------------------------- #

def test_donated_params_parses_alias_header():
    text = ("HloModule jit__step, input_output_alias={ {0}: (0, {}, "
            "may-alias), {1}: (2, {}, may-alias) }, entry_computation_layout"
            "={(f32[4]{0})->f32[4]{0}}\n")
    assert donated_params(text) == {0, 2}
    assert donated_params("HloModule plain\n") == set()


def test_f64_op_count():
    text = ("  %a = f32[4]{0} add(%x, %y)\n"
            "  %b = f64[] convert(%a)\n"
            "  %c = (f32[2]{0}, f64[2]{0}) tuple(%x, %b)\n")
    assert f64_op_count(text) == 2


# --------------------------------------------------------------------------- #
# Layer 2: the compiled-artifact audit on the REAL engine entries
# --------------------------------------------------------------------------- #

def test_hlo_audit_mesh1_clean():
    from repro.analysis.hlo_audit import run_audit
    findings, info = run_audit(1)
    assert findings == [], [f.format() for f in findings]
    assert info["entries"]["sync_step"]["donated_params"] == [0]
    assert all(v == 1 for v in info["cache_sizes"].values())
    assert all(e["f64_ops"] == 0 for e in info["entries"].values())


@mesh8
def test_hlo_audit_mesh8_clean():
    from repro.analysis.hlo_audit import run_audit
    findings, info = run_audit(8)
    assert findings == [], [f.format() for f in findings]
    assert info["entries"]["sync_step"]["donated_params"] == [0]
    assert info["entries"]["sync_step"]["combine_reductions"] == 0
    assert info["selftest"]["attributed"] >= 1


@mesh8
def test_partition_unsafe_toy_is_detected():
    """A cohort-sharded reduction inside the combine scope MUST produce an
    attributed reduction collective — proves the detector isn't vacuous."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.launch.hlo import collective_lines
    from repro.launch.mesh import CLIENT_AXIS, make_client_mesh

    mesh = make_client_mesh(8)
    sharded = NamedSharding(mesh, PartitionSpec(CLIENT_AXIS))

    def unsafe(x):
        x = jax.lax.with_sharding_constraint(x, sharded)
        with jax.named_scope("cohort_combine"):
            return jnp.sum(x, axis=0)

    text = jax.jit(unsafe).lower(
        jnp.ones((32, 16), jnp.float32)).compile().as_text()
    hits = [h for h in collective_lines(text)
            if "cohort_combine" in h[2]
            and h[1] in ("all-reduce", "reduce-scatter")]
    assert hits, "combine detector saw no reduction collective"


def test_hlo_audit_mesh8_subprocess():
    """1-device boxes still audit the forced 8-device mesh (the CLI's
    subprocess dispatch, self-forcing XLA_FLAGS before jax imports)."""
    if N_DEV >= 8:
        pytest.skip("in-process mesh8 audit tests cover this")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.hlo_audit",
         "--shards", "8", "--json", "-"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=900)
    doc = json.loads(proc.stdout)
    assert doc["findings"] == [], doc["findings"]
    assert doc["info"]["selftest"]["attributed"] >= 1
    assert doc["info"]["entries"]["sync_step"]["combine_reductions"] == 0
    assert proc.returncode == 0

import os

# Keep tests on the single real CPU device (the 512-device override belongs
# ONLY to launch/dryrun.py).  Cap intra-op threads for stable CI timing.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

"""Data pipeline: Dirichlet partitioner invariants + packing shapes."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.lm import batch_stream, make_token_stream
from repro.data.partition import sample_probe_batch


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(100, 2000),
    k=st.integers(2, 10),
    m=st.integers(2, 20),
    beta=st.sampled_from([0.1, 0.3, 0.5, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_partition_assigns_every_sample_exactly_once(n, k, m, beta, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, n)
    parts = dirichlet_partition(labels, m, beta, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n
    assert all(len(p) >= 2 for p in parts)


def test_low_beta_is_more_skewed_than_high_beta():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 20000)

    def mean_entropy(beta):
        parts = dirichlet_partition(labels, 20, beta, seed=1)
        ents = []
        for p in parts:
            counts = np.bincount(labels[p], minlength=10) + 1e-9
            q = counts / counts.sum()
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    assert mean_entropy(0.1) < mean_entropy(0.5) < mean_entropy(50.0)


def test_pack_clients_shapes_and_locality():
    (x, y), _ = make_classification_dataset("synth10", seed=0)
    parts = dirichlet_partition(y, 8, 0.1, seed=0)
    cx, cy, tx, ty = pack_clients(x, y, parts, n_batches=3, batch_size=16)
    assert cx.shape == (8, 3, 16, x.shape[1])
    assert cy.shape == (8, 3, 16)
    assert tx.shape[0] == 8 and ty.shape[0] == 8
    # client train labels come from the client's own shard
    for cid in range(8):
        shard_labels = set(y[parts[cid]].tolist())
        assert set(cy[cid].ravel().tolist()) <= shard_labels


def test_probe_batch_single_category():
    (x, y), _ = make_classification_dataset("synth10", seed=1)
    probe = sample_probe_batch(x, y, category=4, psi=32, seed=0)
    assert probe.shape == (32, x.shape[1])


def test_token_stream_learnable_structure():
    toks = make_token_stream(256, 5000, seed=0)
    assert toks.min() >= 0 and toks.max() < 256
    # successor entropy is far below uniform (the stream is learnable)
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), set()).add(int(b))
    branching = np.mean([len(v) for v in pairs.values()])
    assert branching <= 8.5
    xs, ys = next(batch_stream(toks, batch=4, seq_len=16, n_steps=1))
    assert xs.shape == (4, 16) and ys.shape == (4, 16)
    np.testing.assert_array_equal(xs[:, 1:], ys[:, :-1])

"""End-to-end federated rounds: BFLN + all four baselines, chain + tampering."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FederatedTrainer, ModelBundle, make_bfln
from repro.core.baselines import STRATEGY_FACTORIES
from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.models import classifier as clf
from repro.optim import adam
from repro.utils.tree import tree_index


def _setup(m=6, n_clusters=2, seed=0):
    (xt, yt), (xe, ye) = make_classification_dataset("synth10", seed=seed)
    parts = dirichlet_partition(yt, m, 0.1, seed=seed)
    cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=3, batch_size=32)
    probe = jnp.asarray(sample_probe_batch(xt, yt, category=1, psi=16))
    cfg = clf.MLPConfig(in_dim=64, hidden=(64,), rep_dim=32, num_classes=10)
    bundle = ModelBundle(functools.partial(clf.apply, cfg),
                         functools.partial(clf.embed, cfg), 10)
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(seed), m)
    return bundle, sp, map(jnp.asarray, (cx, cy)), (jnp.asarray(xe), jnp.asarray(ye)), probe


def test_bfln_full_protocol_improves_and_chain_validates():
    bundle, sp, (cx, cy), (xe, ye), probe = _setup()
    strat = make_bfln(bundle, probe, n_clusters=2)
    tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=2, n_clusters=2)
    p, o = tr.init(sp)
    for r in range(4):
        p, o, rec = tr.run_round(r, p, o, cx, cy, xe, ye)
    accs = [h.accuracy for h in tr.history]
    losses = [h.mean_loss for h in tr.history]
    assert accs[-1] > accs[0]
    assert losses[-1] < losses[0]
    assert tr.chain.validate()
    assert tr.ledger.conserved()
    # rewards were distributed each round and sum to the pool
    for h in tr.history:
        np.testing.assert_allclose(h.rewards.sum(), 20.0, rtol=1e-4)
        assert h.producer >= 0
    # balances grew from the initial stake on at least some clients
    assert tr.ledger.balances.max() > 5.0


@pytest.mark.parametrize("name", sorted(STRATEGY_FACTORIES))
def test_baselines_run_and_learn(name):
    bundle, sp, (cx, cy), (xe, ye), _ = _setup(seed=1)
    strat = STRATEGY_FACTORIES[name](bundle)
    tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=2,
                          use_chain=False)
    p, o = tr.init(sp)
    for r in range(3):
        p, o, rec = tr.run_round(r, p, o, cx, cy, xe, ye)
    assert tr.history[-1].mean_loss < tr.history[0].mean_loss
    assert np.isfinite(tr.history[-1].accuracy)


def test_tamper_settlement_exact():
    """End-to-end: run_round(tamper=...) → Blockchain.verify_round zeroes the
    tampered clients' rewards while every honest client settles exactly
    reward − fee (+ all fees for the producer, iff the producer itself
    verified), and supply is conserved."""
    bundle, sp, (cx, cy), (xe, ye), probe = _setup(m=6, seed=3)
    strat = make_bfln(bundle, probe, n_clusters=2)
    tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=1, n_clusters=2)
    p, o = tr.init(sp)
    fake = jax.tree.map(jnp.zeros_like, tree_index(sp, 0))
    tampered = {1: fake, 4: jax.tree.map(lambda x: x + 1.0, fake)}
    p, o, rec = tr.run_round(0, p, o, cx, cy, xe, ye, tamper=tampered)

    n = 6
    stake = tr.initial_stake
    verified = np.array([i not in tampered for i in range(n)])
    np.testing.assert_allclose(rec.verified_frac, verified.mean())
    from repro.core.incentives import allocate_rewards
    alloc = allocate_rewards(rec.labels, 2, tr.total_reward, tr.rho)
    fee = float(alloc.fee)
    for i in range(n):
        expect = stake
        if verified[i]:
            expect += float(alloc.client_reward[i]) - fee
        if i == rec.producer and verified[i]:
            expect += fee * verified.sum()
        np.testing.assert_allclose(tr.ledger.balances[i], expect, rtol=1e-5,
                                   err_msg=f"client {i}")
        if i in tampered:
            assert rec.rewards[i] == 0.0
    # tampered rewards are burned, not re-allocated
    np.testing.assert_allclose(
        rec.rewards.sum(),
        tr.total_reward - float(alloc.client_reward[np.array([1, 4])].sum()),
        rtol=1e-5)
    assert tr.ledger.conserved()
    assert tr.chain.validate()


def test_hash_copy_freerider_rejected_end_to_end():
    """A freerider committing a COPY of an honest peer's digest (the attack
    the old set-membership verify_round rewarded) is rejected by the
    sender-bound protocol through the full round driver."""
    bundle, sp, (cx, cy), (xe, ye), probe = _setup(m=6, seed=5)
    strat = make_bfln(bundle, probe, n_clusters=2)
    tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=1, n_clusters=2)
    p, o = tr.init(sp)
    # run once untampered to learn client 0's post-training digest, then
    # replay the identical round with client 3 committing a copy of it
    import copy
    tr2 = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=1, n_clusters=2)
    p2, o2 = tr2.init(copy.deepcopy(sp))
    _, _, rec_clean = tr2.run_round(0, p2, o2, cx, cy, xe, ye)
    victim_digest = next(t.payload for t in tr2.chain.head.transactions
                         if t.kind == "model_hash" and t.sender == 0)
    p, o, rec = tr.run_round(0, p, o, cx, cy, xe, ye,
                             tamper={3: victim_digest})
    assert rec.rewards[3] == 0.0                 # the copy is NOT rewarded
    assert rec.rewards[0] > 0.0                  # the victim still is
    assert rec.verified_frac == 5 / 6
    assert tr.ledger.conserved() and tr.chain.validate()


def test_tampered_client_gets_no_reward():
    """A client committing a hash for params it did not train (freeriding)
    fails consensus verification and is not paid (paper §IV-C)."""
    bundle, sp, (cx, cy), (xe, ye), probe = _setup(seed=2)
    strat = make_bfln(bundle, probe, n_clusters=2)
    tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=1, n_clusters=2)
    p, o = tr.init(sp)
    fake = jax.tree.map(jnp.zeros_like, tree_index(sp, 0))
    p, o, rec = tr.run_round(0, p, o, cx, cy, xe, ye, tamper={2: fake})
    assert rec.verified_frac < 1.0
    assert rec.rewards[2] == 0.0
    assert rec.rewards[0] > 0.0
    np.testing.assert_allclose(tr.ledger.balances[2], 5.0 )  # stake untouched
    assert tr.ledger.conserved()

"""Blockchain: hash links, merkle roots, consensus verification (sender-bound
+ legacy), commitment Merkle membership proofs, ledger."""
import json

import jax.numpy as jnp
import numpy as np

from repro.blockchain import (
    AGG_COMMIT_KIND,
    Block,
    Blockchain,
    RoundCommitments,
    TokenLedger,
    Transaction,
    TxPool,
    commitment_leaf,
    hash_params,
    verify_membership,
)


def test_hash_params_deterministic_and_sensitive():
    p = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    h1, h2 = hash_params(p), hash_params(p)
    assert h1 == h2
    p2 = {"a": jnp.arange(6.0).reshape(2, 3).at[0, 0].set(1e-7),
          "b": {"c": jnp.ones((4,))}}
    assert hash_params(p2) != h1
    # structure-sensitive too
    p3 = {"a": jnp.arange(6.0).reshape(3, 2), "b": {"c": jnp.ones((4,))}}
    assert hash_params(p3) != h1


def test_chain_links_and_validation():
    chain = Blockchain()
    pool = TxPool()
    for r in range(3):
        pool.submit(Transaction("model_hash", r, f"h{r}", r))
        chain.pack_block(r, producer=r % 2, pool=pool)
    assert chain.validate()
    assert len(chain.blocks) == 4  # genesis + 3
    # tampering with a block breaks the chain
    b = chain.blocks[2]
    chain.blocks[2] = Block(b.index, b.round_idx, 9, b.prev_hash,
                            b.merkle_root, b.transactions)
    assert not chain.validate()


def test_verify_round_accepts_matching_rejects_tampered():
    chain = Blockchain()
    pool = TxPool()
    hashes = [f"hash_{i}" for i in range(4)]
    for i, h in enumerate(hashes):
        pool.submit(Transaction("model_hash", i, h, 0))
    # producer only aggregated clients 0,1,3 (client 2 freerode)
    commits = RoundCommitments(0, ((0, hashes[0]), (1, hashes[1]),
                                   (3, hashes[3])))
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, commits.to_payload(), 0))
    block = chain.pack_block(0, 0, pool)
    ok = chain.verify_round(block, 4)
    np.testing.assert_array_equal(ok, [True, True, False, True])


def _copy_attack_block(chain, pool, *, legacy):
    """3 clients: 0 and 1 honest (1's params happen to equal 0's), 2 is a
    freerider that commits a COPY of client 0's digest.  The producer
    aggregated digests d0 for client 0, d0 for client 1 (identical params),
    and d2 (what client 2 actually delivered)."""
    d0, d2 = "digest_honest", "digest_of_2s_actual_params"
    pool.submit(Transaction("model_hash", 0, d0, 0))
    pool.submit(Transaction("model_hash", 1, d0, 0))
    pool.submit(Transaction("model_hash", 2, d0, 0))          # the copy attack
    if legacy:
        pool.submit(Transaction("agg_hash", 9, json.dumps(sorted([d0, d0, d2])), 0))
    else:
        commits = RoundCommitments(0, ((0, d0), (1, d0), (2, d2)))
        pool.submit(Transaction(AGG_COMMIT_KIND, 9, commits.to_payload(), 0))
    return chain.pack_block(0, 9, pool)


def test_hash_copy_freerider_regression():
    """THE anti-freeriding regression (ISSUE 2): a client committing a copy
    of an honest peer's digest was VERIFIED (and hence paid) under the old
    set-membership rule, and is REJECTED under sender-bound commitments —
    while the honest duplicate (client 1, identical params to client 0)
    stays verified in both."""
    legacy_ok = Blockchain().verify_round(
        _copy_attack_block(Blockchain(), TxPool(), legacy=True), 3)
    np.testing.assert_array_equal(legacy_ok, [True, True, True])   # attack paid

    bound_ok = Blockchain().verify_round(
        _copy_attack_block(Blockchain(), TxPool(), legacy=False), 3)
    np.testing.assert_array_equal(bound_ok, [True, True, False])   # rejected


def test_agg_commit_preserves_duplicate_entries():
    """Old format packed sorted(hashes) — duplicates collapsed under set
    semantics.  The sender-bound record keeps one entry per arrived client."""
    commits = RoundCommitments(4, ((7, "d"), (8, "d"), (9, "e")))
    assert len(commits.entries) == 3
    rt = RoundCommitments.from_payload(4, commits.to_payload())
    assert rt.entries == commits.entries
    assert rt.root == commits.root


def test_merkle_membership_proofs_1000_clients():
    """Per-client inclusion proofs on a 1000-entry commitment tree: every
    proof verifies against the root in O(log n) hashes; any digest or
    sender substitution breaks it."""
    n = 1000
    entries = tuple((i, f"digest_{i:04d}") for i in range(n))
    commits = RoundCommitments(3, entries)
    for sender in [0, 1, 499, 512, 998, 999]:
        proof = commits.proof(sender)
        assert len(proof.path) == 10              # ceil(log2(1000))
        assert verify_membership(commits.root, sender, 3,
                                 f"digest_{sender:04d}", proof)
        # wrong digest, wrong sender, wrong round: all rejected
        assert not verify_membership(commits.root, sender, 3, "evil", proof)
        assert not verify_membership(commits.root, sender + 1, 3,
                                     f"digest_{sender:04d}", proof)
        assert not verify_membership(commits.root, sender, 4,
                                     f"digest_{sender:04d}", proof)
    # a tampered sibling path cannot reach the root
    p = commits.proof(5)
    bad = type(p)(p.leaf, ((("0" * 64), p.path[0][1]),) + p.path[1:])
    assert not verify_membership(commits.root, 5, 3, "digest_0005", bad)


def test_malformed_agg_commit_rejects_everyone():
    """A producer whose commitment record is inconsistent (root does not
    match its entries) verifies nobody — it must not crash consensus."""
    chain, pool = Blockchain(), TxPool()
    pool.submit(Transaction("model_hash", 0, "d0", 0))
    commits = RoundCommitments(0, ((0, "d0"),))
    body = json.loads(commits.to_payload())
    body["root"] = "0" * 64
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, json.dumps(body), 0))
    ok = chain.verify_round(chain.pack_block(0, 0, pool), 1)
    np.testing.assert_array_equal(ok, [False])
    # structurally bogus payloads (wrong JSON shapes) must reject, not raise
    for payload in ['{"root": "r", "entries": 5}', '{"entries": null}', "[]"]:
        pool.submit(Transaction("model_hash", 0, "d0", 1))
        pool.submit(Transaction(AGG_COMMIT_KIND, 0, payload, 1))
        ok = chain.verify_round(chain.pack_block(1, 0, pool), 1)
        np.testing.assert_array_equal(ok, [False])


def test_commitment_leaf_binds_all_fields():
    base = commitment_leaf(1, 2, "d")
    assert commitment_leaf(2, 2, "d") != base
    assert commitment_leaf(1, 3, "d") != base
    assert commitment_leaf(1, 2, "e") != base
    assert commitment_leaf(1, 2, "d") == base


def test_ledger_conservation_with_burn():
    ledger = TokenLedger(4, initial_stake=5.0)
    assert ledger.conserved()
    ledger.mint_reward_pool(20.0)
    rewards = np.asarray([6.0, 6.0, 6.0, 2.0])
    verified = np.asarray([True, True, False, True])
    ledger.settle_round(rewards, fee=0.5, producer=0, verified=verified)
    assert ledger.conserved()
    # unverified client's balance unchanged
    np.testing.assert_allclose(ledger.balances[2], 5.0)
    # supply = stakes + pool - burned
    np.testing.assert_allclose(ledger.total_supply(), 4 * 5 + 20 - 6.0)

"""Blockchain: hash links, merkle roots, consensus verification, ledger."""
import json

import jax.numpy as jnp
import numpy as np

from repro.blockchain import Block, Blockchain, TokenLedger, Transaction, TxPool, hash_params


def test_hash_params_deterministic_and_sensitive():
    p = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    h1, h2 = hash_params(p), hash_params(p)
    assert h1 == h2
    p2 = {"a": jnp.arange(6.0).reshape(2, 3).at[0, 0].set(1e-7),
          "b": {"c": jnp.ones((4,))}}
    assert hash_params(p2) != h1
    # structure-sensitive too
    p3 = {"a": jnp.arange(6.0).reshape(3, 2), "b": {"c": jnp.ones((4,))}}
    assert hash_params(p3) != h1


def test_chain_links_and_validation():
    chain = Blockchain()
    pool = TxPool()
    for r in range(3):
        pool.submit(Transaction("model_hash", r, f"h{r}", r))
        chain.pack_block(r, producer=r % 2, pool=pool)
    assert chain.validate()
    assert len(chain.blocks) == 4  # genesis + 3
    # tampering with a block breaks the chain
    b = chain.blocks[2]
    chain.blocks[2] = Block(b.index, b.round_idx, 9, b.prev_hash,
                            b.merkle_root, b.transactions)
    assert not chain.validate()


def test_verify_round_accepts_matching_rejects_tampered():
    chain = Blockchain()
    pool = TxPool()
    hashes = [f"hash_{i}" for i in range(4)]
    for i, h in enumerate(hashes):
        pool.submit(Transaction("model_hash", i, h, 0))
    # producer only aggregated clients 0,1,3 (client 2 freerode)
    pool.submit(Transaction("agg_hash", 0, json.dumps([hashes[0], hashes[1],
                                                       hashes[3]]), 0))
    block = chain.pack_block(0, 0, pool)
    ok = chain.verify_round(block, 4)
    np.testing.assert_array_equal(ok, [True, True, False, True])


def test_ledger_conservation_with_burn():
    ledger = TokenLedger(4, initial_stake=5.0)
    assert ledger.conserved()
    ledger.mint_reward_pool(20.0)
    rewards = np.asarray([6.0, 6.0, 6.0, 2.0])
    verified = np.asarray([True, True, False, True])
    ledger.settle_round(rewards, fee=0.5, producer=0, verified=verified)
    assert ledger.conserved()
    # unverified client's balance unchanged
    np.testing.assert_allclose(ledger.balances[2], 5.0)
    # supply = stakes + pool - burned
    np.testing.assert_allclose(ledger.total_supply(), 4 * 5 + 20 - 6.0)

"""Blockchain: hash links, merkle roots, consensus verification (sender-bound
+ legacy), commitment Merkle membership proofs, ledger."""
import json

import jax.numpy as jnp
import numpy as np

from repro.blockchain import (
    AGG_COMMIT_KIND,
    Block,
    Blockchain,
    RoundCommitments,
    TokenLedger,
    Transaction,
    TxPool,
    commitment_leaf,
    hash_params,
    verify_membership,
)
from repro.blockchain.chain import _legacy_merkle_root, _merkle_root


def test_hash_params_deterministic_and_sensitive():
    p = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    h1, h2 = hash_params(p), hash_params(p)
    assert h1 == h2
    p2 = {"a": jnp.arange(6.0).reshape(2, 3).at[0, 0].set(1e-7),
          "b": {"c": jnp.ones((4,))}}
    assert hash_params(p2) != h1
    # structure-sensitive too
    p3 = {"a": jnp.arange(6.0).reshape(3, 2), "b": {"c": jnp.ones((4,))}}
    assert hash_params(p3) != h1


def test_chain_links_and_validation():
    chain = Blockchain()
    pool = TxPool()
    for r in range(3):
        pool.submit(Transaction("model_hash", r, f"h{r}", r))
        chain.pack_block(r, producer=r % 2, pool=pool)
    assert chain.validate()
    assert len(chain.blocks) == 4  # genesis + 3
    # tampering with a block breaks the chain
    b = chain.blocks[2]
    chain.blocks[2] = Block(b.index, b.round_idx, 9, b.prev_hash,
                            b.merkle_root, b.transactions)
    assert not chain.validate()


def test_duplicated_last_tx_mutation_fails_validation():
    """CVE-2012-2459 analogue: under the retired merkle scheme (duplicate
    last hash on odd levels) a block whose last transaction is duplicated
    kept the same root, so ``validate()`` accepted the mutated chain.  The
    domain-separated root rejects it."""
    chain = Blockchain()
    pool = TxPool()
    for i in range(3):                       # odd count → old scheme self-paired
        pool.submit(Transaction("model_hash", i, f"h{i}", 0))
    block = chain.pack_block(0, producer=0, pool=pool)
    assert chain.validate()

    # the mutation: append a duplicate of the last tx, keep the recorded root
    mutated = Block(block.index, block.round_idx, block.producer,
                    block.prev_hash, block.merkle_root,
                    block.transactions + (block.transactions[-1],))
    # regression guard: the retired scheme really did collide on this mutation
    legacy_orig, _ = _legacy_merkle_root(
        [t.tx_hash() for t in block.transactions])
    legacy_mut, flagged = _legacy_merkle_root(
        [t.tx_hash() for t in mutated.transactions])
    assert legacy_orig == legacy_mut and flagged
    chain.blocks[-1] = mutated
    assert not chain.validate()


def test_legacy_merkle_blocks_still_validate():
    """A chain whose blocks recorded pre-domain-separation roots (old code)
    must keep validating after the fix — but its mutated variant must not."""
    chain = Blockchain()
    pool = TxPool()
    for i in range(3):
        pool.submit(Transaction("model_hash", i, f"h{i}", 0))
    txs = tuple(pool.drain())
    legacy_root, mutated = _legacy_merkle_root([t.tx_hash() for t in txs])
    assert not mutated
    old_block = Block(1, 0, 0, chain.head.block_hash(), legacy_root, txs)
    chain.blocks.append(old_block)
    assert chain.validate()                       # migration path
    chain.blocks[-1] = Block(1, 0, 0, old_block.prev_hash, legacy_root,
                             txs + (txs[-1],))
    assert not chain.validate()                   # same root, flagged mutation


def test_merkle_root_domain_separated():
    """Leaf and interior domains are disjoint: a 'block' whose single tx hash
    equals another block's interior node cannot forge that block's root."""
    a, b = "aa", "bb"
    root2 = _merkle_root([a, b])
    assert _merkle_root([root2]) != root2
    assert _merkle_root([a]) != a


def test_verify_round_accepts_matching_rejects_tampered():
    chain = Blockchain()
    pool = TxPool()
    hashes = [f"hash_{i}" for i in range(4)]
    for i, h in enumerate(hashes):
        pool.submit(Transaction("model_hash", i, h, 0))
    # producer only aggregated clients 0,1,3 (client 2 freerode)
    commits = RoundCommitments(0, ((0, hashes[0]), (1, hashes[1]),
                                   (3, hashes[3])))
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, commits.to_payload(), 0))
    block = chain.pack_block(0, 0, pool)
    ok = chain.verify_round(block, 4)
    np.testing.assert_array_equal(ok, [True, True, False, True])


def _copy_attack_block(chain, pool, *, legacy):
    """3 clients: 0 and 1 honest (1's params happen to equal 0's), 2 is a
    freerider that commits a COPY of client 0's digest.  The producer
    aggregated digests d0 for client 0, d0 for client 1 (identical params),
    and d2 (what client 2 actually delivered)."""
    d0, d2 = "digest_honest", "digest_of_2s_actual_params"
    pool.submit(Transaction("model_hash", 0, d0, 0))
    pool.submit(Transaction("model_hash", 1, d0, 0))
    pool.submit(Transaction("model_hash", 2, d0, 0))          # the copy attack
    if legacy:
        pool.submit(Transaction("agg_hash", 9, json.dumps(sorted([d0, d0, d2])), 0))
    else:
        commits = RoundCommitments(0, ((0, d0), (1, d0), (2, d2)))
        pool.submit(Transaction(AGG_COMMIT_KIND, 9, commits.to_payload(), 0))
    return chain.pack_block(0, 9, pool)


def test_hash_copy_freerider_regression():
    """THE anti-freeriding regression (ISSUE 2): a client committing a copy
    of an honest peer's digest was VERIFIED (and hence paid) under the old
    set-membership rule, and is REJECTED under sender-bound commitments —
    while the honest duplicate (client 1, identical params to client 0)
    stays verified in both."""
    legacy_ok = Blockchain().verify_round(
        _copy_attack_block(Blockchain(), TxPool(), legacy=True), 3)
    np.testing.assert_array_equal(legacy_ok, [True, True, True])   # attack paid

    bound_ok = Blockchain().verify_round(
        _copy_attack_block(Blockchain(), TxPool(), legacy=False), 3)
    np.testing.assert_array_equal(bound_ok, [True, True, False])   # rejected


def test_duplicate_commits_resolve_first_wins_on_both_sides():
    """A client that re-submits a model_hash AFTER the producer recorded it
    must be judged against its FIRST commit — the digest the producer actually
    aggregated.  Last-wins (the old behavior) judged the client against the
    late re-submission: an honest re-submitter was punished, and a freerider
    could overwrite its commit to match the producer's entry for it."""
    chain = Blockchain()
    pool = TxPool()
    pool.submit(Transaction("model_hash", 0, "d0", 0))
    pool.submit(Transaction("model_hash", 1, "d1", 0))
    commits = RoundCommitments(0, ((0, "d0"), (1, "d1")))
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, commits.to_payload(), 0))
    # client 0 re-submits a different digest after the producer's record;
    # client 1 re-submits the digest the producer bound to it (alignment try)
    pool.submit(Transaction("model_hash", 0, "d0-late", 0))
    pool.submit(Transaction("model_hash", 1, "d1", 0))
    ok = chain.verify_round(chain.pack_block(0, 0, pool), 2)
    np.testing.assert_array_equal(ok, [True, True])

    # the freerider direction: first commit is wrong, late commit aligned
    pool.submit(Transaction("model_hash", 0, "not-what-was-delivered", 1))
    commits = RoundCommitments(1, ((0, "actual-delivery"),))
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, commits.to_payload(), 1))
    pool.submit(Transaction("model_hash", 0, "actual-delivery", 1))
    ok = chain.verify_round(chain.pack_block(1, 0, pool), 1)
    np.testing.assert_array_equal(ok, [False])


def test_agg_commit_from_non_producer_is_ignored():
    """First-wins must not be front-runnable: an agg_commit submitted by a
    NON-producer before the producer's genuine record (malformed or forged)
    is ignored entirely — verification still runs against the producer's
    record instead of wiping or rewriting the round."""
    chain = Blockchain()
    pool = TxPool()
    pool.submit(Transaction("model_hash", 0, "d0", 0))
    # attacker front-runs with a forged record, then with garbage
    forged = RoundCommitments(0, ((0, "evil"),))
    pool.submit(Transaction(AGG_COMMIT_KIND, 5, forged.to_payload(), 0))
    pool.submit(Transaction(AGG_COMMIT_KIND, 6, "not json", 0))
    real = RoundCommitments(0, ((0, "d0"),))
    pool.submit(Transaction(AGG_COMMIT_KIND, 3, real.to_payload(), 0))
    ok = chain.verify_round(chain.pack_block(0, producer=3, pool=pool), 1)
    np.testing.assert_array_equal(ok, [True])


def test_duplicate_agg_commits_first_wins():
    """Multiple producer records in one block: the first wins, mirroring the
    first-wins rule for client commits (a second, conflicting record cannot
    retroactively re-judge the round)."""
    chain = Blockchain()
    pool = TxPool()
    pool.submit(Transaction("model_hash", 0, "d0", 0))
    good = RoundCommitments(0, ((0, "d0"),))
    bad = RoundCommitments(0, ((0, "evil"),))
    pool.submit(Transaction(AGG_COMMIT_KIND, 1, good.to_payload(), 0))
    pool.submit(Transaction(AGG_COMMIT_KIND, 1, bad.to_payload(), 0))
    ok = chain.verify_round(chain.pack_block(0, 1, pool), 1)
    np.testing.assert_array_equal(ok, [True])


def test_agg_commit_preserves_duplicate_entries():
    """Old format packed sorted(hashes) — duplicates collapsed under set
    semantics.  The sender-bound record keeps one entry per arrived client."""
    commits = RoundCommitments(4, ((7, "d"), (8, "d"), (9, "e")))
    assert len(commits.entries) == 3
    rt = RoundCommitments.from_payload(4, commits.to_payload())
    assert rt.entries == commits.entries
    assert rt.root == commits.root


def test_merkle_membership_proofs_1000_clients():
    """Per-client inclusion proofs on a 1000-entry commitment tree: every
    proof verifies against the root in O(log n) hashes; any digest or
    sender substitution breaks it."""
    n = 1000
    entries = tuple((i, f"digest_{i:04d}") for i in range(n))
    commits = RoundCommitments(3, entries)
    for sender in [0, 1, 499, 512, 998, 999]:
        proof = commits.proof(sender)
        assert len(proof.path) == 10              # ceil(log2(1000))
        assert verify_membership(commits.root, sender, 3,
                                 f"digest_{sender:04d}", proof)
        # wrong digest, wrong sender, wrong round: all rejected
        assert not verify_membership(commits.root, sender, 3, "evil", proof)
        assert not verify_membership(commits.root, sender + 1, 3,
                                     f"digest_{sender:04d}", proof)
        assert not verify_membership(commits.root, sender, 4,
                                     f"digest_{sender:04d}", proof)
    # a tampered sibling path cannot reach the root
    p = commits.proof(5)
    bad = type(p)(p.leaf, ((("0" * 64), p.path[0][1]),) + p.path[1:])
    assert not verify_membership(commits.root, 5, 3, "digest_0005", bad)


def test_malformed_agg_commit_rejects_everyone():
    """A producer whose commitment record is inconsistent (root does not
    match its entries) verifies nobody — it must not crash consensus."""
    chain, pool = Blockchain(), TxPool()
    pool.submit(Transaction("model_hash", 0, "d0", 0))
    commits = RoundCommitments(0, ((0, "d0"),))
    body = json.loads(commits.to_payload())
    body["root"] = "0" * 64
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, json.dumps(body), 0))
    ok = chain.verify_round(chain.pack_block(0, 0, pool), 1)
    np.testing.assert_array_equal(ok, [False])
    # structurally bogus payloads (wrong JSON shapes) must reject, not raise
    for payload in ['{"root": "r", "entries": 5}', '{"entries": null}', "[]"]:
        pool.submit(Transaction("model_hash", 0, "d0", 1))
        pool.submit(Transaction(AGG_COMMIT_KIND, 0, payload, 1))
        ok = chain.verify_round(chain.pack_block(1, 0, pool), 1)
        np.testing.assert_array_equal(ok, [False])


def test_commitment_leaf_binds_all_fields():
    base = commitment_leaf(1, 2, "d")
    assert commitment_leaf(2, 2, "d") != base
    assert commitment_leaf(1, 3, "d") != base
    assert commitment_leaf(1, 2, "e") != base
    assert commitment_leaf(1, 2, "d") == base


def test_ledger_conservation_with_burn():
    ledger = TokenLedger(4, initial_stake=5.0)
    assert ledger.conserved()
    ledger.mint_reward_pool(20.0)
    rewards = np.asarray([6.0, 6.0, 6.0, 2.0])
    verified = np.asarray([True, True, False, True])
    ledger.settle_round(rewards, fee=0.5, producer=0, verified=verified)
    assert ledger.conserved()
    # unverified client's balance unchanged
    np.testing.assert_allclose(ledger.balances[2], 5.0)
    # supply = stakes + pool - burned
    np.testing.assert_allclose(ledger.total_supply(), 4 * 5 + 20 - 6.0)


def test_unverified_producer_forfeits_fees():
    """A producer whose own commitment failed verification must NOT collect
    the aggregation fees (the old behavior paid it unconditionally — an
    unverified aggregator still profited from every verified client).  The
    fees are burned and supply stays conserved."""
    ledger = TokenLedger(4, initial_stake=5.0)
    ledger.mint_reward_pool(20.0)
    rewards = np.asarray([6.0, 6.0, 6.0, 2.0])
    verified = np.asarray([False, True, True, True])     # producer 0 failed
    ledger.settle_round(rewards, fee=0.5, producer=0, verified=verified)
    assert ledger.conserved()
    # producer: no reward (unverified), no fee income — stake untouched
    np.testing.assert_allclose(ledger.balances[0], 5.0)
    # verified clients: reward − fee as usual
    np.testing.assert_allclose(ledger.balances[1], 5.0 + 6.0 - 0.5)
    # supply = stakes + pool − burned reward − burned fees
    np.testing.assert_allclose(ledger.total_supply(),
                               4 * 5 + 20 - 6.0 - 3 * 0.5)


def test_ledger_conservation_property_random_rounds():
    """Conservation holds over a stream of random settlements including
    unverified producers (the forfeited-fee burn path)."""
    rng = np.random.default_rng(0)
    ledger = TokenLedger(16, initial_stake=5.0)
    for _ in range(50):
        rewards = rng.uniform(0.0, 3.0, 16)
        verified = rng.random(16) < 0.7
        producer = int(rng.integers(16))
        ledger.mint_reward_pool(float(rewards.sum()))
        ledger.settle_round(rewards, fee=float(rng.uniform(0, 0.3)),
                            producer=producer, verified=verified)
        assert ledger.conserved()

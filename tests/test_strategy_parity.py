"""Strategy-generic fused engine: every registered strategy (bfln, fedavg,
fedprox, fedproto, fedhkd) runs through the ONE donated jitted round step,
replays identically to the legacy ``engine=False`` sim driver (sync and
async, including empty-arrival rounds), matches the legacy
``FederatedTrainer`` path on a full-participation round (allclose params +
identical eval accuracy), and keeps the 1-compile-per-entry guarantee."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.core import FederatedTrainer
from repro.core.engine import RoundEngine
from repro.core.fl import global_evaluate
from repro.models import classifier as clf
from repro.optim import adam
from repro.runtime.arena import ParamArena
from repro.sim import ClientPopulation, PopulationSpec, SimulatedFederation

ALL_STRATEGIES = ["bfln", "fedavg", "fedprox", "fedproto", "fedhkd"]


def _pop(n=30, seed=3, **kw):
    defaults = dict(n_clients=n, dataset="synth10", beta=0.3, n_batches=1,
                    batch_size=16, straggler_frac=0.2, straggler_slowdown=8.0,
                    dropout_rate=0.05, byzantine_frac=0.1, seed=seed)
    defaults.update(kw)
    return ClientPopulation.from_spec(PopulationSpec(**defaults))


def _sim(pop, strategy, engine, **kw):
    flat = dict(rounds=3, sample_frac=0.3, n_clusters=3, eval_every=1,
                seed=3, engine=engine, strategy=strategy)
    flat.update(kw)
    return SimulatedFederation(pop, api.ExperimentSpec.from_flat(**flat))


def _block_hashes(sim):
    return [b.block_hash() for b in sim.trainer.chain.blocks]


def _assert_replay_identical(a, ra, b, rb):
    assert ra.event_log == rb.event_log
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(ra.balances, rb.balances)
    assert ra.final_accuracy == rb.final_accuracy
    for x, y in zip(ra.history, rb.history):
        assert x.producer == y.producer
        assert x.reward_paid == y.reward_paid
        # round-metric accuracy may differ by one ulp between the engine's
        # masked eval (sum/denom) and the legacy jnp.mean (sum × 1/n
        # reciprocal rounding) — a metric-only display value; everything that
        # feeds the protocol (event log, hashes, balances, final accuracy)
        # is compared exactly above.  BFLN's exact round-metric parity is
        # pinned separately in tests/test_engine.py.
        assert x.accuracy == pytest.approx(y.accuracy, rel=1e-6, nan_ok=True)


# --------------------------------------------------------------------------- #
# fused engine vs legacy sim driver (sync) — fast subset + slow full matrix
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("strategy", ["fedavg", "fedproto"])
def test_engine_replay_matches_legacy_driver_sync_fast(strategy):
    """fedavg (mask-weighted mean) and fedproto (personal models) cover the
    two non-BFLN aggregation shapes; bfln is pinned by tests/test_engine."""
    a = _sim(_pop(), strategy, engine=True)
    b = _sim(_pop(), strategy, engine=False)
    _assert_replay_identical(a, a.run(), b, b.run())


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_engine_replay_matches_legacy_driver_sync(strategy):
    a = _sim(_pop(n=40), strategy, engine=True, rounds=4)
    b = _sim(_pop(n=40), strategy, engine=False, rounds=4)
    ra, rb = a.run(), b.run()
    _assert_replay_identical(a, ra, b, rb)
    assert any(not r.arrived.all() for r in ra.history), \
        "replay should cover rounds with missing arrivals"


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_engine_replay_matches_legacy_driver_async(strategy):
    kw = dict(mode="async", buffer_size=6, concurrency=12)
    a = _sim(_pop(n=40), strategy, engine=True, **kw)
    b = _sim(_pop(n=40), strategy, engine=False, **kw)
    ra, rb = a.run(), b.run()
    _assert_replay_identical(a, ra, b, rb)
    assert any(r.staleness_mean > 0 for r in ra.history)


@pytest.mark.parametrize("strategy", ["fedavg", "bfln"])
def test_empty_arrival_round_identical_and_blockless(strategy):
    """Nobody beats the deadline: no block minted, balances untouched, and
    the engine never compiles — for baselines exactly like for bfln."""
    def make():
        pop = _pop(n=20, straggler_frac=0.0, dropout_rate=0.0)
        pop.latency.speed[:] = 1e9
        return pop
    a = _sim(make(), strategy, engine=True, rounds=2, eval_every=0)
    b = _sim(make(), strategy, engine=False, rounds=2, eval_every=0)
    ra, rb = a.run(), b.run()
    assert ra.event_log == rb.event_log
    assert all(not r.arrived.any() for r in ra.history)
    assert len(a.trainer.chain.blocks) == 1          # genesis only
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(
        ra.balances, np.full(20, a.cfg.initial_stake))
    assert a.engine.cache_sizes()["sync_step"] == 0


def test_cache_sizes_one_compile_per_entry_per_strategy():
    """The 1-compile-per-entry contract holds for a baseline strategy under
    varying arrival counts, exactly as for bfln."""
    sim = _sim(_pop(n=40, straggler_frac=0.3), "fedhkd", engine=True,
               rounds=4, eval_every=1)
    rep = sim.run()
    counts = {int(r.arrived.sum()) for r in rep.history}
    assert len(counts) > 1, "population should produce varying arrival counts"
    sizes = sim.engine.cache_sizes()
    assert sizes["sync_step"] == 1, sizes
    assert sizes["eval_cohort"] == 1, sizes
    assert sizes["eval_population"] == 1, sizes


def test_engine_requires_aggregate_cohort():
    from repro.core.baselines import Strategy
    data = api.load_packed_clients("synth10", 4, 0.3, n_batches=1,
                                   batch_size=8, psi=8)
    cfg, bundle = api.make_mlp_bundle(data.in_dim, data.num_classes,
                                      hidden=(8,), rep_dim=4)
    legacy_only = Strategy("legacy", None, None, None)   # no cohort stage
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), 4)
    arena = ParamArena.from_stacked(sp)
    with pytest.raises(ValueError, match="aggregate_cohort"):
        RoundEngine(arena.layout, apply_fn=bundle.apply_fn,
                    strategy=legacy_only, opt=adam(1e-3), n_clusters=2,
                    local_epochs=1)


# --------------------------------------------------------------------------- #
# fused engine vs the legacy FederatedTrainer path (full participation)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_engine_round_matches_federated_trainer(strategy):
    """One full-participation round from identical init: the engine's fused
    step aggregates like ``FederatedTrainer._train_round`` + ``aggregate``
    (allclose params, identical mean eval accuracy)."""
    n = 6
    data = api.load_packed_clients("synth10", n, 0.3, n_batches=2,
                                   batch_size=8, psi=8)
    cfg, bundle = api.make_mlp_bundle(data.in_dim, data.num_classes,
                                      hidden=(16,), rep_dim=8)
    strat = api.build_strategy(strategy, bundle, probe=data.probe,
                               n_clusters=2)
    opt = adam(1e-3)
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), n)

    # legacy path: one trainer round (fresh optimizer state, like the sim)
    tr = FederatedTrainer(bundle, strat, opt, local_epochs=2, n_clusters=2,
                          use_chain=False)
    p0, o0 = tr.init(sp)
    local_params, agg, _, tr_loss = tr._train_round(p0, o0, data.cx, data.cy)

    # engine path: the same round through the donated fused step
    arena = ParamArena.from_stacked(sp)
    eng = RoundEngine(
        arena.layout, apply_fn=bundle.apply_fn, strategy=strat, opt=opt,
        n_clusters=2, local_epochs=2,
        stacked_apply_fn=functools.partial(clf.apply_stacked, cfg))
    _, out = eng.sync_step(arena.data, jnp.arange(n), data.cx, data.cy,
                           jnp.ones((n,), jnp.float32))
    engine_params = arena.layout.unflatten(out.new_rows)

    for a, b in zip(jax.tree.leaves(agg.stacked_params),
                    jax.tree.leaves(engine_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    assert float(out.mean_loss) == pytest.approx(float(tr_loss), rel=1e-6)
    acc_tr = float(global_evaluate(bundle.apply_fn, agg.stacked_params,
                                   data.test_x, data.test_y))
    acc_eng = float(global_evaluate(bundle.apply_fn, engine_params,
                                    data.test_x, data.test_y))
    assert acc_tr == acc_eng

"""HLO collective accounting: trip-count weighting on synthetic modules."""
from repro.launch.hlo import collective_bytes, collective_counts, computation_multipliers

HLO = """
HloModule test

%region_body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %ar.1 = f32[64,64]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[128,64]{1,0} all-gather(%y), dimensions={0}
  ROOT %t = (s32[], f32[64,64]) tuple(%i, %ar.1)
}

%region_cond.2 (p: (s32[], f32[64,64])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.3 (a: f32[64,64]) -> f32[64,64] {
  %rs.2 = f32[32,64]{1,0} reduce-scatter(%a), dimensions={0}
  %w = (s32[], f32[64,64]) while(%init), condition=%region_cond.2, body=%region_body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[64,64] get-tuple-element(%w), index=1
}
"""


def test_multipliers_resolve_trip_counts():
    mult = computation_multipliers(HLO)
    assert mult["main.3"] == 1
    assert mult["region_body.1"] == 12


def test_collective_bytes_weighted():
    got = collective_bytes(HLO)
    ar = 64 * 64 * 4 * 12          # inside while ×12
    ag = 128 * 64 * 4 * 12
    rs = 32 * 64 * 4               # top level ×1
    assert got["all-reduce"] == ar
    assert got["all-gather"] == ag
    assert got["reduce-scatter"] == rs
    assert got["total"] == ar + ag + rs


def test_collective_counts_weighted():
    got = collective_counts(HLO)
    assert got["all-reduce"] == 12
    assert got["reduce-scatter"] == 1

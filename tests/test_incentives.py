"""Incentive mechanism (Eqs. 7–9): property-based invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.incentives import allocate_rewards, apply_round_settlement


@settings(max_examples=50, deadline=None)
@given(
    m=st.integers(2, 40),
    c=st.integers(1, 8),
    rho=st.floats(1.1, 3.0),
    reward=st.floats(1.0, 100.0),
    seed=st.integers(0, 2**16),
)
def test_total_reward_conserved(m, c, rho, reward, seed):
    rng = np.random.default_rng(seed)
    labels = jnp.asarray(rng.integers(0, c, m))
    alloc = allocate_rewards(labels, c, reward, rho)
    # Σ Γ(n_i) = ℜ exactly (over non-empty clusters)
    np.testing.assert_allclose(float(jnp.sum(alloc.cluster_reward)), reward,
                               rtol=1e-5)
    # per-client payouts also sum to ℜ
    np.testing.assert_allclose(float(jnp.sum(alloc.client_reward)), reward,
                               rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(rho=st.floats(1.05, 3.0), seed=st.integers(0, 2**16))
def test_percapita_reward_increases_with_cluster_size(rho, seed):
    """ρ>1 ⇒ bigger clusters pay more *per member* (the paper's design goal)."""
    rng = np.random.default_rng(seed)
    sizes = sorted(rng.integers(1, 10, 3).tolist())
    labels = jnp.asarray(np.repeat(np.arange(3), sizes))
    alloc = allocate_rewards(labels, 3, 20.0, rho)
    per_capita = np.asarray(alloc.cluster_reward) / np.maximum(sizes, 1)
    assert all(per_capita[i] <= per_capita[i + 1] + 1e-9 for i in range(2))


def test_equal_shares_within_cluster():
    labels = jnp.asarray([0, 0, 0, 1, 1, 2])
    alloc = allocate_rewards(labels, 3, 20.0, 2.0)
    r = np.asarray(alloc.client_reward)
    np.testing.assert_allclose(r[0], r[1])
    np.testing.assert_allclose(r[1], r[2])
    np.testing.assert_allclose(r[3], r[4])


def test_paper_rho2_example():
    """ρ=2, clusters (3,1): κ = 20/10 = 2; Γ = (18, 2); per-capita (6, 2)."""
    labels = jnp.asarray([0, 0, 0, 1])
    alloc = allocate_rewards(labels, 2, 20.0, 2.0)
    np.testing.assert_allclose(np.asarray(alloc.cluster_reward), [18.0, 2.0],
                               rtol=1e-6)
    np.testing.assert_allclose(float(alloc.kappa), 2.0, rtol=1e-6)
    np.testing.assert_allclose(float(alloc.fee), 0.5, rtol=1e-6)  # κ/N


def test_settlement_routes_fees_to_producer():
    labels = jnp.asarray([0, 0, 1, 1])
    alloc = allocate_rewards(labels, 2, 20.0, 2.0)
    balances = jnp.full((4,), 5.0)
    verified = jnp.asarray([True, True, True, False])
    new = apply_round_settlement(balances, alloc, producer=0, verified=verified)
    new = np.asarray(new)
    # producer 0 collected 3 fees; client 3 (unverified) got nothing, paid nothing
    fee = float(alloc.fee)
    assert np.isclose(new[3], 5.0)
    expected_total = 20.0 + 4 * 5.0 - float(alloc.client_reward[3])
    np.testing.assert_allclose(new.sum(), expected_total, rtol=1e-6)
    assert new[0] > new[1]  # producer collected fees

"""Incentive mechanism (Eqs. 7–9): property-based invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.incentives import allocate_rewards, apply_round_settlement


@settings(max_examples=50, deadline=None)
@given(
    m=st.integers(2, 40),
    c=st.integers(1, 8),
    rho=st.floats(1.1, 3.0),
    reward=st.floats(1.0, 100.0),
    seed=st.integers(0, 2**16),
)
def test_total_reward_conserved(m, c, rho, reward, seed):
    rng = np.random.default_rng(seed)
    labels = jnp.asarray(rng.integers(0, c, m))
    alloc = allocate_rewards(labels, c, reward, rho)
    # Σ Γ(n_i) = ℜ exactly (over non-empty clusters)
    np.testing.assert_allclose(float(jnp.sum(alloc.cluster_reward)), reward,
                               rtol=1e-5)
    # per-client payouts also sum to ℜ
    np.testing.assert_allclose(float(jnp.sum(alloc.client_reward)), reward,
                               rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(rho=st.floats(1.05, 3.0), seed=st.integers(0, 2**16))
def test_percapita_reward_increases_with_cluster_size(rho, seed):
    """ρ>1 ⇒ bigger clusters pay more *per member* (the paper's design goal)."""
    rng = np.random.default_rng(seed)
    sizes = sorted(rng.integers(1, 10, 3).tolist())
    labels = jnp.asarray(np.repeat(np.arange(3), sizes))
    alloc = allocate_rewards(labels, 3, 20.0, rho)
    per_capita = np.asarray(alloc.cluster_reward) / np.maximum(sizes, 1)
    assert all(per_capita[i] <= per_capita[i + 1] + 1e-9 for i in range(2))


def test_equal_shares_within_cluster():
    labels = jnp.asarray([0, 0, 0, 1, 1, 2])
    alloc = allocate_rewards(labels, 3, 20.0, 2.0)
    r = np.asarray(alloc.client_reward)
    np.testing.assert_allclose(r[0], r[1])
    np.testing.assert_allclose(r[1], r[2])
    np.testing.assert_allclose(r[3], r[4])


def test_paper_rho2_example():
    """ρ=2, clusters (3,1): κ = 20/10 = 2; Γ = (18, 2); per-capita (6, 2)."""
    labels = jnp.asarray([0, 0, 0, 1])
    alloc = allocate_rewards(labels, 2, 20.0, 2.0)
    np.testing.assert_allclose(np.asarray(alloc.cluster_reward), [18.0, 2.0],
                               rtol=1e-6)
    np.testing.assert_allclose(float(alloc.kappa), 2.0, rtol=1e-6)
    np.testing.assert_allclose(float(alloc.fee), 0.5, rtol=1e-6)  # κ/N


def test_settlement_routes_fees_to_producer():
    labels = jnp.asarray([0, 0, 1, 1])
    alloc = allocate_rewards(labels, 2, 20.0, 2.0)
    balances = jnp.full((4,), 5.0)
    verified = jnp.asarray([True, True, True, False])
    new = apply_round_settlement(balances, alloc, producer=0, verified=verified)
    new = np.asarray(new)
    # producer 0 collected 3 fees; client 3 (unverified) got nothing, paid nothing
    fee = float(alloc.fee)
    assert np.isclose(new[3], 5.0)
    expected_total = 20.0 + 4 * 5.0 - float(alloc.client_reward[3])
    np.testing.assert_allclose(new.sum(), expected_total, rtol=1e-6)
    assert new[0] > new[1]  # producer collected fees


def test_unverified_producer_forfeits_fees_jittable_mirror():
    """The jittable settlement burns the fees of an unverified producer
    (regression: it used to credit them unconditionally) and stays in exact
    agreement with the host-side ``TokenLedger.settle_round``."""
    from repro.blockchain import TokenLedger
    labels = jnp.asarray([0, 0, 1, 1])
    alloc = allocate_rewards(labels, 2, 20.0, 2.0)
    balances = jnp.full((4,), 5.0)
    verified = jnp.asarray([False, True, True, True])   # producer 0 unverified
    new = np.asarray(apply_round_settlement(balances, alloc, producer=0,
                                            verified=verified))
    fee = float(alloc.fee)
    # producer: no reward, no fees — balance untouched
    np.testing.assert_allclose(new[0], 5.0, rtol=1e-6)
    # verified clients pay their fee but nobody receives it
    np.testing.assert_allclose(
        new[1:], 5.0 + np.asarray(alloc.client_reward[1:]) - fee, rtol=1e-6)

    # exact agreement with the authoritative host ledger
    ledger = TokenLedger(4, initial_stake=5.0)
    ledger.mint_reward_pool(20.0)
    ledger.settle_round(np.asarray(alloc.client_reward), fee, producer=0,
                        verified=np.asarray(verified))
    np.testing.assert_allclose(ledger.balances, new, rtol=1e-6)
    assert ledger.conserved()

    # and with a verified producer the two mirrors also agree
    verified = jnp.asarray([True, True, False, True])
    new = np.asarray(apply_round_settlement(balances, alloc, producer=0,
                                            verified=verified))
    ledger = TokenLedger(4, initial_stake=5.0)
    ledger.mint_reward_pool(20.0)
    ledger.settle_round(np.asarray(alloc.client_reward), fee, producer=0,
                        verified=np.asarray(verified))
    np.testing.assert_allclose(ledger.balances, new, rtol=1e-6)
    assert ledger.conserved()

"""RWKV6 wkv kernel: sweep vs lax.scan oracle + chunked-state composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(B, H, T, hd, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, H, T, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, T, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, T, hd)).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, T, hd))) * 0.4 + 0.55).astype(dtype)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(dtype)
    s0 = (jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1).astype(jnp.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("B,H,T,hd", [(1, 1, 8, 8), (2, 3, 33, 16), (1, 4, 128, 32)])
def test_rwkv6_matches_oracle(B, H, T, hd):
    r, k, v, w, u, s0 = _mk(B, H, T, hd, seed=T)
    ya, sa = ops.rwkv6_wkv(r, k, v, w, u, s0)
    yb, sb = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), atol=1e-4)


def test_rwkv6_chunked_composition():
    """Running two half-sequences with carried state == one full sequence —
    the contract the ops wrapper relies on for long sequences."""
    r, k, v, w, u, s0 = _mk(1, 2, 64, 16, seed=5)
    y_full, s_full = ops.rwkv6_wkv(r, k, v, w, u, s0)
    y1, s_mid = ops.rwkv6_wkv(r[:, :, :32], k[:, :, :32], v[:, :, :32],
                              w[:, :, :32], u, s0)
    y2, s_end = ops.rwkv6_wkv(r[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                              w[:, :, 32:], u, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=2)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full), atol=1e-4)


def test_rwkv6_decay_zero_forgets_state():
    """w=0 must wipe the state: y depends only on the current token bonus."""
    r, k, v, w, u, s0 = _mk(1, 1, 4, 8, seed=9)
    w0 = jnp.zeros_like(w)
    y, sT = ops.rwkv6_wkv(r, k, v, w0, u, s0)
    # final state = last kv outer product only
    kv_last = np.asarray(k)[0, 0, -1][:, None] * np.asarray(v)[0, 0, -1][None, :]
    np.testing.assert_allclose(np.asarray(sT)[0, 0], kv_last, atol=1e-5)

"""serve_step (KV-cache / recurrent decode) matches the parallel forward —
including SWA ring buffers past the window, MoE routing, Mamba and RWKV
states, and whisper cross-attention."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.decode import decode_step, init_cache, warm_cache
from repro.models.transformer import forward, init_params

CASES = ["gemma3-4b", "jamba-1.5-large-398b", "rwkv6-3b", "whisper-large-v3",
         "grok-1-314b", "h2o-danube-3-4b", "minitron-8b"]


@pytest.mark.parametrize("name", CASES)
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 24   # SWA windows reduce to 8 -> ring buffer wraps 3×
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    enc = None
    if cfg.encoder is not None:
        enc = jax.random.normal(jax.random.PRNGKey(3),
                                (B, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    ref, _, _ = jax.jit(lambda p: forward(cfg, p, tokens=toks, enc_embeds=enc))(params)

    cache = init_cache(cfg, B, S)
    cache = warm_cache(cfg, params, cache, enc_embeds=enc)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, toks[:, i:i + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 2e-2, f"{name}: rel err {rel}"
    assert int(cache["pos"]) == S

"""Optimizers: descent on quadratics, reference-math checks, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adam, adamw, clip_by_global_norm, momentum, sgd, warmup_cosine_schedule


def _quadratic_descend(opt, steps=200):
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["x"] - target) ** 2)

    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(params, g, state)
    return float(loss_fn(params))


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adam(0.05), adamw(0.05, weight_decay=0.0)])
def test_optimizers_descend(opt):
    assert _quadratic_descend(opt) < 1e-3


def test_adam_matches_reference_first_step():
    opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"x": jnp.asarray([1.0])}
    state = opt.init(params)
    g = {"x": jnp.asarray([0.5])}
    new, state = opt.update(params, g, state)
    # bias-corrected first step: update = lr * g/|g| -> exactly lr
    np.testing.assert_allclose(float(new["x"][0]), 1.0 - 0.1, rtol=1e-5)


def test_adamw_decays_weights():
    opt = adamw(0.1, weight_decay=0.1)
    params = {"x": jnp.asarray([10.0])}
    state = opt.init(params)
    zero_g = {"x": jnp.asarray([0.0])}
    new, _ = opt.update(params, zero_g, state)
    assert float(new["x"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}  # norm = 10
    clipped = clip_by_global_norm(g, 5.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 5.0, rtol=1e-5)


def test_warmup_cosine_schedule_shape():
    s = warmup_cosine_schedule(1.0, warmup_steps=10, decay_steps=110)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, atol=1e-6)
    assert float(s(jnp.asarray(60))) < 1.0
    np.testing.assert_allclose(float(s(jnp.asarray(110))), 0.0, atol=1e-6)

"""Spectral clustering: planted-partition recovery, validity, determinism."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pearson import pearson_affinity, pearson_matrix
from repro.core.spectral import kmeans, spectral_cluster


def _planted_affinity(sizes, p_in=0.95, p_out=0.05, seed=0):
    rng = np.random.default_rng(seed)
    m = sum(sizes)
    labels = np.repeat(np.arange(len(sizes)), sizes)
    a = np.where(labels[:, None] == labels[None, :], p_in, p_out)
    a += 0.02 * rng.standard_normal((m, m))
    a = np.clip((a + a.T) / 2, 0, 1)
    np.fill_diagonal(a, 1.0)
    return jnp.asarray(a, jnp.float32), labels


def _partition_match(pred, true):
    """Clustering accuracy up to label permutation (greedy)."""
    pred, true = np.asarray(pred), np.asarray(true)
    total = 0
    for c in np.unique(pred):
        vals, counts = np.unique(true[pred == c], return_counts=True)
        total += counts.max()
    return total / len(true)


@pytest.mark.parametrize("sizes", [(7, 7, 6), (10, 5, 3, 2), (12, 8)])
def test_recovers_planted_clusters(sizes):
    aff, true = _planted_affinity(sizes, seed=len(sizes))
    labels = np.asarray(spectral_cluster(aff, len(sizes)))
    assert _partition_match(labels, true) >= 0.9


def test_labels_valid_and_deterministic():
    aff, _ = _planted_affinity((5, 5, 5), seed=3)
    l1 = np.asarray(spectral_cluster(aff, 3))
    l2 = np.asarray(spectral_cluster(aff, 3))
    assert l1.shape == (15,)
    assert set(l1.tolist()) <= {0, 1, 2}
    np.testing.assert_array_equal(l1, l2)  # replayable (chain validation)


def test_kmeans_centers_are_means():
    pts = jnp.asarray(np.random.default_rng(0).standard_normal((30, 4)), jnp.float32)
    labels, centers = kmeans(pts, 3)
    labels, centers = np.asarray(labels), np.asarray(centers)
    for c in range(3):
        if (labels == c).any():
            np.testing.assert_allclose(centers[c],
                                       np.asarray(pts)[labels == c].mean(0),
                                       atol=1e-4)


def test_end_to_end_prototype_clustering():
    """Prototypes from 3 distinct generating directions -> 3 clean clusters."""
    rng = np.random.default_rng(1)
    base = rng.standard_normal((3, 64)).astype(np.float32)
    protos = np.concatenate([
        base[i] * rng.uniform(0.5, 2.0, (6, 1)).astype(np.float32)
        + 0.05 * rng.standard_normal((6, 64)).astype(np.float32)
        for i in range(3)])
    corr = pearson_matrix(jnp.asarray(protos))
    labels = np.asarray(spectral_cluster(pearson_affinity(corr), 3))
    true = np.repeat(np.arange(3), 6)
    assert _partition_match(labels, true) >= 0.9

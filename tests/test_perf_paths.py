"""The §Perf optimisation paths are mathematically identical to baselines.

* two_step / two_step_bf16 cluster aggregation ≡ the mix matmul (exact / bf16
  tolerance) — property over random labels;
* shard_map expert-parallel MoE ≡ the dense-dispatch MoE, verified on a real
  4-device mesh in a subprocess (device count must be set before jax init).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import cluster_mean_params
from repro.utils.tree import tree_stack


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 24), c=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_two_step_equals_mix(m, c, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), m + 1)
    sp = tree_stack([{"w": jax.random.normal(k, (6, 5)),
                      "b": jax.random.normal(k, (3,))} for k in ks[:m]])
    labels = jax.random.randint(ks[-1], (m,), 0, c)
    a = cluster_mean_params(sp, labels, c, method="mix")
    b = cluster_mean_params(sp, labels, c, method="two_step")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_two_step_bf16_close():
    ks = jax.random.split(jax.random.PRNGKey(0), 9)
    sp = tree_stack([{"w": jax.random.normal(k, (16, 8))} for k in ks[:8]])
    labels = jnp.asarray([0, 0, 1, 1, 2, 2, 2, 0])
    a = cluster_mean_params(sp, labels, 3, method="mix")
    b = cluster_mean_params(sp, labels, 3, method="two_step_bf16")
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), atol=3e-2)


_EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.moe import moe_apply, moe_init, moe_capacity
from repro.models.moe_sharded import moe_apply_shard_map

from repro.launch.mesh import compat_make_mesh, use_mesh
mesh = compat_make_mesh((2, 2), ("data", "model"))
E, D, F, T, k = 4, 16, 32, 64, 2
p = moe_init(jax.random.PRNGKey(0), "swiglu", D, F, E, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, D))
cap = moe_capacity(T, k, E, multiple=8)

with use_mesh(mesh):
    y_ref, aux_ref = jax.jit(
        lambda p, x: moe_apply("swiglu", p, x, top_k=k, capacity=cap))(p, x)
    # EP path: per-shard capacity = cap // 2 per local dispatch -> give the
    # same TOTAL capacity so no extra drops vs the reference
    y_ep, aux_ep = jax.jit(
        lambda p, x: moe_apply_shard_map(
            "swiglu", p, x, top_k=k, capacity=cap * 2))(p, x)

# EP computes capacity per shard; with generous capacity no token drops on
# either path, so outputs must match exactly up to float error.
err = float(jnp.max(jnp.abs(y_ref - y_ep)))
print("MAXERR", err)
assert err < 1e-4, err
print("OK")
"""


def test_shard_map_ep_matches_dense_moe():
    res = subprocess.run([sys.executable, "-c", _EP_SCRIPT],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "OK" in res.stdout, res.stdout + res.stderr

"""Checkpoint container hardening + full experiment-state round-trips.

The contract under test (`repro.checkpoint`): a snapshot survives exactly
the faults the injection harness can throw at it — truncation and bit-flips
raise a clean :class:`CheckpointError` (never a raw zip/pickle exception),
``load_latest`` falls back to the previous keep-last-K snapshot, and a
restored experiment state is byte-for-byte the captured one (bfloat16
leaves included).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    CheckpointSpec,
    capture_experiment_state,
    list_checkpoints,
    load_latest,
    load_pytree,
    restore_experiment_state,
    restore_trainer_state,
    save_checkpoint,
    save_pytree,
    save_trainer_state,
)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert str(x.dtype) == str(y.dtype)
        assert x.shape == y.shape
        assert x.tobytes() == y.tobytes()


def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                       "c": [jnp.asarray(1), jnp.asarray([True, False])]}}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    _assert_trees_equal(tree, load_pytree(path))


def test_pytree_roundtrip_bf16_exact_bits(tmp_path):
    # bfloat16 values that do NOT survive a float32 round-trip-and-cast
    # blindly: check raw bytes, not values
    rng = np.random.default_rng(0)
    arr = rng.standard_normal((7, 5)).astype(jnp.bfloat16)
    path = str(tmp_path / "bf16.npz")
    save_pytree(path, {"w": arr, "scalar": arr[0, 0]})
    back = load_pytree(path)
    np.testing.assert_array_equal(np.asarray(back["w"]).view(np.uint8),
                                  arr.view(np.uint8))
    assert np.asarray(back["scalar"]).shape == ()


def test_trainer_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((4, 4))}
    opt_state = {"step": jnp.asarray(7), "m": {"w": jnp.zeros((4, 4))}}
    path = str(tmp_path / "trainer.npz")
    save_trainer_state(path, params, opt_state, round_idx=3,
                       extra={"strategy": "bfln", "clusters": 5})
    p, o, r, extra = restore_trainer_state(path)
    assert r == 3
    assert extra == {"strategy": "bfln", "clusters": 5}
    np.testing.assert_array_equal(np.asarray(o["step"]), 7)


# --------------------------------------------------------------------- #
# hardened container: corruption is detected, never mis-parsed
# --------------------------------------------------------------------- #


def test_truncated_file_raises_checkpoint_error(tmp_path):
    path = str(tmp_path / "t.npz")
    save_pytree(path, {"w": jnp.arange(100.0)})
    size = os.path.getsize(path)
    for cut in (size // 2, 10, 3):
        os.truncate(path, cut)
        with pytest.raises(CheckpointError):
            load_pytree(path)
        save_pytree(path, {"w": jnp.arange(100.0)})


def test_bitflip_fails_sha256_check(tmp_path):
    path = str(tmp_path / "b.npz")
    save_pytree(path, {"w": jnp.arange(100.0)})
    size = os.path.getsize(path)
    with open(path, "r+b") as f:          # flip a payload byte
        f.seek(size - 17)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CheckpointError, match="sha256"):
        load_pytree(path)


def test_missing_file_raises_checkpoint_error(tmp_path):
    with pytest.raises(CheckpointError):
        load_pytree(str(tmp_path / "nope.npz"))


def test_not_a_checkpoint_raises(tmp_path):
    path = str(tmp_path / "junk.npz")
    with open(path, "wb") as f:
        f.write(b"hello world, definitely not a checkpoint")
    with pytest.raises(CheckpointError, match="magic"):
        load_pytree(path)


def test_legacy_bare_npz_still_loads(tmp_path):
    # pre-header files are a bare npz payload (zip magic); the reader must
    # keep accepting them
    from repro.checkpoint.io import _encode_payload
    tree = {"w": jnp.arange(6.0), "n": jnp.asarray(3)}
    path = str(tmp_path / "legacy.npz")
    with open(path, "wb") as f:
        f.write(_encode_payload(tree))
    _assert_trees_equal(tree, load_pytree(path))


# --------------------------------------------------------------------- #
# directory management: keep-last-K + corrupt-latest fallback
# --------------------------------------------------------------------- #


def test_keep_last_pruning(tmp_path):
    d = str(tmp_path / "ck")
    for step in (2, 4, 6, 8):
        save_checkpoint(d, step, {"s": jnp.asarray(step)}, keep_last=2)
    assert [s for s, _ in list_checkpoints(d)] == [6, 8]
    step, tree = load_latest(d)
    assert step == 8 and int(tree["s"]) == 8


def test_load_latest_falls_back_over_corrupt_snapshots(tmp_path):
    d = str(tmp_path / "ck")
    for step in (2, 4, 6):
        save_checkpoint(d, step, {"s": jnp.asarray(step)}, keep_last=3)
    os.truncate(os.path.join(d, "ckpt_00000006.npz"), 20)
    step, tree = load_latest(d)
    assert step == 4 and int(tree["s"]) == 4
    # corrupt everything -> clean error naming the directory
    for _, p in list_checkpoints(d):
        os.truncate(p, 5)
    with pytest.raises(CheckpointError, match="unreadable"):
        load_latest(d)


def test_load_latest_empty_dir_raises(tmp_path):
    with pytest.raises(CheckpointError, match="no checkpoints"):
        load_latest(str(tmp_path / "empty"))


# --------------------------------------------------------------------- #
# CheckpointSpec
# --------------------------------------------------------------------- #


def test_checkpoint_spec_validation():
    assert not CheckpointSpec().enabled
    assert CheckpointSpec(interval=5).enabled
    with pytest.raises(ValueError):
        CheckpointSpec(interval=-1)
    with pytest.raises(ValueError):
        CheckpointSpec(interval=1, keep_last=0)


# --------------------------------------------------------------------- #
# full experiment-state capture/restore (the tentpole's data plane)
# --------------------------------------------------------------------- #


def _small_sim(mode="sync", engine=True, seed=3):
    from repro.api import DataSpec, ExperimentSpec, TrainSpec
    from repro.api.spec import AsyncSpec
    from repro.sim import ClientPopulation, SimulatedFederation
    spec = ExperimentSpec(
        data=DataSpec(n_clients=30, n_batches=1, batch_size=16),
        train=TrainSpec(strategy="bfln", rounds=4, sample_frac=0.3,
                        n_clusters=2, local_epochs=1, mode=mode),
        async_=AsyncSpec(buffer_size=4, concurrency=8),
        engine=engine, seed=seed)
    pop = ClientPopulation.from_spec(spec.population_spec())
    return spec, SimulatedFederation(pop, spec)


@pytest.mark.parametrize("engine", [True, False])
def test_capture_restore_sync_state_identity(tmp_path, engine):
    """capture -> save -> load -> restore reproduces every state component
    byte-for-byte on a fresh sim of the same spec."""
    spec, sim = _small_sim(engine=engine)
    for r in range(2):
        sim.history.append(sim._run_sync_round(r))
    tree = capture_experiment_state(sim, 2)
    path = str(tmp_path / "s.npz")
    save_pytree(path, tree)

    _, sim2 = _small_sim(engine=engine)
    next_round, av = restore_experiment_state(sim2, load_pytree(path))
    assert next_round == 2 and av is None
    assert sim2.clock.now == sim.clock.now
    assert sim2.queue._heap == sim.queue._heap
    assert sim2.queue._seq == sim.queue._seq
    assert sim2.event_log == sim.event_log
    assert sim2.rng.bit_generator.state == sim.rng.bit_generator.state
    assert (sim2.pop.latency.rng.bit_generator.state
            == sim.pop.latency.rng.bit_generator.state)
    assert ([b.block_hash() for b in sim2.trainer.chain.blocks]
            == [b.block_hash() for b in sim.trainer.chain.blocks])
    assert sim2.trainer.pool.pending == sim.trainer.pool.pending
    np.testing.assert_array_equal(sim2.trainer.ledger.balances,
                                  sim.trainer.ledger.balances)
    assert sim2.trainer.ledger.minted == sim.trainer.ledger.minted
    assert sim2.trainer._queue == sim.trainer._queue
    np.testing.assert_array_equal(sim2.last_labels, sim.last_labels)
    if engine:
        np.testing.assert_array_equal(np.asarray(sim2.arena.data),
                                      np.asarray(sim.arena.data))
    else:
        _assert_trees_equal(sim2._params, sim._params)


def test_capture_with_empty_txpool_and_fresh_sim(tmp_path):
    # boundary 0-rounds-in: pool empty, chain = genesis only, no history
    spec, sim = _small_sim()
    tree = capture_experiment_state(sim, 0)
    path = str(tmp_path / "z.npz")
    save_pytree(path, tree)
    _, sim2 = _small_sim()
    next_round, av = restore_experiment_state(sim2, load_pytree(path))
    assert next_round == 0
    assert sim2.trainer.pool.pending == []
    assert len(sim2.trainer.chain.blocks) == 1


def test_restore_rejects_different_experiment(tmp_path):
    spec, sim = _small_sim(seed=3)
    path = str(tmp_path / "s.npz")
    save_pytree(path, capture_experiment_state(sim, 0))
    _, other = _small_sim(seed=4)           # different experiment identity
    with pytest.raises(CheckpointError, match="different experiment"):
        restore_experiment_state(other, load_pytree(path))


def test_resume_digest_ignores_obs_checkpoint_faults():
    from dataclasses import replace

    from repro.api import CheckpointSpec as CkSpec
    from repro.api import FaultSpec
    from repro.api.spec import ObsSpec
    spec, _ = _small_sim()
    variants = [
        replace(spec, checkpoint=CkSpec(interval=7, dir="/tmp/x")),
        replace(spec, faults=FaultSpec(crash_round=1)),
        replace(spec, obs=ObsSpec(enabled=True, trace_path="/tmp/t.jsonl")),
    ]
    for v in variants:
        assert v.resume_digest() == spec.resume_digest()
    assert replace(spec, seed=99).resume_digest() != spec.resume_digest()
    # faults DO perturb the trajectory -> config_digest must see them
    assert (replace(spec, faults=FaultSpec(crash_round=1)).config_digest()
            != spec.config_digest())
    # checkpointing must NOT (pure observer)
    assert (replace(spec, checkpoint=CkSpec(interval=7)).config_digest()
            == spec.config_digest())

"""Checkpoint round-trips (params + optimizer + chain metadata)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, restore_trainer_state, save_pytree, save_trainer_state


def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                       "c": [jnp.asarray(1), jnp.asarray([True, False])]}}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    back = load_pytree(path)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert str(np.asarray(x).dtype) == str(np.asarray(y).dtype)
        np.testing.assert_array_equal(np.asarray(x, np.float64),
                                      np.asarray(y, np.float64))


def test_trainer_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((4, 4))}
    opt_state = {"step": jnp.asarray(7), "m": {"w": jnp.zeros((4, 4))}}
    path = str(tmp_path / "trainer.npz")
    save_trainer_state(path, params, opt_state, round_idx=3,
                       extra={"strategy": "bfln", "clusters": 5})
    p, o, r, extra = restore_trainer_state(path)
    assert r == 3
    assert extra == {"strategy": "bfln", "clusters": 5}
    np.testing.assert_array_equal(np.asarray(o["step"]), 7)

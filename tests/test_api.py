"""The declarative experiment API: spec JSON round-trip + config digest,
construction-time validation, the SimConfig deprecation shim (old kwargs →
new nested spec equivalence), the strategy registry, and run() manifests."""
import dataclasses
import json
import warnings

import numpy as np
import pytest

import repro.api as api
from repro.sim import SimConfig, SimulatedFederation


def _spec(**kw):
    defaults = dict(
        data=api.DataSpec(n_clients=40, n_batches=1, batch_size=16,
                          byzantine_frac=0.1),
        train=api.TrainSpec(strategy="fedavg", rounds=2, sample_frac=0.3,
                            n_clusters=3),
        eval=api.EvalSpec(every=1, examples=128),
        seed=7)
    defaults.update(kw)
    return api.ExperimentSpec(**defaults)


# --------------------------------------------------------------------------- #
# spec: JSON round trip + digest
# --------------------------------------------------------------------------- #

def test_spec_json_round_trip():
    for spec in (api.ExperimentSpec(), _spec(),
                 _spec(train=api.TrainSpec(
                     strategy="fedprox", strategy_params={"mu": 0.1},
                     mode="async", hidden=(32, 16)))):
        assert api.ExperimentSpec.from_json(spec.to_json()) == spec
        # dict form is plain JSON types (tuples normalised away)
        assert json.loads(spec.to_json()) == spec.to_dict()


def test_from_dict_rejects_unknown_sections_and_accepts_async_alias():
    spec = _spec()
    d = spec.to_dict()
    d["async"] = d.pop("async_")         # hand-written specs may skip the
    assert api.ExperimentSpec.from_dict(d) == spec   # escaped field name
    d["mesh_"] = {"shards": 8}
    with pytest.raises(ValueError, match="unknown spec section"):
        api.ExperimentSpec.from_dict(d)


def test_run_rejects_mismatched_population():
    from repro.sim import ClientPopulation
    spec = _spec()
    other = dataclasses.replace(spec, seed=spec.seed + 1)
    pop = ClientPopulation.from_spec(other.population_spec())
    with pytest.raises(ValueError, match="different PopulationSpec"):
        api.run(spec, population=pop)


def test_config_digest_stable_and_sensitive():
    a, b = _spec(), _spec()
    assert a.config_digest() == b.config_digest()
    assert len(a.config_digest()) == 64
    c = _spec(seed=8)
    d = _spec(train=api.TrainSpec(strategy="bfln", rounds=2, sample_frac=0.3,
                                  n_clusters=3))
    assert len({a.config_digest(), c.config_digest(),
                d.config_digest()}) == 3


# --------------------------------------------------------------------------- #
# validation at construction (used to fail deep inside the round loop)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("bad", [
    dict(mode="asink"), dict(sampler="random"), dict(strategy="fedsgd"),
    dict(mesh_shards=0), dict(mesh_shards=2, engine=False),
    dict(sample_frac=0.0), dict(sample_frac=1.5), dict(rounds=0),
    dict(local_epochs=0), dict(lr=0.0), dict(eval_every=-1),
    dict(buffer_size=0), dict(staleness_alpha=-0.1),
])
def test_simconfig_rejects_invalid_values(bad):
    with pytest.raises(ValueError):
        SimConfig._internal(**bad)


@pytest.mark.parametrize("build", [
    lambda: api.TrainSpec(mode="asink"),
    lambda: api.TrainSpec(sampler="random"),
    lambda: api.TrainSpec(strategy="fedsgd"),
    lambda: api.TrainSpec(sample_frac=0.0),
    lambda: api.TrainSpec(hidden=()),
    lambda: api.MeshSpec(shards=0),
    lambda: api.DataSpec(byzantine_frac=1.5),
    lambda: api.DataSpec(n_clients=0),
    lambda: api.DataSpec(straggler_slowdown=0.5),
    lambda: api.EvalSpec(every=-1),
    lambda: api.AsyncSpec(buffer_size=0),
    lambda: api.ChainSpec(total_reward=-1.0),
    lambda: api.ExperimentSpec(mesh=api.MeshSpec(shards=2), engine=False),
])
def test_spec_rejects_invalid_values(build):
    with pytest.raises(ValueError):
        build()


# --------------------------------------------------------------------------- #
# SimConfig deprecation shim: old kwargs → new spec equivalence
# --------------------------------------------------------------------------- #

def test_simconfig_warns_and_maps_to_spec():
    old_kwargs = dict(rounds=4, sample_frac=0.25, n_clusters=3, mode="async",
                      buffer_size=8, concurrency=16, eval_every=2,
                      total_reward=10.0, hidden=(32,), mesh_shards=1, seed=3)
    with pytest.warns(DeprecationWarning, match="ExperimentSpec"):
        cfg = SimConfig(**old_kwargs)

    expected = api.ExperimentSpec(
        train=api.TrainSpec(rounds=4, sample_frac=0.25, n_clusters=3,
                            mode="async", hidden=(32,)),
        async_=api.AsyncSpec(buffer_size=8, concurrency=16),
        eval=api.EvalSpec(every=2),
        chain=api.ChainSpec(total_reward=10.0),
        seed=3)
    assert cfg.to_spec() == expected
    # the flat view of the nested spec reproduces the old config exactly
    flat = expected.sim_config()
    assert flat == cfg
    assert dataclasses.asdict(flat) == dataclasses.asdict(cfg)


def test_spec_path_emits_no_deprecation_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        spec = _spec()
        spec.sim_config()
        api.ExperimentSpec.from_flat(rounds=2)


def test_from_flat_matches_nested():
    assert api.ExperimentSpec.from_flat(rounds=3, mode="async",
                                        buffer_size=4, concurrency=8) == \
        api.ExperimentSpec(
            train=api.TrainSpec(rounds=3, mode="async"),
            async_=api.AsyncSpec(buffer_size=4, concurrency=8))


# --------------------------------------------------------------------------- #
# strategy registry
# --------------------------------------------------------------------------- #

def test_registry_lists_all_paper_strategies():
    assert api.strategy_names() == ["bfln", "fedavg", "fedhkd", "fedproto",
                                    "fedprox"]


def test_register_strategy_collision_and_custom():
    from repro.api import registry
    with pytest.raises(ValueError, match="already registered"):
        api.register_strategy("fedavg", lambda bundle, **kw: None)

    def builder(bundle, *, probe=None, n_clusters=0, **params):
        from repro.core.baselines import make_fedavg
        return make_fedavg(bundle)._replace(name="myavg")

    api.register_strategy("myavg", builder)
    try:
        spec = _spec(train=api.TrainSpec(strategy="myavg", rounds=1,
                                         sample_frac=0.3, n_clusters=3))
        res = api.run(spec)
        assert res.manifest["strategy"] == "myavg"
        assert res.report.chain_valid
    finally:
        del registry._REGISTRY["myavg"]


def test_bfln_builder_requires_probe():
    _, bundle = api.make_mlp_bundle(8, 4, hidden=(8,), rep_dim=4)
    with pytest.raises(ValueError, match="probe"):
        api.build_strategy("bfln", bundle, n_clusters=2)


def test_federated_trainer_resolves_strategy_names():
    import jax
    from repro.core import FederatedTrainer
    from repro.models import classifier as clf
    from repro.optim import adam

    data = api.load_packed_clients("synth10", 4, 0.3, n_batches=1,
                                   batch_size=8, psi=8)
    cfg, bundle = api.make_mlp_bundle(data.in_dim, data.num_classes,
                                      hidden=(8,), rep_dim=4)
    tr = FederatedTrainer(bundle, "bfln", adam(1e-3), local_epochs=1,
                          n_clusters=2, probe=data.probe)
    assert tr.strategy.name == "bfln"
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(0), 4)
    p, o = tr.init(sp)
    _, _, rec = tr.run_round(0, p, o, data.cx, data.cy,
                             data.test_x, data.test_y)
    assert tr.chain.validate() and rec.labels is not None

    tr2 = FederatedTrainer(bundle, "fedavg", adam(1e-3), use_chain=False)
    assert tr2.strategy.name == "fedavg"
    with pytest.raises(ValueError, match="n_clusters"):
        FederatedTrainer(bundle, "bfln", adam(1e-3), probe=data.probe)


# --------------------------------------------------------------------------- #
# run(): manifest + determinism + spec-first driver entry
# --------------------------------------------------------------------------- #

def test_run_manifest_carries_config_digest_and_replays():
    spec = _spec()
    a, b = api.run(spec), api.run(spec)
    for res in (a, b):
        m = res.manifest
        assert m["config_digest"] == spec.config_digest()
        assert m["strategy"] == "fedavg"
        assert m["rounds_run"] == len(res.report.history)
        assert m["chain_valid"] and m["ledger_conserved"]
        used = {k: v for k, v in m["engine_compile_counts"].items() if v}
        assert all(v == 1 for v in used.values())
    # same spec ⇒ same digests, bit for bit
    for key in ("event_log_digest", "block_hashes_digest", "balances_digest",
                "final_accuracy"):
        assert a.manifest[key] == b.manifest[key]
    assert spec.train.strategy in a.summary()


def test_driver_accepts_spec_and_flat_config_identically():
    from repro.sim import ClientPopulation
    spec = _spec()
    pop1 = ClientPopulation.from_spec(spec.population_spec())
    pop2 = ClientPopulation.from_spec(spec.population_spec())
    a = SimulatedFederation(pop1, spec)
    b = SimulatedFederation(pop2, spec.sim_config())
    ra, rb = a.run(), b.run()
    assert ra.event_log == rb.event_log
    np.testing.assert_array_equal(ra.balances, rb.balances)
    assert ra.final_accuracy == rb.final_accuracy
    assert a.spec == spec
    # the flat view carries no population sub-spec; everything else maps back
    assert b.spec == dataclasses.replace(spec, data=api.DataSpec())

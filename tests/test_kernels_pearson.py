"""Pearson kernel: shape/dtype sweep vs oracle + mathematical properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("m", [3, 20, 130])
@pytest.mark.parametrize("d", [32, 300, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pearson_matches_oracle(m, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(m * d), (m, d)).astype(dtype)
    got = ops.pearson(x)
    want = ref.pearson_ref(x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol)


def test_pearson_matches_numpy_corrcoef():
    x = jax.random.normal(jax.random.PRNGKey(7), (12, 257))
    got = np.asarray(ops.pearson(x))
    want = np.corrcoef(np.asarray(x))
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 24), d=st.integers(8, 128), seed=st.integers(0, 2**16))
def test_pearson_properties(m, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    corr = np.asarray(ops.pearson(x))
    assert corr.shape == (m, m)
    np.testing.assert_allclose(corr, corr.T, atol=1e-5)       # symmetric
    np.testing.assert_allclose(np.diag(corr), 1.0, atol=1e-4)  # unit diagonal
    assert np.all(corr <= 1.0 + 1e-5) and np.all(corr >= -1.0 - 1e-5)


def test_pearson_detects_correlation_strength():
    """The paper's cosine-vs-Pearson argument: an offset+scaled copy is
    perfectly linearly correlated; an anti-correlated copy is -1."""
    base = jax.random.normal(jax.random.PRNGKey(0), (1, 64))
    x = jnp.concatenate([base, 3.0 * base + 5.0, -base + 2.0], axis=0)
    corr = np.asarray(ops.pearson(x))
    assert corr[0, 1] > 0.999
    assert corr[0, 2] < -0.999

"""Per-assigned-architecture smoke tests (deliverable f).

Each architecture instantiates a REDUCED same-family variant (≤2 pattern
periods, d_model ≤ 512, ≤ 4 experts) and runs one forward + one train step on
CPU, asserting output shapes and the absence of NaNs.  The FULL configs are
exercised only via the dry-run (launch/dryrun.py, ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.lm import make_train_step
from repro.models.transformer import forward, init_params
from repro.optim import sgd

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = jax.random.normal(ks[1], (B, S, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(
            ks[2], (B, cfg.encoder.n_frames, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, hidden, aux = jax.jit(
        lambda p: forward(cfg, p, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          enc_embeds=batch.get("enc_embeds")))(params)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.any(jnp.isnan(hidden)))

    opt = sgd(1e-2)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    loss0, params1, opt_state = step(params, opt_state, batch)
    assert jnp.isfinite(loss0)
    loss1, _, _ = step(params1, opt_state, batch)
    assert jnp.isfinite(loss1)
    assert float(loss1) < float(loss0)  # one SGD step on the same batch helps


def test_full_configs_match_assignment():
    """The registry carries the exact assigned hyper-parameters."""
    spec = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
    }
    for name, (L, D, H, KV, F, V) in spec.items():
        cfg = ARCHS[name]
        assert cfg.n_layers == L and cfg.d_model == D, name
        assert cfg.n_heads == H and cfg.n_kv_heads == KV, name
        assert cfg.d_ff == F and cfg.vocab_size == V, name
        assert cfg.source, name  # provenance recorded


def test_moe_configs():
    l4 = ARCHS["llama4-maverick-400b-a17b"]
    assert l4.n_experts == 128 and l4.moe_top_k == 1 and l4.moe_shared_expert
    gk = ARCHS["grok-1-314b"]
    assert gk.n_experts == 8 and gk.moe_top_k == 2
    jb = ARCHS["jamba-1.5-large-398b"]
    assert jb.n_experts == 16 and jb.moe_top_k == 2
    # jamba interleave: exactly 1 attn per 8 layers, MoE on every other layer
    assert sum(s.mixer == "attn" for s in jb.pattern) == 1
    assert sum(s.moe for s in jb.pattern) == 4

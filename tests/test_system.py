"""End-to-end system behaviour: the full BFLN protocol (Fig. 1 steps 1–6)
against the paper's qualitative claims, plus LM-substrate integration."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # multi-round e2e training runs

from repro.core import FederatedTrainer, ModelBundle, make_bfln, make_fedavg
from repro.core.fl import evaluate
from repro.data import dirichlet_partition, make_classification_dataset, pack_clients
from repro.data.partition import sample_probe_batch
from repro.models import classifier as clf
from repro.optim import adam


def _run(strategy_name, rounds=6, m=10, n_clusters=3, seed=0):
    (xt, yt), (xe, ye) = make_classification_dataset("synth10", seed=seed)
    parts = dirichlet_partition(yt, m, 0.1, seed=seed)
    cx, cy, tx, ty = pack_clients(xt, yt, parts, n_batches=4, batch_size=32,
                                  seed=seed)
    probe = jnp.asarray(sample_probe_batch(xt, yt, category=0, psi=16, seed=seed))
    cfg = clf.MLPConfig(in_dim=64, hidden=(64,), rep_dim=32, num_classes=10)
    bundle = ModelBundle(functools.partial(clf.apply, cfg),
                         functools.partial(clf.embed, cfg), 10)
    sp = clf.init_stacked(cfg, jax.random.PRNGKey(seed), m)
    if strategy_name == "bfln":
        strat = make_bfln(bundle, probe, n_clusters)
        tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=3,
                              n_clusters=n_clusters)
    else:
        strat = make_fedavg(bundle)
        tr = FederatedTrainer(bundle, strat, adam(1e-3), local_epochs=3,
                              use_chain=False)
    p, o = tr.init(sp)
    for r in range(rounds):
        p, o, _ = tr.run_round(r, p, o, jnp.asarray(cx), jnp.asarray(cy),
                               jnp.asarray(xe), jnp.asarray(ye))
    # personalized accuracy on each client's own local test distribution
    pacc = float(jnp.mean(evaluate(bundle.apply_fn, p, jnp.asarray(tx),
                                   jnp.asarray(ty))))
    return tr, pacc


def test_bfln_beats_fedavg_on_skewed_data():
    """Table II's headline claim, at smoke scale: under label skew (β=0.1),
    clustered aggregation beats the single global model on personalized
    accuracy."""
    _, bfln_acc = _run("bfln")
    _, fedavg_acc = _run("fedavg")
    assert bfln_acc > fedavg_acc - 0.02   # never worse; usually better
    assert bfln_acc > 0.5


def test_rewards_track_cluster_size():
    """Fig. 2's claim: clients in larger clusters accumulate more tokens."""
    tr, _ = _run("bfln", rounds=5)
    last = tr.history[-1]
    sizes_per_client = last.cluster_sizes[last.labels]
    r = np.asarray(last.rewards)
    big, small = sizes_per_client.max(), sizes_per_client.min()
    if big > small:
        assert r[sizes_per_client == big].mean() > r[sizes_per_client == small].mean()


def test_chain_and_ledger_invariants_over_training():
    tr, _ = _run("bfln", rounds=4)
    assert tr.chain.validate()
    assert tr.ledger.conserved()
    assert len(tr.chain.blocks) == 5  # genesis + 4 rounds
    # every block carries the clients' model-hash txs + the producer's
    # sender-bound aggregation commitment (one entry per arrived client)
    from repro.blockchain import AGG_COMMIT_KIND, RoundCommitments
    for block in tr.chain.blocks[1:]:
        kinds = [t.kind for t in block.transactions]
        assert kinds.count(AGG_COMMIT_KIND) == 1
        assert kinds.count("model_hash") == 10
        agg = next(t for t in block.transactions if t.kind == AGG_COMMIT_KIND)
        commits = RoundCommitments.from_payload(block.round_idx, agg.payload)
        assert len(commits.entries) == 10


def test_lm_substrate_learns_token_stream():
    """The big-model substrate trains: tiny LM on the synthetic Markov
    stream, loss must drop markedly within ~40 steps."""
    from repro.configs import ARCHS
    from repro.data.lm import batch_stream, make_token_stream
    from repro.models.lm import make_train_step
    from repro.models.transformer import init_params
    from repro.optim import adamw

    cfg = ARCHS["h2o-danube-3-4b"].reduced(
        n_layers=2, d_model=128, d_ff=256, vocab_size=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    toks = make_token_stream(cfg.vocab_size, 30000, seed=0)
    losses = []
    for x, y in batch_stream(toks, batch=8, seq_len=32, n_steps=40, seed=0):
        batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
        loss, params, opt_state = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])

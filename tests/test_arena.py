"""Parameter arena: exact flatten/unflatten round-trips, canonical layout
parity with the fingerprint path, masked scatter semantics, and bit-identity
of arena-routed cluster aggregation against the kernels/ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import cluster_mean_params, cluster_mean_rows
from repro.kernels import ops
from repro.kernels.fingerprint import (
    cohort_digests,
    fingerprint_rows,
    format_digest,
    poly_weights,
    stack_flatten_u32,
)
from repro.kernels.cluster_agg import mixing_matrix
from repro.kernels.ref import cluster_agg_ref, fingerprint_ref
from repro.runtime.arena import ArenaLayout, ParamArena, bitcast_u32
from repro.utils.tree import tree_stack


def _stacked(m=6, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), m)
    return tree_stack([
        {"w0": jax.random.normal(k, (5, 3)),
         "nested": {"b": jax.random.normal(k, (4,)),
                    "w10": jax.random.normal(k, (2, 2, 2))},
         "b_head": jax.random.normal(k, (7,))} for k in ks])


def test_flatten_unflatten_roundtrip_exact():
    sp = _stacked()
    layout = ArenaLayout.from_stacked(sp)
    flat = layout.flatten(sp)
    assert flat.shape == (6, layout.n_params)
    back = layout.unflatten(flat)
    assert jax.tree.structure(back) == jax.tree.structure(sp)
    for a, b in zip(jax.tree.leaves(sp), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype and a.shape == b.shape


def test_layout_order_matches_fingerprint_flatten():
    """The arena's canonical (path-sorted) order IS the fingerprint order:
    bitcast arena rows == stack_flatten_u32, for any dict insertion order."""
    a = jnp.asarray([[1.5, -2.25]])
    b = jnp.asarray([[3.0]])
    for tree in ({"x": a, "y": b}, {"y": b, "x": a}):
        layout = ArenaLayout.from_stacked(tree)
        np.testing.assert_array_equal(
            np.asarray(bitcast_u32(layout.flatten(tree))),
            np.asarray(stack_flatten_u32(tree)))
        np.testing.assert_array_equal(
            np.asarray(layout.flatten_u32(tree)),
            np.asarray(stack_flatten_u32(tree)))


def test_arena_digests_bit_identical_to_pre_arena_oracle():
    """Digesting arena rows == the pre-arena cohort_digests pipeline."""
    sp = _stacked(m=5, seed=3)
    arena = ParamArena.from_stacked(sp)
    res = fingerprint_rows(bitcast_u32(arena.data), use_pallas=False)
    got = [format_digest(r, arena.n_params) for r in np.asarray(res)]
    assert got == cohort_digests(sp)
    # and against the raw ref oracle on the independent flattening
    flat = stack_flatten_u32(sp)
    ref = fingerprint_ref(flat, jnp.asarray(poly_weights(flat.shape[1])))
    np.testing.assert_array_equal(np.asarray(res), np.asarray(ref))


def test_gather_masked_scatter_semantics():
    sp = _stacked(m=8, seed=1)
    arena = ParamArena.from_stacked(sp)
    before = np.asarray(arena.data).copy()
    cohort = np.array([1, 4, 6])
    mask = np.array([True, False, True])
    rows = jnp.ones((3, arena.n_params), jnp.float32) * 42.0
    arena.masked_scatter(cohort, mask, rows)
    after = np.asarray(arena.data)
    np.testing.assert_array_equal(after[1], 42.0)      # arrived: adopted
    np.testing.assert_array_equal(after[6], 42.0)
    np.testing.assert_array_equal(after[4], before[4])  # masked out: kept
    untouched = np.setdiff1d(np.arange(8), cohort)
    np.testing.assert_array_equal(after[untouched], before[untouched])
    np.testing.assert_array_equal(np.asarray(arena.gather([1, 4])),
                                  after[[1, 4]])


def test_row_pytree_matches_tree_index():
    sp = _stacked(m=4, seed=2)
    arena = ParamArena.from_stacked(sp)
    row = arena.row_pytree(2)
    for a, b in zip(jax.tree.leaves(row),
                    jax.tree.leaves(jax.tree.map(lambda x: x[2], sp))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cluster_mean_rows_bit_identical_to_tree_two_step():
    """Flat-row aggregation == the per-leaf two_step collective, bit for bit
    at this size (same sums; very large cohorts may block the contraction
    differently, which is why the engine keeps the per-leaf form)."""
    sp = _stacked(m=9, seed=5)
    layout = ArenaLayout.from_stacked(sp)
    labels = jnp.asarray([0, 1, 2, 0, 1, 2, 0, 0, 1])
    w = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)
    flat_out = cluster_mean_rows(layout.flatten(sp), labels, 3, weights=w)
    tree_out = cluster_mean_params(sp, labels, 3, weights=w, method="two_step")
    np.testing.assert_array_equal(
        np.asarray(flat_out).view(np.uint32),
        np.asarray(layout.flatten(tree_out)).view(np.uint32))


def test_cluster_agg_kernel_via_layout_matches_ref_oracle():
    """Routing the Pallas cluster-agg kernel through the arena layout is
    bit-identical to the pre-arena cluster_agg_ref oracle."""
    sp = _stacked(m=7, seed=6)
    layout = ArenaLayout.from_stacked(sp)
    flat = layout.flatten(sp)
    labels = jnp.asarray([0, 0, 1, 2, 1, 2, 0])
    got = ops.cluster_aggregate(flat, labels, 3)
    ref = cluster_agg_ref(flat, mixing_matrix(labels, 3))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

"""Fault-injection harness (`repro.faults`) + graceful degradation.

Contracts under test:

* the all-off default binds ``NULL_INJECTOR`` and leaves seeded replay
  bit-identical to a run without the harness,
* every injected fault fires deterministically, degrades gracefully
  (failover / quarantine-and-continue / forfeit), keeps the chain valid and
  the ledger conserved, and surfaces as a schema-valid ``fault.*`` trace
  record,
* the injector's own RNG stream checkpoints and restores exactly.
"""
import json
from dataclasses import replace

import pytest

from repro.api import DataSpec, ExperimentSpec, FaultSpec, TrainSpec, run
from repro.api.spec import ObsSpec
from repro.faults import (
    CRASH_PHASES,
    FaultInjector,
    InjectedCrash,
    NULL_INJECTOR,
)


def _spec(**kw):
    faults = kw.pop("faults", FaultSpec())
    obs = kw.pop("obs", ObsSpec())
    engine = kw.pop("engine", True)
    defaults = dict(strategy="bfln", rounds=5, sample_frac=0.3,
                    n_clusters=2, local_epochs=1)
    defaults.update(kw)
    return ExperimentSpec(
        data=DataSpec(n_clients=40, n_batches=1, batch_size=16),
        train=TrainSpec(**defaults), obs=obs, faults=faults,
        engine=engine, seed=3)


def _digests(m):
    return {k: m[k] for k in ("event_log_digest", "block_hashes_digest",
                              "balances_digest", "final_accuracy")}


# --------------------------------------------------------------------- #
# spec + injector unit behaviour
# --------------------------------------------------------------------- #


def test_fault_spec_validation():
    assert not FaultSpec().enabled
    assert FaultSpec(crash_round=3).enabled
    assert FaultSpec(retry=True).enabled
    with pytest.raises(ValueError):
        FaultSpec(crash_phase="mid_air")
    with pytest.raises(ValueError):
        FaultSpec(crash_mode="segfault")
    with pytest.raises(ValueError):
        FaultSpec(producer_fail_rounds=(1, -2))
    with pytest.raises(ValueError):
        FaultSpec(retry=True, retry_max=0)


def test_null_injector_is_inert():
    assert not NULL_INJECTOR.enabled
    assert NULL_INJECTOR.commit_drop_slot(0, 5) == -1
    assert NULL_INJECTOR.release_commits() == []
    NULL_INJECTOR.maybe_crash(0, "round_start")        # no-op
    NULL_INJECTOR.corrupt_checkpoint("/nonexistent", 0)


def test_injector_crash_fires_once_per_schedule():
    inj = FaultInjector(FaultSpec(crash_round=2, crash_phase="pre_chain",
                                  crash_mode="exception"))
    inj.maybe_crash(1, "pre_chain")                    # wrong round
    inj.maybe_crash(2, "round_start")                  # wrong phase
    with pytest.raises(InjectedCrash):
        inj.maybe_crash(2, "pre_chain")
    inj.maybe_crash(2, "pre_chain")                    # already crashed: inert
    assert set(CRASH_PHASES) == {"round_start", "pre_chain",
                                 "post_checkpoint"}


def test_injector_rng_state_roundtrip():
    """The injector's stream resumes exactly: a save/restore at any point
    yields the same subsequent draws as never pausing."""
    spec = FaultSpec(drop_commit_rounds=(0, 1, 2, 3), retry=True, seed=7)
    a = FaultInjector(spec)
    a.commit_drop_slot(0, 9)
    a.retry_latency(10.0, 1)
    state = a.state_dict()
    b = FaultInjector(spec)                 # fresh injector, restored stream
    b.load_state(state)
    assert a.commit_drop_slot(1, 9) == b.commit_drop_slot(1, 9)
    assert a.retry_succeeds(0.5) == b.retry_succeeds(0.5)
    assert a.retry_latency(10.0, 2) == b.retry_latency(10.0, 2)


# --------------------------------------------------------------------- #
# faults fully off == bit-identical to an unconfigured run
# --------------------------------------------------------------------- #


def test_default_spec_binds_null_injector_and_matches_plain_run():
    plain = run(_spec())
    from repro.sim import ClientPopulation, SimulatedFederation
    spec = _spec()
    sim = SimulatedFederation(
        ClientPopulation.from_spec(spec.population_spec()), spec)
    assert sim.faults is NULL_INJECTOR
    assert sim.trainer.faults is NULL_INJECTOR
    again = run(_spec())
    assert _digests(again.manifest) == _digests(plain.manifest)


# --------------------------------------------------------------------- #
# degradation paths, end to end
# --------------------------------------------------------------------- #


def test_producer_failover_keeps_chain_valid():
    faulted = run(_spec(faults=FaultSpec(producer_fail_rounds=(1, 2))))
    plain = run(_spec())
    assert faulted.manifest["chain_valid"]
    assert faulted.manifest["ledger_conserved"]
    # failover changed at least one block's producer
    assert (faulted.manifest["block_hashes_digest"]
            != plain.manifest["block_hashes_digest"])


def test_bad_block_is_quarantined_and_round_continues():
    from repro.sim import ClientPopulation, SimulatedFederation
    spec = _spec(faults=FaultSpec(bad_block_rounds=(1,)))
    sim = SimulatedFederation(
        ClientPopulation.from_spec(spec.population_spec()), spec)
    report = sim.run()
    chain = sim.trainer.chain
    assert len(chain.quarantined) == 1
    assert chain.quarantined[0].round_idx == 1
    assert not chain.block_ok(chain.quarantined[0])
    assert report.chain_valid                  # honest re-pack went on-chain
    # the honest block carries the SAME txs the bad candidate held
    honest = next(b for b in chain.blocks if b.round_idx == 1)
    assert honest.transactions == chain.quarantined[0].transactions
    # quarantine does not perturb the chain content vs the faultless run
    plain = run(_spec())
    assert ([b.block_hash() for b in chain.blocks]
            == _chain_hashes_of(plain))


def _chain_hashes_of(result):
    # reconstruct the faultless chain hashes via a fresh manifest-level run
    from repro.sim import ClientPopulation, SimulatedFederation
    spec = _spec()
    sim = SimulatedFederation(
        ClientPopulation.from_spec(spec.population_spec()), spec)
    sim.run()
    return [b.block_hash() for b in sim.trainer.chain.blocks]


def test_dropped_commit_forfeits_reward():
    """The victim's update is aggregated but its commit never reaches the
    pool -> it fails verification and earns nothing that round."""
    from repro.sim import ClientPopulation, SimulatedFederation
    spec = _spec(faults=FaultSpec(drop_commit_rounds=(1,), seed=5))
    sim = SimulatedFederation(
        ClientPopulation.from_spec(spec.population_spec()), spec)
    report = sim.run()
    rec = next(r for r in report.history if r.round_idx == 1)
    assert rec.verified_frac < 1.0
    assert report.chain_valid and report.ledger_conserved


def test_delayed_commit_lands_late_and_carries_no_weight():
    from repro.sim import ClientPopulation, SimulatedFederation
    spec = _spec(faults=FaultSpec(delay_commit_rounds=(1,), seed=5))
    sim = SimulatedFederation(
        ClientPopulation.from_spec(spec.population_spec()), spec)
    report = sim.run()
    chain = sim.trainer.chain
    late = [(b.round_idx, tx) for b in chain.blocks for tx in b.transactions
            if tx.kind == "model_hash" and tx.round_idx != b.round_idx]
    assert late, "the held commit never got delivered into a later block"
    for block_round, tx in late:
        assert tx.round_idx == 1 and block_round > 1
    # verification ignored the stray tx: the late block's own cohort is
    # unaffected, the chain stays valid, rewards conserved
    assert report.chain_valid and report.ledger_conserved
    rec = next(r for r in report.history if r.round_idx == 1)
    assert rec.verified_frac < 1.0             # the victim forfeited round 1


def test_retry_recovers_some_dropouts():
    """With retry on, dropped cohort slots get bounded re-attempts through
    the injector's own stream; recovered clients arrive and the round
    machinery stays consistent."""
    spec = _spec(rounds=8,
                 faults=FaultSpec(retry=True, retry_max=3, seed=11),
                 obs=ObsSpec(enabled=True,
                             trace_path="/tmp/retry_trace.jsonl"))
    # raise dropout so retries actually trigger
    spec = replace(spec, data=replace(spec.data, dropout_rate=0.5))
    result = run(spec)
    assert result.manifest["chain_valid"]
    recs = [json.loads(l) for l in open("/tmp/retry_trace.jsonl")]
    retries = [r for r in recs if r.get("name") == "round.retry"
               and r.get("kind") == "span"]
    assert retries, "no retry spans emitted despite 50% dropout"
    counters = {r["name"]: r["value"] for r in recs
                if r.get("kind") == "counter"}
    assert counters.get("fault.retry", 0) >= len(retries)


def test_faulted_run_is_itself_replayable():
    spec = _spec(faults=FaultSpec(producer_fail_rounds=(1,),
                                  drop_commit_rounds=(2,),
                                  bad_block_rounds=(3,), seed=13))
    a, b = run(spec), run(spec)
    assert _digests(a.manifest) == _digests(b.manifest)


# --------------------------------------------------------------------- #
# every injected fault appears as a schema-valid fault.* trace record
# --------------------------------------------------------------------- #


def test_fault_records_validate_against_trace_schema(tmp_path):
    from repro.obs import validate_record
    trace = str(tmp_path / "faults.jsonl")
    spec = _spec(rounds=6,
                 faults=FaultSpec(producer_fail_rounds=(1, 3),
                                  bad_block_rounds=(2,),
                                  drop_commit_rounds=(1,),
                                  delay_commit_rounds=(2,), seed=9),
                 obs=ObsSpec(enabled=True, trace_path=trace))
    result = run(spec)
    assert result.manifest["chain_valid"]
    recs = [json.loads(l) for l in open(trace)]
    fault_names = set()
    for rec in recs:
        name = str(rec.get("name", ""))
        if name.startswith("fault."):
            validate_record(rec)               # raises on schema violation
            fault_names.add(name)
    for want in ("fault.producer_fail", "fault.producer_failover",
                 "fault.block_quarantined", "fault.commit_dropped",
                 "fault.commit_delayed", "fault.commit_delivered_late"):
        assert want in fault_names, f"missing trace record {want}"


def test_crash_event_is_recorded_before_dying(tmp_path):
    trace = str(tmp_path / "crash.jsonl")
    spec = _spec(faults=FaultSpec(crash_round=2, crash_phase="round_start",
                                  crash_mode="exception"),
                 obs=ObsSpec(enabled=True, trace_path=trace))
    with pytest.raises(InjectedCrash):
        run(spec)
    # the recorder never flushed (the run died), but the injector emitted
    # the event through the live recorder — verify via a fresh injector
    from repro.obs import FlightRecorder
    from repro.obs.spec import ObsSpec as OS
    obs = FlightRecorder(OS(enabled=True, trace_path=trace))
    inj = FaultInjector(FaultSpec(crash_round=0, crash_phase="round_start",
                                  crash_mode="exception"), obs=obs)
    with pytest.raises(InjectedCrash):
        inj.maybe_crash(0, "round_start")
    kinds = [r.get("name") for r in obs.records]
    assert "fault.crash" in kinds

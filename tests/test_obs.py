"""Unit tests for `repro.obs` — the flight recorder subsystem.

Covers the streaming metrics (deterministic RNG-free reservoir thinning),
the span tracer (wall + virtual clocks, compile-delta events), the JSONL
schema validator, and the sinks (digest-stable JSONL, Chrome trace export,
console summary).  End-to-end replay invariance lives in
``tests/test_obs_invariance.py``.
"""
import json

import pytest

from repro.obs import (
    NULL_RECORDER,
    FlightRecorder,
    MetricsRegistry,
    ObsSpec,
    Summary,
    console_summary,
    file_sha256,
    validate_record,
    validate_trace_lines,
    write_chrome_trace,
    write_jsonl,
)


# --------------------------------------------------------------------------- #
# ObsSpec
# --------------------------------------------------------------------------- #

def test_obs_spec_defaults_off():
    spec = ObsSpec()
    assert not spec.enabled
    assert spec.trace_path


@pytest.mark.parametrize("bad", [
    dict(trace_path=""),
    dict(sample_cap=4),
    dict(chrome_path=""),
    dict(profile_dir=""),
])
def test_obs_spec_validates(bad):
    with pytest.raises(ValueError):
        ObsSpec(enabled=True, **bad)


# --------------------------------------------------------------------------- #
# Summary / MetricsRegistry
# --------------------------------------------------------------------------- #

def test_summary_exact_aggregates():
    s = Summary(cap=64)
    for v in [3.0, 1.0, 2.0]:
        s.observe(v)
    snap = s.snapshot()
    assert snap["count"] == 3
    assert snap["sum"] == 6.0
    assert snap["mean"] == 2.0
    assert snap["min"] == 1.0 and snap["max"] == 3.0
    assert snap["p50"] == 2.0


def test_summary_thinning_is_bounded_and_deterministic():
    a, b = Summary(cap=32), Summary(cap=32)
    for i in range(10_000):
        a.observe(float(i))
        b.observe(float(i))
    assert len(a._samples) < 32
    assert a.snapshot() == b.snapshot()        # no RNG anywhere
    assert a.count == 10_000
    assert a.min == 0.0 and a.max == 9999.0
    # the systematic reservoir still spans the stream
    assert a.quantile(0.5) == pytest.approx(5000, rel=0.1)


def test_registry_counters_gauges_summaries():
    m = MetricsRegistry(sample_cap=64)
    m.inc("blocks")
    m.inc("blocks", 2.0)
    m.set_gauge("bytes", 7.0)
    m.set_gauge("bytes", 9.0)
    m.observe("lat", 5.0)
    snap = m.snapshot()
    assert snap["counters"]["blocks"] == 3.0
    assert snap["gauges"]["bytes"] == 9.0
    assert snap["summaries"]["lat"]["count"] == 1


# --------------------------------------------------------------------------- #
# FlightRecorder / NullRecorder
# --------------------------------------------------------------------------- #

def test_span_records_wall_and_virtual_time():
    vt = [10.0]
    rec = FlightRecorder(ObsSpec(enabled=True), clock=lambda: vt[0])
    with rec.span("round.total", round=3) as sp:
        vt[0] = 12.5
        sp.set(arrived=8)
    (r,) = rec.records
    assert r["kind"] == "span" and r["name"] == "round.total"
    assert r["round"] == 3
    assert r["dur_us"] >= 0
    assert r["vt"] == 12.5
    assert r["attrs"]["vt_dur"] == 2.5
    assert r["attrs"]["arrived"] == 8
    # the span also feeds the ms summary under its own name
    assert rec.metrics.summaries["round.total"].count == 1


def test_compile_delta_emits_events_once_per_growth():
    rec = FlightRecorder(ObsSpec(enabled=True))
    rec.compile_delta({"sync_step": 1, "eval": 0}, round_idx=0)
    rec.compile_delta({"sync_step": 1, "eval": 1}, round_idx=1)
    rec.compile_delta({"sync_step": 1, "eval": 1}, round_idx=2)
    events = [r for r in rec.records if r["kind"] == "event"]
    assert [(e["attrs"]["entry"], e["round"]) for e in events] == \
        [("sync_step", 0), ("eval", 1)]
    assert rec.metrics.counters["compiles"] == 2


def test_ready_returns_value_unchanged():
    rec = FlightRecorder(ObsSpec(enabled=True, block_until_ready=True))
    assert rec.ready(41) == 41
    assert NULL_RECORDER.ready("x") == "x"


def test_null_recorder_is_inert():
    with NULL_RECORDER.span("anything", round=1) as sp:
        sp.set(a=1)
    NULL_RECORDER.event("e")
    NULL_RECORDER.point("p", 1.0)
    NULL_RECORDER.inc("c")
    NULL_RECORDER.set_gauge("g", 2.0)
    NULL_RECORDER.observe("o", 3.0)
    NULL_RECORDER.compile_delta({"x": 5})
    assert not NULL_RECORDER.enabled


def test_timing_summary_reads_round_metrics():
    rec = FlightRecorder(ObsSpec(enabled=True))
    for ms in (10.0, 12.0, 11.0):
        rec.metrics.observe("round.total", ms)
        rec.metrics.observe("round.chain", ms / 10)
    rec.inc("compiles", 4)
    t = rec.timing_summary()
    assert t["rounds"] == 3
    assert t["compiles"] == 4
    assert t["round_ms_p50"] == 11.0
    assert t["chain_overhead_pct"] == 10.0


# --------------------------------------------------------------------------- #
# schema
# --------------------------------------------------------------------------- #

def test_validate_record_accepts_each_kind():
    for rec in [
        {"kind": "meta", "schema": 1},
        {"kind": "span", "name": "a", "cat": "round", "round": 1,
         "ts_us": 0.0, "dur_us": 1.0, "vt": None},
        {"kind": "event", "name": "compile", "round": None, "ts_us": 2.0},
        {"kind": "point", "name": "p", "round": 0, "value": 1.5},
        {"kind": "summary", "name": "s", "count": 1, "sum": 1.0, "mean": 1.0,
         "min": 1.0, "max": 1.0, "p50": 1.0, "p90": 1.0, "p99": 1.0},
        {"kind": "counter", "name": "c", "value": 2.0},
        {"kind": "gauge", "name": "g", "value": 3.0},
    ]:
        validate_record(rec)


@pytest.mark.parametrize("bad", [
    {"name": "missing-kind"},
    {"kind": "nope"},
    {"kind": "span", "name": "a"},                       # missing fields
    {"kind": "counter", "name": "c", "value": "high"},   # non-numeric
    {"kind": "counter", "name": "c", "value": True},     # bool is not a number
    {"kind": "point", "name": 7, "round": 0, "value": 1.0},
])
def test_validate_record_rejects(bad):
    with pytest.raises(ValueError):
        validate_record(bad)


def test_validate_trace_lines_requires_meta_header():
    meta = json.dumps({"kind": "meta", "schema": 1})
    span = json.dumps({"kind": "span", "name": "a", "cat": "c", "round": None,
                       "ts_us": 0.0, "dur_us": 1.0, "vt": None})
    counts = validate_trace_lines([meta, span])
    assert counts == {"meta": 1, "span": 1}
    with pytest.raises(ValueError):
        validate_trace_lines([span, meta])               # meta must come first
    with pytest.raises(ValueError):
        validate_trace_lines([meta, meta])               # exactly one meta


# --------------------------------------------------------------------------- #
# sinks
# --------------------------------------------------------------------------- #

def _recorder_with_traffic() -> FlightRecorder:
    rec = FlightRecorder(ObsSpec(enabled=True))
    with rec.span("round.total", round=0):
        with rec.span("chain.pack", cat="chain", round=0) as sp:
            sp.set(n_tx=3)
    rec.event("compile", round=0, entry="sync_step", n=1)
    rec.inc("chain.blocks")
    rec.set_gauge("arena.bytes", 1024.0)
    return rec


def test_write_jsonl_digest_matches_file_and_schema(tmp_path):
    rec = _recorder_with_traffic()
    path = str(tmp_path / "t.jsonl")
    digest = write_jsonl(path, {"seed": 0}, rec.records, rec.metrics)
    assert digest == file_sha256(path)
    lines = open(path).read().splitlines()
    counts = validate_trace_lines(lines)
    assert counts["span"] == 2 and counts["meta"] == 1
    # byte-determinism: same records -> same file -> same digest
    path2 = str(tmp_path / "t2.jsonl")
    assert write_jsonl(path2, {"seed": 0}, rec.records, rec.metrics) == digest


def test_chrome_trace_export(tmp_path):
    rec = _recorder_with_traffic()
    path = str(tmp_path / "chrome.json")
    n = write_chrome_trace(path, rec.records)
    doc = json.load(open(path))
    events = doc["traceEvents"]
    assert n == len(events) == 3                         # 2 spans + 1 instant
    spans = [e for e in events if e["ph"] == "X"]
    assert {e["cat"] for e in spans} == {"round", "chain"}
    # one track per category
    assert len({e["tid"] for e in spans}) == 2
    (instant,) = [e for e in events if e["ph"] == "i"]
    assert instant["name"] == "compile"


def test_console_summary_mentions_phases_and_counters():
    rec = _recorder_with_traffic()
    text = console_summary(rec.metrics, title="t")
    assert "round.total" in text and "chain.pack" in text
    assert "chain.blocks=1" in text
    assert "arena.bytes=1024" in text
    assert "100.0%" in text                              # round.total share


# --------------------------------------------------------------------------- #
# spec integration
# --------------------------------------------------------------------------- #

def test_experiment_spec_obs_roundtrip_and_digest_exclusion():
    import repro.api as api
    on = api.ExperimentSpec(obs=api.ObsSpec(enabled=True,
                                            trace_path="x.jsonl"))
    off = api.ExperimentSpec()
    # observability is out-of-band: traced and untraced runs share the
    # replay recipe, so the config digest must ignore the obs section
    assert on.config_digest() == off.config_digest()
    back = api.ExperimentSpec.from_json(on.to_json())
    assert back.obs == on.obs
    assert back == on

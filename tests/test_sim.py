"""Event-driven federation simulator: determinism, sampled-cohort reward
conservation, straggler/dropout/Byzantine handling, async staleness weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim import (
    BufferedAggregator,
    BufferedUpdate,
    ClientPopulation,
    PopulationSpec,
    SamplerState,
    SimConfig,
    SimulatedFederation,
    get_sampler,
    staleness_weight,
    weighted_delta_mean,
)
from repro.utils.tree import tree_stack


def _small_pop(n=60, seed=0, **kw):
    defaults = dict(n_clients=n, dataset="synth10", beta=0.3, n_batches=1,
                    batch_size=16, straggler_frac=0.1, straggler_slowdown=8.0,
                    dropout_rate=0.05, byzantine_frac=0.0, seed=seed)
    defaults.update(kw)
    return ClientPopulation.from_spec(PopulationSpec(**defaults))


def _run(pop, seed=0, **kw):
    defaults = dict(rounds=3, sample_frac=0.25, n_clusters=3, eval_every=0,
                    seed=seed)
    defaults.update(kw)
    sim = SimulatedFederation(pop, SimConfig(**defaults))
    return sim, sim.run()


# --------------------------------------------------------------------------- #
# determinism
# --------------------------------------------------------------------------- #

@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_fixed_seed_replays_identically(mode):
    kw = dict(mode=mode, buffer_size=6, concurrency=12)
    _, a = _run(_small_pop(seed=3), seed=3, **kw)
    _, b = _run(_small_pop(seed=3), seed=3, **kw)
    _, c = _run(_small_pop(seed=4), seed=4, **kw)
    assert a.event_log == b.event_log
    assert len(a.event_log) > 0
    np.testing.assert_array_equal(a.balances, b.balances)
    assert a.final_accuracy == b.final_accuracy
    assert a.event_log != c.event_log       # seed actually matters


# --------------------------------------------------------------------------- #
# sampled-cohort reward conservation
# --------------------------------------------------------------------------- #

def test_sampled_cohort_reward_conservation():
    pop = _small_pop(byzantine_frac=0.1)
    sim, rep = _run(pop, rounds=4)
    total = sim.cfg.total_reward
    for rec in rep.history:
        if rec.arrived.any():
            # the full pool splits exactly into paid + burned
            np.testing.assert_allclose(rec.reward_paid + rec.reward_burned,
                                       total, rtol=1e-5)
    assert rep.ledger_conserved
    assert rep.chain_valid


def test_non_cohort_balances_untouched():
    pop = _small_pop(dropout_rate=0.0, byzantine_frac=0.0)
    sim, rep = _run(pop, rounds=1)
    rec = rep.history[0]
    touched = set(int(g) for g in rec.cohort) | {rec.producer}
    stake = sim.cfg.initial_stake
    for cid in range(pop.n_clients):
        if cid not in touched:
            assert rep.balances[cid] == stake, cid


# --------------------------------------------------------------------------- #
# stragglers / dropouts / Byzantine clients
# --------------------------------------------------------------------------- #

def test_permanent_straggler_never_settles():
    pop = _small_pop(n=30, straggler_frac=0.0, dropout_rate=0.0)
    pop.availability[:] = 1.0
    pop.latency.speed[7] = 1e9          # never beats any deadline
    sim, rep = _run(pop, rounds=3, sample_frac=1.0)
    for rec in rep.history:
        slot = int(np.flatnonzero(rec.cohort == 7)[0])
        assert not rec.arrived[slot]
        assert rec.n_stragglers >= 1
    assert rep.balances[7] == sim.cfg.initial_stake
    assert rep.ledger_conserved


def test_byzantine_client_rejected_end_to_end():
    pop = _small_pop(n=30, straggler_frac=0.0, dropout_rate=0.0)
    pop.availability[:] = 1.0
    pop.byzantine[5] = True
    sim, rep = _run(pop, rounds=3, sample_frac=1.0, deadline=1e6)
    for rec in rep.history:
        assert rec.n_byzantine == 1
        assert rec.verified_frac < 1.0
        assert rec.reward_burned > 0.0
    # the freerider never earns a training reward — at most the tiny
    # aggregation fees for rounds where CACC elected it producer; honest
    # clients settle their full rewards
    per_round_fee_bound = sim.cfg.total_reward / pop.n_clients
    gain = rep.balances[5] - sim.cfg.initial_stake
    assert gain < len(rep.history) * per_round_fee_bound
    honest = np.delete(rep.balances, 5)
    assert honest.max() > sim.cfg.initial_stake + 1.0
    assert rep.balances[5] < honest.mean()
    assert rep.ledger_conserved and rep.chain_valid


# --------------------------------------------------------------------------- #
# async buffered aggregation: staleness weighting
# --------------------------------------------------------------------------- #

def test_staleness_weight_monotone():
    s = jnp.arange(6)
    w = np.asarray(staleness_weight(s, alpha=0.5))
    assert w[0] == 1.0
    assert np.all(np.diff(w) < 0)
    np.testing.assert_allclose(np.asarray(staleness_weight(s, alpha=0.0)),
                               np.ones(6))


def test_weighted_delta_mean_matches_manual():
    rng = np.random.default_rng(0)
    deltas = [{"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
              for _ in range(5)]
    w = jnp.asarray([1.0, 0.5, 0.25, 0.0, 2.0])
    out = weighted_delta_mean(tree_stack(deltas), w)
    manual = sum(float(wi) * np.asarray(d["w"])
                 for wi, d in zip(w, deltas)) / float(w.sum())
    np.testing.assert_allclose(np.asarray(out["w"]), manual, rtol=1e-5)


def test_buffered_aggregator_staleness_and_gate():
    agg = BufferedAggregator(capacity=3, alpha=1.0)
    mk = lambda v: {"w": jnp.ones((2,), jnp.float32) * (v + 1)}
    for client, version in [(0, 0), (1, 1), (2, 2)]:
        agg.add(BufferedUpdate(client, mk(version), version))
    res = agg.flush(current_version=3, gate=np.array([1.0, 1.0, 0.0]))
    np.testing.assert_array_equal(res.staleness, [3, 2, 1])
    # gated update (client 2) contributes nothing despite lowest staleness
    np.testing.assert_allclose(res.weights, [1 / 4, 1 / 3, 0.0], rtol=1e-6)
    manual = (1 / 4 * 1.0 + 1 / 3 * 2.0) / (1 / 4 + 1 / 3)
    np.testing.assert_allclose(np.asarray(res.delta["w"]),
                               np.full(2, manual), rtol=1e-5)
    assert len(agg) == 0


def test_async_sim_staleness_observed_and_conserved():
    pop = _small_pop(byzantine_frac=0.1)
    sim, rep = _run(pop, rounds=4, mode="async", buffer_size=6, concurrency=18)
    assert any(r.staleness_mean > 0 for r in rep.history)
    for rec in rep.history:
        np.testing.assert_allclose(rec.reward_paid + rec.reward_burned,
                                   sim.cfg.total_reward, rtol=1e-5)
    assert rep.ledger_conserved and rep.chain_valid


# --------------------------------------------------------------------------- #
# samplers
# --------------------------------------------------------------------------- #

def test_uniform_sampler_deterministic_and_sorted():
    online = np.arange(50)
    s = get_sampler("uniform")
    a = s(np.random.default_rng(1), online, 10, SamplerState())
    b = s(np.random.default_rng(1), online, 10, SamplerState())
    np.testing.assert_array_equal(a, b)
    assert len(np.unique(a)) == 10
    assert np.all(np.diff(a) > 0)


def test_stake_weighted_sampler_prefers_rich_clients():
    online = np.arange(40)
    balances = np.ones(40)
    balances[:5] = 100.0
    state = SamplerState(balances=balances)
    s = get_sampler("stake_weighted")
    rng = np.random.default_rng(0)
    hits = sum(np.intersect1d(s(rng, online, 8, state), np.arange(5)).size
               for _ in range(50))
    # rich 5 hold ~58% of total stake; uniform would give them 20% of picks
    assert hits > 0.4 * 50 * 8


def test_cluster_stratified_sampler_covers_all_clusters():
    online = np.arange(60)
    labels = np.repeat([0, 1, 2], 20)
    state = SamplerState(last_labels=labels, n_clusters=3)
    s = get_sampler("cluster_stratified")
    cohort = s(np.random.default_rng(0), online, 12, state)
    assert len(cohort) == 12
    picked = labels[cohort]
    for c in range(3):
        assert (picked == c).sum() == 4      # exact proportional allocation


# --------------------------------------------------------------------------- #
# chain_round over an explicit cohort (core integration)
# --------------------------------------------------------------------------- #

def test_chain_round_cohort_scatter():
    pop = _small_pop(n=40, dropout_rate=0.0, straggler_frac=0.0)
    sim, _ = _run(pop, rounds=1, sample_frac=0.3)
    tr = sim.trainer
    cohort = np.array([2, 9, 17, 25, 33])
    arrived = np.array([True, True, False, True, True])
    params = jax.tree.map(lambda x: x[jnp.asarray(cohort)], sim.params)
    labels = jnp.asarray([0, 0, 1, 1, 2])
    corr = jnp.eye(5, dtype=jnp.float32)
    before = tr.ledger.balances.copy()
    res = tr.chain_round(100, params, labels, corr, cohort=cohort,
                         arrived=arrived)
    assert not res.verified[2]               # the no-show is never verified
    assert res.rewards[2] == 0.0
    np.testing.assert_allclose(res.rewards.sum(), sim.cfg.total_reward,
                               rtol=1e-5)
    assert res.producer in set(int(c) for c in cohort[arrived])
    delta = tr.ledger.balances - before
    outside = np.ones(40, bool)
    outside[cohort] = False
    np.testing.assert_array_equal(delta[outside], 0.0)
    assert tr.ledger.conserved()

"""Cluster-aggregation kernel: sweep vs oracle + FedAvg equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.cluster_agg import mixing_matrix


@pytest.mark.parametrize("m", [4, 20, 64])
@pytest.mark.parametrize("n", [100, 2048, 5001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cluster_agg_matches_oracle(m, n, dtype):
    key = jax.random.PRNGKey(m + n)
    flat = jax.random.normal(key, (m, n)).astype(dtype)
    labels = jax.random.randint(key, (m,), 0, 4)
    got = ops.cluster_aggregate(flat, labels, 4)
    want = ref.cluster_agg_ref(flat, mixing_matrix(labels, 4))
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_single_cluster_equals_fedavg():
    flat = jax.random.normal(jax.random.PRNGKey(0), (10, 333))
    labels = jnp.zeros((10,), jnp.int32)
    got = np.asarray(ops.cluster_aggregate(flat, labels, 1))
    fedavg = np.broadcast_to(np.mean(np.asarray(flat), axis=0), got.shape)
    np.testing.assert_allclose(got, fedavg, atol=1e-5)


def test_members_of_same_cluster_get_identical_params():
    flat = jax.random.normal(jax.random.PRNGKey(1), (8, 77))
    labels = jnp.asarray([0, 0, 1, 1, 1, 2, 2, 2])
    out = np.asarray(ops.cluster_aggregate(flat, labels, 3))
    np.testing.assert_allclose(out[0], out[1], atol=1e-6)
    np.testing.assert_allclose(out[2], out[3], atol=1e-6)
    np.testing.assert_allclose(out[5], out[7], atol=1e-6)
    # different clusters differ
    assert np.abs(out[0] - out[2]).max() > 1e-3


def test_aggregation_idempotent():
    """Aggregating already-aggregated params is a no-op."""
    flat = jax.random.normal(jax.random.PRNGKey(2), (6, 50))
    labels = jnp.asarray([0, 0, 1, 1, 2, 2])
    once = ops.cluster_aggregate(flat, labels, 3)
    twice = ops.cluster_aggregate(once, labels, 3)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-5)

"""Sharding rule engine: divisibility guard + expected placements.

Uses AbstractMesh — no devices needed, so these run on the 1-CPU test
environment while still exercising the exact production mesh shapes.
"""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch import sharding as shd
from repro.launch.mesh import make_abstract_mesh
from repro.models.decode import init_cache
from repro.models.transformer import param_specs


def _mesh(multi=False):
    if multi:
        return make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_abstract_mesh((16, 16), ("data", "model"))


def _check_divisibility(shapes, specs, mesh):
    def ok(path, leaf, spec):
        for dim, axis in zip(leaf.shape, spec):
            if axis is None:
                continue
            names = axis if isinstance(axis, tuple) else (axis,)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            assert dim % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: ok(p, l, s), shapes, specs,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_always_divisible(arch, multi):
    cfg = ARCHS[arch]
    mesh = _mesh(multi)
    shapes = param_specs(cfg)
    specs = shd.param_pspecs(cfg, shapes, mesh)
    _check_divisibility(shapes, specs, mesh)


def test_vocab_padding_makes_embeddings_shardable():
    cfg = ARCHS["internvl2-2b"]          # raw vocab 92553 is not /16
    assert cfg.padded_vocab % 2048 == 0
    shapes = param_specs(cfg)
    specs = shd.param_pspecs(cfg, shapes, _mesh())
    assert specs["embed"] == P("model", None)   # tp mode: no fsdp dim


def test_fsdp_mode_shards_both_axes():
    cfg = ARCHS["llama4-maverick-400b-a17b"]
    shapes = param_specs(cfg)
    specs = shd.param_pspecs(cfg, shapes, _mesh())
    assert specs["embed"] == P("model", "data")
    # MoE expert tables: (P, E, D, F) stacked -> (None, None, data, model)
    moe_spec = specs["layers"][1]["moe"]["w_gate"]
    assert moe_spec == P(None, None, "data", "model")


def test_cache_specs_decode_vs_long_context():
    cfg = ARCHS["gemma3-4b"]
    mesh = _mesh()
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = shd.cache_pspecs(cfg, cache, mesh, shard_batch=True)
    # batched decode (stacked periods axis first): batch over data, seq over model
    assert specs["layers"][0]["k"] == P(None, ("data",), "model", None, None)
    long_cache = jax.eval_shape(lambda: init_cache(cfg, 1, 524288))
    lspecs = shd.cache_pspecs(cfg, long_cache, mesh, shard_batch=False)
    # long-context: sequence over data+model
    assert lspecs["layers"][0]["k"] == P(None, None, ("data", "model"), None, None)
    _check_divisibility(long_cache, lspecs, mesh)


def test_rwkv_non_divisible_heads_guarded():
    cfg = ARCHS["rwkv6-3b"]             # 40 heads not /16
    mesh = _mesh()
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = shd.cache_pspecs(cfg, cache, mesh, shard_batch=True)
    wkv = specs["layers"][0]["wkv"]
    assert wkv[2] is None or wkv[2] != "model"  # head axis dropped by guard
    _check_divisibility(cache, specs, mesh)

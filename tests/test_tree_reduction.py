"""Deterministic tree reductions vs their single-device numpy oracles.

The fused round engine's bit-identical replay across mesh widths rests on
one numerical contract (``repro.core.aggregation``): every cohort-axis
float reduction is a fixed-order adjacent-pair binary tree whose rounding
sequence is pinned in the graph, and zero-weight (masked / padding) slots
are where-guarded to contribute EXACTLY +0.0.  These tests pin that
contract against the pure-numpy oracles in ``repro.kernels.ref`` —
elementwise IEEE adds have one correct rounding, so jit and numpy must
agree bit for bit — across ragged lengths, permuted layouts, appended
zero-weight padding, garbage in dead slots, zero-arrival clusters, and
(on a mesh) cohort blocks that arrive sharded at shard counts 1/2/4/8 and
are replicated before reducing — the engine's combine discipline.

Property-based exploration runs under ``hypothesis`` when installed;
the seeded-numpy sweeps below always run.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (
    masked_tree_sum,
    tree_cluster_mean_params,
    tree_sum,
)
from repro.kernels.ref import (
    masked_tree_sum_ref,
    tree_cluster_mean_ref,
    tree_sum_ref,
)

N_DEV = len(jax.devices())
mesh8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _bits(x) -> np.ndarray:
    return np.asarray(x, np.float32).view(np.uint32)


def _assert_bitwise(actual, expected):
    np.testing.assert_array_equal(_bits(actual), _bits(expected))


# --------------------------------------------------------------------------- #
# jit vs numpy oracle — always run
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 13, 16, 31])
def test_tree_sum_matches_oracle(m):
    rng = np.random.default_rng(100 + m)
    x = (rng.standard_normal((m, 7)) * rng.choice(
        [1e-8, 1.0, 1e8], size=(m, 7))).astype(np.float32)
    _assert_bitwise(jax.jit(tree_sum)(jnp.asarray(x)), tree_sum_ref(x))
    # non-leading axis reduces through the same moveaxis path
    _assert_bitwise(jax.jit(lambda a: tree_sum(a, axis=1))(jnp.asarray(x.T)),
                    tree_sum_ref(x.T, axis=1))


@pytest.mark.parametrize("m,n_zero", [(6, 2), (10, 3), (16, 0), (16, 16)])
def test_masked_tree_sum_matches_oracle_and_guards_dead_slots(m, n_zero):
    rng = np.random.default_rng(7 * m + n_zero)
    x = rng.standard_normal((m, 5)).astype(np.float32)
    w = rng.uniform(0.5, 2.0, size=m).astype(np.float32)
    dead = rng.choice(m, size=n_zero, replace=False)
    w[dead] = 0.0
    # garbage in dead slots must be where-guarded into exact +0.0
    x[dead] = np.float32(np.inf)
    got = jax.jit(masked_tree_sum)(jnp.asarray(x), jnp.asarray(w))
    _assert_bitwise(got, masked_tree_sum_ref(x, w))
    assert np.isfinite(np.asarray(got)).all()


def test_masked_tree_sum_zero_weight_padding_is_bitwise_noop():
    """The engine's cohort padding contract: appending zero-weight slots
    (with arbitrary values) never changes a single output bit.

    The jit-vs-jit comparison stays within one padded power-of-two tree
    width (how the engine pads: k and k_pad share ``next_pow2``): at some
    larger widths XLA CPU contracts the weight multiply into the tree adds
    (FMA), flipping ULPs relative to a *differently shaped* program.  The
    numpy oracle has no such freedom, so its padding invariance is asserted
    unconditionally, across the power-of-two boundary too."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal((10, 6)).astype(np.float32)
    w = rng.uniform(0.1, 1.0, size=10).astype(np.float32)
    base = jax.jit(masked_tree_sum)(jnp.asarray(x), jnp.asarray(w))
    for pad in (1, 2, 6):                 # 10 + pad <= 16 == next_pow2(10)
        xp = np.concatenate(
            [x, np.full((pad, 6), np.nan, np.float32)], axis=0)
        wp = np.concatenate([w, np.zeros(pad, np.float32)])
        _assert_bitwise(jax.jit(masked_tree_sum)(jnp.asarray(xp),
                                                 jnp.asarray(wp)), base)
    for pad in (1, 6, 22, 54):            # oracle: any pad is a no-op
        xp = np.concatenate([x, np.zeros((pad, 6), np.float32)], axis=0)
        wp = np.concatenate([w, np.zeros(pad, np.float32)])
        _assert_bitwise(masked_tree_sum_ref(xp, wp), masked_tree_sum_ref(x, w))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_tree_cluster_mean_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    m, n, c = 12, 9, 3
    rows = rng.standard_normal((m, n)).astype(np.float32)
    labels = rng.integers(0, c, size=m)
    weights = rng.uniform(0.0, 2.0, size=m).astype(np.float32)
    got = jax.jit(tree_cluster_mean_params, static_argnums=2)(
        jnp.asarray(rows), jnp.asarray(labels), c, jnp.asarray(weights))
    _assert_bitwise(got, tree_cluster_mean_ref(rows, labels, c, weights))


def test_tree_cluster_mean_permuted_layout_consistent_with_oracle():
    """Permuting the slot layout permutes the outputs through the oracle the
    same way — membership is by label, not by slot position."""
    rng = np.random.default_rng(21)
    m, n, c = 16, 8, 4
    rows = rng.standard_normal((m, n)).astype(np.float32)
    labels = rng.integers(0, c, size=m)
    fn = jax.jit(tree_cluster_mean_params, static_argnums=2)
    for pseed in range(3):
        perm = np.random.default_rng(pseed).permutation(m)
        got = fn(jnp.asarray(rows[perm]), jnp.asarray(labels[perm]), c)
        _assert_bitwise(got, tree_cluster_mean_ref(rows[perm], labels[perm], c))


def test_tree_cluster_mean_zero_arrival_cluster_degrades_to_zeros():
    """A cluster whose members all carry zero weight yields exact zeros
    (clamped denominator), in jit and oracle alike."""
    rng = np.random.default_rng(33)
    m, n, c = 8, 5, 2
    rows = rng.standard_normal((m, n)).astype(np.float32)
    labels = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    weights = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float32)
    got = jax.jit(tree_cluster_mean_params, static_argnums=2)(
        jnp.asarray(rows), jnp.asarray(labels), c, jnp.asarray(weights))
    ref = tree_cluster_mean_ref(rows, labels, c, weights)
    _assert_bitwise(got, ref)
    np.testing.assert_array_equal(np.asarray(got)[4:], 0.0)


def test_tree_sum_property_hypothesis():
    """Property lane (skipped when hypothesis isn't installed): random
    lengths / magnitudes / zero-weight patterns, jit vs oracle bitwise."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    jsum = jax.jit(tree_sum)
    jmasked = jax.jit(masked_tree_sum)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 33), st.integers(0, 2**31 - 1))
    def prop(m, seed):
        rng = np.random.default_rng(seed)
        x = (rng.standard_normal((m, 3)) * rng.choice(
            [1e-20, 1e-3, 1.0, 1e6], size=(m, 3))).astype(np.float32)
        w = rng.choice([0.0, 0.5, 1.0, 3.0], size=m).astype(np.float32)
        _assert_bitwise(jsum(jnp.asarray(x)), tree_sum_ref(x))
        _assert_bitwise(jmasked(jnp.asarray(x), jnp.asarray(w)),
                        masked_tree_sum_ref(x, w))

    prop()


# --------------------------------------------------------------------------- #
# cohort-sharded inputs: bit identity at shard counts 1/2/4/8
# --------------------------------------------------------------------------- #

def _sharded_case(shards: int):
    """Tree reductions over a cohort block that arrives SHARDED over
    ``shards`` devices and is replicated before reducing — the engine's
    combine discipline (``repro.core.engine``) — returns np outputs.

    The replicate step is load-bearing: reducing the still-sharded axis
    lets GSPMD rewrite the tree levels into cross-device collectives whose
    CPU codegen contracts differently than the single-device program
    (observed ULP flips — the bug the engine's replicated combine fixes).
    Replicated, every device runs the identical scalar program and the
    bits match the numpy oracle at every shard count."""
    from repro.launch.mesh import make_client_mesh
    from repro.launch.sharding import cohort_shardings

    rng = np.random.default_rng(5)
    m, n, c = 16, 11, 3
    rows = rng.standard_normal((m, n)).astype(np.float32)
    labels = rng.integers(0, c, size=m)
    weights = rng.uniform(0.0, 2.0, size=m).astype(np.float32)
    weights[labels == 2] = 0.0           # a zero-arrival cluster
    weights[m - m // 8:] = 0.0           # trailing dead slots (empty-shard
    #                                      padding when shards divide m)
    csh, rep = cohort_shardings(make_client_mesh(shards))

    @jax.jit
    def fn(r, w):
        r = jax.lax.with_sharding_constraint(r, csh)    # arrives sharded
        r = jax.lax.with_sharding_constraint(r, rep)    # combine: replicate
        s = tree_sum(r)
        ms = masked_tree_sum(r, w)
        cm = tree_cluster_mean_params(r, jnp.asarray(labels), c, w)
        return (jax.lax.with_sharding_constraint(s, rep),
                jax.lax.with_sharding_constraint(ms, rep),
                jax.lax.with_sharding_constraint(cm, rep))

    outs = fn(jnp.asarray(rows), jnp.asarray(weights))
    refs = (tree_sum_ref(rows), masked_tree_sum_ref(rows, weights),
            tree_cluster_mean_ref(rows, labels, c, weights))
    return [np.asarray(o) for o in outs], refs


@mesh8
@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_tree_reductions_bit_identical_under_cohort_sharding(shards):
    outs, refs = _sharded_case(shards)
    for got, ref in zip(outs, refs):
        _assert_bitwise(got, ref)


# --------------------------------------------------------------------------- #
# single-device environments: self-forcing subprocess gate
# --------------------------------------------------------------------------- #

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from test_tree_reduction import _sharded_case, _bits
for shards in (1, 2, 4, 8):
    outs, refs = _sharded_case(shards)
    for got, ref in zip(outs, refs):
        assert np.array_equal(_bits(got), _bits(ref)), shards
print("TREE_SHARDING_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(N_DEV >= 8, reason="covered in-process by the mesh tests")
def test_tree_reductions_sharded_via_forced_devices_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.abspath(os.path.join(here, os.pardir, "src"))
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here, env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "TREE_SHARDING_OK" in out.stdout

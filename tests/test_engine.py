"""Arena-backed fused round engine: jit cache stability across varying
arrival counts, in-place (donated) arena updates, and bit-identical seeded
replay against the legacy `_cohort_round` + scatter driver — including empty
rounds and zero-arrival clusters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sim import ClientPopulation, PopulationSpec, SimConfig, SimulatedFederation


def _pop(n=60, seed=3, **kw):
    defaults = dict(n_clients=n, dataset="synth10", beta=0.3, n_batches=1,
                    batch_size=16, straggler_frac=0.2, straggler_slowdown=8.0,
                    dropout_rate=0.05, byzantine_frac=0.1, seed=seed)
    defaults.update(kw)
    return ClientPopulation.from_spec(PopulationSpec(**defaults))


def _sim(pop, engine, **kw):
    defaults = dict(rounds=4, sample_frac=0.25, n_clusters=3, eval_every=2,
                    seed=3, engine=engine)
    defaults.update(kw)
    return SimulatedFederation(pop, SimConfig(**defaults))


def _block_hashes(sim):
    return [b.block_hash() for b in sim.trainer.chain.blocks]


# --------------------------------------------------------------------------- #
# jit cache stability (the ROADMAP recompile item)
# --------------------------------------------------------------------------- #

def test_engine_compiles_once_across_varying_arrival_counts():
    """Regression for the ROADMAP open item: eval used to recompile for
    every distinct arrived-client count.  The engine's fixed-shape masked
    entries compile exactly once, no matter how arrivals vary."""
    sim = _sim(_pop(straggler_frac=0.3), engine=True, rounds=5, eval_every=1)
    rep = sim.run()
    counts = {int(r.arrived.sum()) for r in rep.history}
    assert len(counts) > 1, "population should produce varying arrival counts"
    sizes = sim.engine.cache_sizes()
    assert sizes["sync_step"] == 1, sizes
    assert sizes["eval_cohort"] == 1, sizes
    # the final population eval has its own entry and never retraces the
    # round eval
    assert sizes["eval_population"] == 1, sizes


def test_legacy_final_eval_has_dedicated_entry():
    """The final population eval no longer reuses the round-eval jit with a
    different leading dim (which thrashed compile-count accounting)."""
    sim = _sim(_pop(), engine=False, rounds=3, eval_every=1)
    sim.run()
    assert sim._eval_final._cache_size() == 1
    # the legacy round eval still recompiles per arrival count — quarantined
    # to its own entry (and killed entirely by the engine path)
    assert sim._eval._cache_size() >= 1


def test_arena_updated_in_place_no_population_realloc():
    """Donation: after warmup the arena buffer is reused in place — the
    O(n_clients · N_params) per-round reallocation is gone."""
    if jax.default_backend() != "cpu":
        pytest.skip("buffer-pointer check is exercised on CPU CI")
    pop = _pop(straggler_frac=0.0, dropout_rate=0.0)
    pop.availability[:] = 1.0
    sim = _sim(pop, engine=True, rounds=1, eval_every=0)
    sim.history.append(sim._run_sync_round(0))      # warmup (compile)
    ptr = sim.arena.data.unsafe_buffer_pointer()
    for r in range(1, 4):
        sim.history.append(sim._run_sync_round(r))
        assert sim.arena.data.unsafe_buffer_pointer() == ptr


# --------------------------------------------------------------------------- #
# bit-identical replay vs the legacy (pre-arena) driver
# --------------------------------------------------------------------------- #

@pytest.mark.slow
def test_engine_replay_identical_sync():
    # Accuracy comparisons below are exact on purpose: accuracy is a
    # count-based metric (hits/examples), so it tolerates the ulp-level
    # logit differences between the engine's stacked forward and the legacy
    # vmap eval unless an argmax lands exactly on a tie.  Deterministic for
    # a fixed platform/jax version; revisit if a jax upgrade flips one.
    a = _sim(_pop(), engine=True)
    b = _sim(_pop(), engine=False)
    ra, rb = a.run(), b.run()
    assert ra.event_log == rb.event_log
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(ra.balances, rb.balances)
    assert ra.final_accuracy == rb.final_accuracy
    assert any(not r.arrived.all() for r in ra.history), \
        "replay should cover rounds with missing arrivals"
    for x, y in zip(ra.history, rb.history):
        assert x.producer == y.producer
        assert x.reward_paid == y.reward_paid
        assert (x.accuracy == y.accuracy) or \
            (np.isnan(x.accuracy) and np.isnan(y.accuracy))


@pytest.mark.slow
def test_engine_replay_identical_async():
    kw = dict(mode="async", buffer_size=6, concurrency=12)
    a = _sim(_pop(), engine=True, **kw)
    b = _sim(_pop(), engine=False, **kw)
    ra, rb = a.run(), b.run()
    assert ra.event_log == rb.event_log
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(ra.balances, rb.balances)
    assert ra.final_accuracy == rb.final_accuracy
    assert any(r.staleness_mean > 0 for r in ra.history)


def test_empty_rounds_identical_and_blockless():
    """Nobody beats the deadline: no block is minted, balances untouched,
    and the engine/legacy drivers agree event for event."""
    def make():
        pop = _pop(n=30, straggler_frac=0.0, dropout_rate=0.0)
        pop.latency.speed[:] = 1e9          # everyone misses every deadline
        return pop
    a = _sim(make(), engine=True, rounds=2, eval_every=0)
    b = _sim(make(), engine=False, rounds=2, eval_every=0)
    ra, rb = a.run(), b.run()
    assert ra.event_log == rb.event_log
    assert all(not r.arrived.any() for r in ra.history)
    assert len(a.trainer.chain.blocks) == 1          # genesis only
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(ra.balances,
                                  np.full(30, a.cfg.initial_stake))
    # the engine never ran — and never compiled
    assert a.engine.cache_sizes()["sync_step"] == 0


def test_engine_eval_matches_generic_masked_reference():
    """The engine's width-concatenated stacked eval == the generic
    ``masked_global_evaluate`` oracle (same per-client accuracies)."""
    from repro.core.fl import masked_global_evaluate
    pop = _pop(n=30)
    sim = _sim(pop, engine=True, rounds=1)
    k = 8
    cohort_idx = jnp.arange(k)
    cx, cy = pop.cohort_data(np.arange(k))
    mask = jnp.asarray([1, 1, 0, 1, 0, 1, 1, 1], jnp.float32)
    sim.arena.data, out = sim.engine.sync_step(
        sim.arena.data, cohort_idx, cx, cy, mask)
    ex, ey = sim._eval_slices()
    acc, cacc = sim.engine.eval_cohort(out.new_rows, mask, out.labels, ex, ey)
    ref_acc, ref_accs = masked_global_evaluate(
        sim.bundle.apply_fn, sim.arena.layout.unflatten(out.new_rows),
        ex, ey, mask)
    assert float(acc) == float(ref_acc)
    assert cacc.shape == (sim.cfg.n_clusters,)


def test_sync_step_zero_arrival_cluster_matches_legacy():
    """A cluster whose members all miss the deadline must aggregate exactly
    like the legacy path (its mean is weight-zero; members keep old rows)."""
    pop = _pop(n=40, straggler_frac=0.0, dropout_rate=0.0, byzantine_frac=0.0)
    ea = _sim(pop, engine=True, rounds=1)
    eb = _sim(pop, engine=False, rounds=1)
    k = 12
    cohort = np.arange(0, 40, 40 // k)[:k]
    cx, cy = pop.cohort_data(cohort)
    cohort_idx = jnp.asarray(cohort)

    # discover the round's labels (mask-independent), then craft an arrival
    # mask that leaves one whole cluster empty
    _, probe_out = ea.engine.sync_step(
        ea.arena.data, cohort_idx, cx, cy, jnp.ones((k,), jnp.float32))
    labels = np.asarray(probe_out.labels)
    dead = labels[0]
    mask = (labels != dead)
    assert mask.any() and not mask.all()

    # fresh sims so both paths start from identical params
    ea = _sim(pop, engine=True, rounds=1)
    eb = _sim(pop, engine=False, rounds=1)
    arrived_w = jnp.asarray(mask, jnp.float32)
    new_data, out = ea.engine.sync_step(
        ea.arena.data, cohort_idx, cx, cy, arrived_w)

    local_params, agg, mean_loss = eb._cohort_round(
        jax.tree.map(lambda x: x[cohort_idx], eb.params), cx, cy, arrived_w)
    np.testing.assert_array_equal(np.asarray(out.labels), labels)
    np.testing.assert_array_equal(np.asarray(out.corr), np.asarray(agg.corr))
    assert float(out.mean_loss) == float(mean_loss)
    # scatter-back equivalence, bit for bit, dead cluster rows untouched
    upd = cohort[mask]
    new_rows = jax.tree.map(lambda x: x[jnp.asarray(np.flatnonzero(mask))],
                            agg.stacked_params)
    expect = jax.tree.map(lambda P, rows: P.at[jnp.asarray(upd)].set(rows),
                          eb.params, new_rows)
    np.testing.assert_array_equal(
        np.asarray(new_data).view(np.uint32),
        np.asarray(ea.arena.layout.flatten(expect)).view(np.uint32))

"""XLA attention implementations agree (full vs chunked vs chunk-skipping)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attend_chunked, attend_decode, attend_full


def _mk(B, S, Hq, Hkv, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, hd)),
            jax.random.normal(ks[1], (B, S, Hkv, hd)),
            jax.random.normal(ks[2], (B, S, Hkv, hd)))


@pytest.mark.parametrize("window", [0, 37, 128])
def test_chunked_matches_full(window):
    q, k, v = _mk(2, 256, 4, 2, 16, seed=window)
    a = attend_full(q, k, v, causal=True, window=window)
    b = attend_chunked(q, k, v, causal=True, window=window, q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("window", [0, 64])
def test_skip_masked_chunks_is_exact(window):
    """The §Perf chunk-skipping optimisation must be bit-compatible in math."""
    q, k, v = _mk(1, 512, 2, 2, 16, seed=9 + window)
    base = attend_chunked(q, k, v, causal=True, window=window,
                          q_chunk=128, k_chunk=128, skip_masked_chunks=False)
    opt = attend_chunked(q, k, v, causal=True, window=window,
                         q_chunk=128, k_chunk=128, skip_masked_chunks=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt), atol=1e-6)


def test_decode_matches_full_last_token():
    q, k, v = _mk(2, 64, 4, 2, 16, seed=3)
    full = attend_full(q, k, v, causal=True)
    out = attend_decode(q[:, -1:], k, v, pos=jnp.asarray(63))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5)

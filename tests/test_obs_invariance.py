"""Tracing must be out-of-band: replay with the flight recorder ON is
bit-identical to replay with it OFF.

The invariant pinned here (the tentpole's hard contract): for the same spec
and seed, trace on vs. trace off produces identical event logs, block
hashes, ledger balances and final accuracy — observability times and
counts, it never perturbs.  Verified for sync and async modes, the legacy
``engine=False`` driver, and the mesh-sharded engine (in-process at 8
devices, else via a self-forcing subprocess).  The traced run's artifact is
also checked end to end: every JSONL record validates against the schema,
the manifest's ``trace_digest`` matches the file's sha256, and the manifest
carries the timing readout.
"""
import os
import subprocess
import sys

import jax
import pytest

import repro.api as api
from repro.obs import file_sha256, validate_trace_lines

N_DEV = len(jax.devices())
mesh8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _spec(*, mode="sync", engine=True, mesh_shards=1, obs=None,
          seed=3) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=40, dataset="synth10", beta=0.3,
                          n_batches=1, batch_size=16, straggler_frac=0.2,
                          straggler_slowdown=8.0, dropout_rate=0.05,
                          byzantine_frac=0.1),
        train=api.TrainSpec(rounds=3, sample_frac=0.25, n_clusters=3,
                            local_epochs=1, mode=mode),
        async_=api.AsyncSpec(buffer_size=6, concurrency=12),
        eval=api.EvalSpec(every=2, clients=16, examples=64),
        mesh=api.MeshSpec(shards=mesh_shards),
        obs=obs if obs is not None else api.ObsSpec(),
        engine=engine, seed=seed)


REPLAY_KEYS = ("event_log_digest", "block_hashes_digest", "balances_digest",
               "final_accuracy")


def _assert_traced_replay_identical(tmp_path, *, mode, engine,
                                    mesh_shards=1):
    trace = str(tmp_path / f"{mode}_{engine}_{mesh_shards}.jsonl")
    on = api.run(_spec(mode=mode, engine=engine, mesh_shards=mesh_shards,
                       obs=api.ObsSpec(enabled=True, trace_path=trace)))
    off = api.run(_spec(mode=mode, engine=engine, mesh_shards=mesh_shards))

    # the hard invariant: identical replay with tracing on vs. off
    for key in REPLAY_KEYS:
        assert on.manifest[key] == off.manifest[key], key
    assert on.spec.config_digest() == off.spec.config_digest()

    # the traced artifact is complete and digest-stamped
    assert on.manifest["trace_path"] == trace
    assert on.manifest["trace_digest"] == file_sha256(trace)
    counts = validate_trace_lines(open(trace).read().splitlines())
    assert counts["span"] > 0 and counts["summary"] > 0
    timing = on.manifest["timing"]
    assert timing["rounds"] == len(on.report.history)
    assert "round_ms_p50" in timing
    # the one-line readout surfaces the timing
    assert "timing:" in on.summary() and "compiles=" in on.summary()
    return on


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_traced_replay_identical_engine(tmp_path, mode):
    _assert_traced_replay_identical(tmp_path, mode=mode, engine=True)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_traced_replay_identical_legacy(tmp_path, mode):
    _assert_traced_replay_identical(tmp_path, mode=mode, engine=False)


def test_trace_records_chain_and_phase_spans(tmp_path):
    res = _assert_traced_replay_identical(tmp_path, mode="sync", engine=True)
    import json
    names = set()
    for line in open(res.manifest["trace_path"]):
        rec = json.loads(line)
        if rec["kind"] == "span":
            names.add(rec["name"])
    assert {"round.total", "round.sample", "round.step", "round.chain",
            "chain.pack", "chain.verify", "run.final_eval"} <= names


@mesh8
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_traced_replay_identical_mesh8(tmp_path, mode):
    """Tracing stays out of band under cohort sharding too — sync rounds
    AND FedBuff flushes through the sharded step, traced vs untraced,
    replay bit-identical (the `round.step`/`flush.step` spans additionally
    carry `shards`/`cohort_mode` attrs; schema in docs/TRACE_SCHEMA.md)."""
    res = _assert_traced_replay_identical(tmp_path, mode=mode, engine=True,
                                          mesh_shards=8)
    import json
    step = "round.step" if mode == "sync" else "flush.step"
    attrs = [rec.get("attrs", {})
             for rec in map(json.loads, open(res.manifest["trace_path"]))
             if rec["kind"] == "span" and rec["name"] == step]
    assert attrs and all(a.get("shards") == 8 and
                         a.get("cohort_mode") == "sharded" for a in attrs)


# --------------------------------------------------------------------------- #
# single-device environments: self-forcing subprocess mesh gate
# --------------------------------------------------------------------------- #

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_cpu_multi_thread_eigen=false")
import repro.api as api
from repro.obs import file_sha256, validate_trace_lines

def spec(obs):
    return api.ExperimentSpec(
        data=api.DataSpec(n_clients=40, dataset="synth10", beta=0.3,
                          n_batches=1, batch_size=16, straggler_frac=0.2,
                          straggler_slowdown=8.0, dropout_rate=0.05,
                          byzantine_frac=0.1),
        train=api.TrainSpec(rounds=3, sample_frac=0.25, n_clusters=3,
                            local_epochs=1),
        eval=api.EvalSpec(every=2, clients=16, examples=64),
        mesh=api.MeshSpec(shards=8), obs=obs, engine=True, seed=3)

on = api.run(spec(api.ObsSpec(enabled=True, trace_path="mesh_trace.jsonl")))
off = api.run(spec(api.ObsSpec()))
for key in ("event_log_digest", "block_hashes_digest", "balances_digest",
            "final_accuracy"):
    assert on.manifest[key] == off.manifest[key], key
assert on.manifest["trace_digest"] == file_sha256("mesh_trace.jsonl")
validate_trace_lines(open("mesh_trace.jsonl").read().splitlines())
print("MESH_TRACE_REPLAY_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(N_DEV >= 8, reason="covered in-process by the mesh8 test")
def test_traced_mesh_replay_via_forced_devices_subprocess(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=str(tmp_path), timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MESH_TRACE_REPLAY_OK" in out.stdout

"""CACC: centroid-representative selection (Eqs. 4–6) + packing queue."""
import jax.numpy as jnp
import numpy as np

from repro.core.consensus import packing_queue, producer_for_round, select_centroid_clients


def test_centroid_selection_matches_bruteforce():
    rng = np.random.default_rng(0)
    m, c = 12, 3
    corr = rng.uniform(-1, 1, (m, m)).astype(np.float32)
    corr = (corr + corr.T) / 2
    np.fill_diagonal(corr, 1.0)
    labels = rng.integers(0, c, m)

    res = select_centroid_clients(jnp.asarray(corr), jnp.asarray(labels), c)
    for tau in range(c):
        members = np.flatnonzero(labels == tau)
        centroid = corr[members].mean(axis=0)                 # Eq. 4
        dists = np.linalg.norm(corr[members] - centroid, axis=1)  # Eqs. 5–6
        want = members[np.argmin(dists)]
        assert int(res.representatives[tau]) == int(want)


def test_empty_cluster_marked():
    corr = jnp.eye(4)
    labels = jnp.asarray([0, 0, 1, 1])
    res = select_centroid_clients(corr, labels, 3)
    assert int(res.representatives[2]) == -1
    q = packing_queue(res.representatives)
    assert len(q) == 2 and -1 not in q


def test_round_robin_rotation():
    q = [4, 7, 1]
    assert [producer_for_round(q, r) for r in range(6)] == [4, 7, 1, 4, 7, 1]

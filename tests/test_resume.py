"""Crash-consistent checkpoint/resume — the tentpole contract.

Kill a run at a checkpoint boundary (in-process ``InjectedCrash`` for the
matrix, a real SIGKILL subprocess for the slow case), resume from the
snapshot directory with the fault schedule cleared, and the final manifest
digests (event-log sha256, block-hashes digest, balances digest, final
accuracy) must be BIT-identical to the uninterrupted run — for sync and
async, engine and legacy-oracle, mesh_shards 1 and 8.  Checkpointing itself
must be a pure observer: snapshots on vs off changes no digest.
"""
import os
import subprocess
import sys
import textwrap
from dataclasses import replace

import pytest

from repro.api import (
    CheckpointSpec,
    DataSpec,
    ExperimentSpec,
    FaultSpec,
    TrainSpec,
    run,
)
from repro.api.spec import AsyncSpec
from repro.faults import InjectedCrash

DIGEST_KEYS = ("event_log_digest", "block_hashes_digest",
               "balances_digest", "final_accuracy")


def _digests(m):
    return {k: m[k] for k in DIGEST_KEYS}


def _spec(mode="sync", engine=True, rounds=6, seed=3, **kw):
    return ExperimentSpec(
        data=DataSpec(n_clients=40, n_batches=1, batch_size=16),
        train=TrainSpec(strategy="bfln", rounds=rounds, sample_frac=0.3,
                        n_clusters=2, local_epochs=1, mode=mode),
        async_=AsyncSpec(buffer_size=4, concurrency=8),
        engine=engine, seed=seed, **kw)


def _crash_resume_roundtrip(tmp_path, mode, engine):
    """Plain run; crashed-at-boundary-4 run; resume; compare digests."""
    plain = run(_spec(mode=mode, engine=engine))

    ck = CheckpointSpec(interval=2, dir=str(tmp_path / "ck"))
    crash = replace(_spec(mode=mode, engine=engine), checkpoint=ck,
                    faults=FaultSpec(crash_round=4,
                                     crash_phase="post_checkpoint",
                                     crash_mode="exception"))
    with pytest.raises(InjectedCrash):
        run(crash)

    resumed = run(replace(_spec(mode=mode, engine=engine), checkpoint=ck),
                  resume_from=ck.dir)
    assert resumed.manifest["resume_step"] == 4
    assert _digests(resumed.manifest) == _digests(plain.manifest)
    assert resumed.manifest["rounds_run"] == plain.manifest["rounds_run"]


def test_checkpointing_is_a_pure_observer(tmp_path):
    """Snapshots on vs off: identical digests, and the spec digests agree."""
    plain = run(_spec())
    ck = CheckpointSpec(interval=2, dir=str(tmp_path / "ck"))
    ckd = run(replace(_spec(), checkpoint=ck))
    assert _digests(ckd.manifest) == _digests(plain.manifest)
    assert ckd.manifest["checkpoints_written"] == 3      # boundaries 2, 4, 6
    assert replace(_spec(), checkpoint=ck).config_digest() \
        == _spec().config_digest()


def test_crash_resume_sync_engine(tmp_path):
    _crash_resume_roundtrip(tmp_path, "sync", True)


@pytest.mark.slow
@pytest.mark.parametrize("mode,engine", [("sync", False), ("async", True),
                                         ("async", False)])
def test_crash_resume_matrix(tmp_path, mode, engine):
    _crash_resume_roundtrip(tmp_path, mode, engine)


def test_resume_from_explicit_snapshot_file(tmp_path):
    plain = run(_spec())
    ck = CheckpointSpec(interval=2, dir=str(tmp_path / "ck"))
    run(replace(_spec(), checkpoint=ck))
    resumed = run(replace(_spec(), checkpoint=ck),
                  resume_from=os.path.join(ck.dir, "ckpt_00000004.npz"))
    assert resumed.manifest["resume_step"] == 4
    assert _digests(resumed.manifest) == _digests(plain.manifest)


def test_resume_falls_back_over_injected_corruption(tmp_path):
    """The newest snapshot is bit-flipped by the fault schedule; resume must
    fall back to the previous keep-last-K snapshot and still land on
    identical digests."""
    plain = run(_spec())
    ck = CheckpointSpec(interval=2, dir=str(tmp_path / "ck"))
    crash = replace(_spec(), checkpoint=ck,
                    faults=FaultSpec(corrupt_checkpoint_round=4,
                                     crash_round=4,
                                     crash_phase="post_checkpoint",
                                     crash_mode="exception"))
    with pytest.raises(InjectedCrash):
        run(crash)
    resumed = run(replace(_spec(), checkpoint=ck), resume_from=ck.dir)
    assert resumed.manifest["resume_step"] == 2          # 4 was corrupt
    assert _digests(resumed.manifest) == _digests(plain.manifest)


def test_resume_after_truncation_fault(tmp_path):
    plain = run(_spec())
    ck = CheckpointSpec(interval=2, dir=str(tmp_path / "ck"))
    crash = replace(_spec(), checkpoint=ck,
                    faults=FaultSpec(truncate_checkpoint_round=4,
                                     crash_round=4,
                                     crash_phase="post_checkpoint",
                                     crash_mode="exception"))
    with pytest.raises(InjectedCrash):
        run(crash)
    resumed = run(replace(_spec(), checkpoint=ck), resume_from=ck.dir)
    assert resumed.manifest["resume_step"] == 2
    assert _digests(resumed.manifest) == _digests(plain.manifest)


def test_resume_refuses_a_different_experiment(tmp_path):
    from repro.checkpoint import CheckpointError
    ck = CheckpointSpec(interval=2, dir=str(tmp_path / "ck"))
    run(replace(_spec(), checkpoint=ck))
    with pytest.raises(CheckpointError, match="different experiment"):
        run(replace(_spec(seed=7), checkpoint=ck), resume_from=ck.dir)


# --------------------------------------------------------------------- #
# the real thing: SIGKILL the process, resume in a fresh one
# --------------------------------------------------------------------- #

_KILL_SCRIPT = textwrap.dedent("""
    from dataclasses import replace
    from repro.api import (CheckpointSpec, DataSpec, ExperimentSpec,
                           FaultSpec, TrainSpec, run)
    from repro.api.spec import AsyncSpec
    spec = ExperimentSpec(
        data=DataSpec(n_clients=40, n_batches=1, batch_size=16),
        train=TrainSpec(strategy="bfln", rounds=6, sample_frac=0.3,
                        n_clusters=2, local_epochs=1),
        async_=AsyncSpec(buffer_size=4, concurrency=8),
        checkpoint=CheckpointSpec(interval=2, dir={ckdir!r}),
        faults=FaultSpec(crash_round=4, crash_phase="post_checkpoint",
                         crash_mode="sigkill"),
        engine=True, seed=3)
    run(spec)
    raise SystemExit("survived an injected SIGKILL")
""")


@pytest.mark.slow
def test_sigkill_and_resume_bit_identical(tmp_path):
    ckdir = str(tmp_path / "ck")
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", _KILL_SCRIPT.format(ckdir=ckdir)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == -9, (proc.returncode, proc.stderr[-2000:])
    assert os.path.isdir(ckdir) and os.listdir(ckdir)

    plain = run(_spec())
    ck = CheckpointSpec(interval=2, dir=ckdir)
    resumed = run(replace(_spec(), checkpoint=ck), resume_from=ckdir)
    assert resumed.manifest["resume_step"] == 4
    assert _digests(resumed.manifest) == _digests(plain.manifest)


# --------------------------------------------------------------------- #
# mesh8: sharded-arena snapshots resume bit-identically
# --------------------------------------------------------------------- #

_MESH_SCRIPT = textwrap.dedent("""
    import jax
    assert len(jax.devices()) == 8, jax.devices()
    import shutil
    from dataclasses import replace
    from repro.api import (CheckpointSpec, DataSpec, ExperimentSpec,
                           FaultSpec, TrainSpec, run)
    from repro.api.spec import MeshSpec
    from repro.faults import InjectedCrash

    def spec(**kw):
        return ExperimentSpec(
            data=DataSpec(n_clients=64, n_batches=1, batch_size=16),
            train=TrainSpec(strategy="bfln", rounds=4, sample_frac=0.25,
                            n_clusters=2, local_epochs=1),
            mesh=MeshSpec(shards=8), engine=True, seed=3, **kw)

    keys = ("event_log_digest", "block_hashes_digest", "balances_digest",
            "final_accuracy")
    plain = run(spec())
    ck = CheckpointSpec(interval=2, dir={ckdir!r})
    try:
        run(spec(checkpoint=ck,
                 faults=FaultSpec(crash_round=2,
                                  crash_phase="post_checkpoint",
                                  crash_mode="exception")))
        raise SystemExit("crash never fired")
    except InjectedCrash:
        pass
    resumed = run(spec(checkpoint=ck), resume_from={ckdir!r})
    assert resumed.manifest["resume_step"] == 2
    for k in keys:
        assert resumed.manifest[k] == plain.manifest[k], k
    print("MESH8_RESUME_OK")
""")


@pytest.mark.slow
def test_mesh8_crash_resume_bit_identical(tmp_path):
    ckdir = str(tmp_path / "ck")
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT.format(ckdir=ckdir)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH8_RESUME_OK" in proc.stdout

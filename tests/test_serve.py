"""The serving tier (`repro.serve`): snapshot provenance, the refuse-to-serve
gate, mixed-batch single-dispatch bit-identity, and frontend determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.blockchain.commit import (
    MerkleProof,
    RoundCommitments,
    verify_membership,
)
from repro.models import classifier as clf
from repro.obs import FlightRecorder, validate_record
from repro.serve import (
    Completion,
    ModelBank,
    ProvenanceError,
    ServeConfig,
    ServeFrontend,
    ServingEngine,
    latest_release,
    load_bank,
    publish_release,
    serve,
    snapshot,
    tampered,
    verify_bank,
)
from repro.sim.clock import VirtualClock


@pytest.fixture(scope="module")
def result():
    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=40),
        train=api.TrainSpec(rounds=2, sample_frac=0.3, n_clusters=3),
        eval=api.EvalSpec(every=0, clients=16, examples=64))
    return api.run(spec)


@pytest.fixture(scope="module")
def bank(result):
    return snapshot(result)


@pytest.fixture(scope="module")
def chain(result):
    return result.sim.trainer.chain


# --------------------------------------------------------------------- #
# snapshot
# --------------------------------------------------------------------- #

def test_snapshot_shapes_release_and_chain(result, bank, chain):
    K = result.spec.train.n_clusters
    assert bank.data.shape == (K, bank.layout.n_params)
    assert len(bank.releases) == K
    assert len(set(bank.digests())) >= 1
    # the release block is the chain head and the chain still validates
    head, rc = latest_release(chain)
    assert head is chain.blocks[-1]
    assert head.block_hash() == bank.block_hash
    assert rc.root == bank.root
    assert head.round_idx == bank.round_idx > result.spec.train.rounds - 1
    assert chain.validate()


def test_snapshot_models_are_cluster_means(result, bank):
    sim = result.sim
    rows = np.asarray(jax.device_get(sim.arena.data))[: sim.pop.n_clients]
    labels = np.asarray(sim.last_labels)
    for c in range(bank.n_models):
        members = rows[labels == c]
        if len(members):
            want = members.mean(axis=0)
            np.testing.assert_allclose(np.asarray(bank.data[c]), want,
                                       rtol=1e-6, atol=1e-7)


def test_snapshot_accepts_result_or_sim(result):
    a = snapshot(result, publish=False, verify=False)
    b = snapshot(result.sim, publish=False, verify=False)
    assert np.array_equal(np.asarray(a.data), np.asarray(b.data))
    with pytest.raises(ValueError):
        snapshot(object())


def test_verify_bank_passes_on_fresh_snapshot(bank, chain):
    verify_bank(bank, chain)    # must not raise


# --------------------------------------------------------------------- #
# the refuse-to-serve gate
# --------------------------------------------------------------------- #

def test_tampered_weights_refused_end_to_end(bank, chain):
    bad = tampered(bank, 1)
    with pytest.raises(ProvenanceError, match="fingerprint"):
        ServingEngine(bad, chain)


def test_tampered_digest_refused(bank, chain):
    releases = list(bank.releases)
    releases[0] = dataclasses.replace(releases[0], digest="0" * 40)
    bad = dataclasses.replace(bank, releases=tuple(releases))
    with pytest.raises(ProvenanceError):
        ServingEngine(bad, chain)


def test_wrong_round_refused(bank, chain):
    bad = dataclasses.replace(bank, round_idx=bank.round_idx - 1)
    with pytest.raises(ProvenanceError):
        ServingEngine(bad, chain)


def test_stale_release_refused(result, bank, chain):
    # mint a NEWER release of the same digests: the old bank must refuse
    sim = result.sim
    block, _ = publish_release(chain, sim.trainer.pool, bank.digests())
    try:
        with pytest.raises(ProvenanceError, match="stale"):
            ServingEngine(bank, chain)
        fresh = snapshot(result, publish=False)     # re-anchors on the head
        ServingEngine(fresh, chain)
    finally:
        # restore the fixture bank as the latest release for later tests
        chain.blocks.pop()
        assert chain.validate()


def test_engine_requires_chain_unless_opted_out(bank):
    with pytest.raises(ProvenanceError):
        ServingEngine(bank, None)
    ServingEngine(bank, None, verify=False)     # probe escape hatch


def test_unpublished_chain_refuses(result):
    # a run whose chain carries no release: snapshot(publish=False) refuses
    spec = api.ExperimentSpec(
        data=api.DataSpec(n_clients=20),
        train=api.TrainSpec(rounds=1, sample_frac=0.4, n_clusters=2),
        eval=api.EvalSpec(every=0, clients=8, examples=32))
    res = api.run(spec)
    with pytest.raises(ProvenanceError, match="no model release"):
        snapshot(res, publish=False)


# --------------------------------------------------------------------- #
# verify_membership negative paths, as serving uses them
# --------------------------------------------------------------------- #

def test_membership_negative_paths(bank):
    rc = RoundCommitments(bank.round_idx, tuple(enumerate(bank.digests())))
    digest = bank.releases[1].digest
    proof = rc.proof(1)
    assert verify_membership(rc.root, 1, bank.round_idx, digest, proof)
    # tampered digest
    assert not verify_membership(rc.root, 1, bank.round_idx, "f" * 40, proof)
    # wrong sender (another cluster claiming this model)
    assert not verify_membership(rc.root, 2, bank.round_idx, digest, proof)
    # wrong round (release leaf replayed into another round)
    assert not verify_membership(rc.root, 1, bank.round_idx + 1, digest,
                                 proof)
    # stale root (proof against a superseded release's root)
    rc2 = RoundCommitments(bank.round_idx + 1,
                           tuple(enumerate(bank.digests())))
    assert not verify_membership(rc2.root, 1, bank.round_idx, digest, proof)
    # forged proof path
    forged = MerkleProof(proof.leaf, tuple(("0" * 64, side)
                                           for _, side in proof.path))
    assert not verify_membership(rc.root, 1, bank.round_idx, digest, forged)


# --------------------------------------------------------------------- #
# engine: one dispatch, bit-identical routing
# --------------------------------------------------------------------- #

def test_mixed_batch_one_dispatch_bitwise_per_request(bank, chain):
    eng = ServingEngine(bank, chain)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((8, bank.mcfg.in_dim)).astype(np.float32)
    cids = np.array([0, 1, 2, 0, 2, 1, 0, 2], dtype=np.int32)
    out = eng.forward(x, cids)
    assert out.shape == (8, bank.mcfg.num_classes)
    assert eng.cache_sizes() == {"forward": 1}
    # same shape, different values/routing: the compile count stays pinned
    eng.forward(x + 1.0, cids[::-1].copy())
    assert eng.cache_sizes() == {"forward": 1}
    # a second batch shape compiles exactly once more
    eng.forward(x[:4], cids[:4])
    assert eng.cache_sizes() == {"forward": 2}
    # acceptance: per-request outputs bit-identical to routing each request
    # to its cluster model individually
    oracle = eng.forward_per_request(x, cids)
    assert bool(jnp.all(out.view(jnp.int32) == oracle.view(jnp.int32)))
    # and to the plain single-model forward per cluster
    for c in range(bank.n_models):
        rows = np.flatnonzero(cids == c)
        ref = clf.apply(bank.mcfg, bank.model_pytree(c), jnp.asarray(x))
        assert np.array_equal(np.asarray(out)[rows], np.asarray(ref)[rows])


def test_request_output_independent_of_batch_routing(bank, chain):
    # each row's logits depend only on its own (x, cid) — not on how the
    # rest of the batch routes: uniform-cid batches must reproduce the
    # mixed batch's rows bitwise
    eng = ServingEngine(bank, chain)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((6, bank.mcfg.in_dim)).astype(np.float32)
    cids = np.array([2, 0, 1, 1, 0, 2], dtype=np.int32)
    mixed = np.asarray(eng.forward(x, cids))
    for c in range(bank.n_models):
        uniform = np.asarray(eng.forward(x, np.full(6, c, np.int32)))
        rows = np.flatnonzero(cids == c)
        assert np.array_equal(mixed[rows], uniform[rows])


# --------------------------------------------------------------------- #
# frontend: deterministic bucketing, deadline, rejection, replay
# --------------------------------------------------------------------- #

def _drive(engine, schedule, *, config):
    """Replay a (t_arrival, cluster_id, x) schedule on a fresh virtual
    clock; returns the completions plus the flush count."""
    clock = VirtualClock()
    fe = ServeFrontend(engine, config, clock=clock)
    for t, cid, x in schedule:
        clock.advance_to(t)
        fe.pump()
        fe.submit(cid, x)
    clock.advance_to(schedule[-1][0] + 10 * config.max_wait)
    fe.pump()
    fe.drain()
    return fe.take_completed(), fe.n_flushes, fe


def test_frontend_replay_bit_identical(bank, chain):
    eng = ServingEngine(bank, chain)
    rng = np.random.default_rng(3)
    schedule = [(0.001 * i, int(i % 3),
                 rng.standard_normal(bank.mcfg.in_dim).astype(np.float32))
                for i in range(23)]
    cfg = ServeConfig(buckets=(1, 2, 4, 8), max_wait=0.004)
    a, flushes_a, _ = _drive(eng, schedule, config=cfg)
    b, flushes_b, _ = _drive(eng, schedule, config=cfg)
    assert flushes_a == flushes_b
    assert [c.req_id for c in a] == [c.req_id for c in b]
    assert [c.status for c in a] == [c.status for c in b]
    for ca, cb in zip(a, b):
        assert np.array_equal(ca.logits, cb.logits)
    # every request answered, and answered correctly
    assert sorted(c.req_id for c in a) == list(range(23))
    oracle = eng.forward_per_request(
        np.stack([x for _, _, x in schedule]),
        [cid for _, cid, _ in schedule])
    by_id = {c.req_id: c for c in a}
    for i in range(23):
        assert np.array_equal(by_id[i].logits, np.asarray(oracle[i]))


def test_frontend_full_bucket_flushes_inside_submit(bank, chain):
    eng = ServingEngine(bank, chain)
    fe = ServeFrontend(eng, ServeConfig(buckets=(4,), max_wait=1e9),
                       clock=VirtualClock())
    x = np.zeros(bank.mcfg.in_dim, np.float32)
    for i in range(3):
        fe.submit(i % 3, x)
    assert fe.queue_depth == 3 and fe.n_flushes == 0
    fe.submit(0, x)
    assert fe.queue_depth == 0 and fe.n_flushes == 1
    assert [c.status for c in fe.take_completed()] == ["ok"] * 4


def test_frontend_deadline_pads_to_bucket(bank, chain):
    eng = ServingEngine(bank, chain)
    clock = VirtualClock()
    fe = ServeFrontend(eng, ServeConfig(buckets=(8,), max_wait=0.5),
                       clock=clock)
    rng = np.random.default_rng(4)
    x = rng.standard_normal((3, bank.mcfg.in_dim)).astype(np.float32)
    for i in range(3):
        fe.submit(i, x[i])
    fe.pump()
    assert fe.n_flushes == 0            # deadline not reached
    clock.advance_to(1.0)
    fe.pump()
    assert fe.n_flushes == 1            # padded 3 -> bucket 8
    done = fe.take_completed()
    assert len(done) == 3
    oracle = eng.forward_per_request(x, [0, 1, 2])
    for i, c in enumerate(done):
        assert np.array_equal(c.logits, np.asarray(oracle[i]))
        assert c.t_done >= c.t_arrival


def test_frontend_overload_rejects_gracefully(bank, chain):
    eng = ServingEngine(bank, chain)
    fe = ServeFrontend(eng, ServeConfig(buckets=(8,), max_wait=1e9,
                                        max_pending=4),
                       clock=VirtualClock())
    x = np.zeros(bank.mcfg.in_dim, np.float32)
    for i in range(6):
        fe.submit(0, x)
    done = fe.take_completed()
    assert [c.status for c in done] == ["rejected"] * 2
    assert all(c.logits is None for c in done)
    assert fe.n_rejected == 2 and fe.queue_depth == 4
    fe.drain()
    assert [c.status for c in fe.take_completed()] == ["ok"] * 4


def test_frontend_validates_requests(bank, chain):
    eng = ServingEngine(bank, chain)
    fe = ServeFrontend(eng, clock=VirtualClock())
    with pytest.raises(ValueError, match="features"):
        fe.submit(0, np.zeros(bank.mcfg.in_dim + 1, np.float32))
    with pytest.raises(ValueError, match="cluster_id"):
        fe.submit(bank.n_models, np.zeros(bank.mcfg.in_dim, np.float32))
    with pytest.raises(ValueError, match="clock"):
        ServeFrontend(eng, clock=None)
    with pytest.raises(ValueError):
        ServeConfig(buckets=(4, 2))


# --------------------------------------------------------------------- #
# bank disk round-trip
# --------------------------------------------------------------------- #

def test_bank_save_load_roundtrip_and_tamper(tmp_path, result, bank, chain):
    path = str(tmp_path / "bank.npz")
    bank.save(path)
    loaded = load_bank(path, chain)     # verifies against the chain
    assert np.array_equal(np.asarray(loaded.data), np.asarray(bank.data))
    assert loaded.digests() == bank.digests()
    assert loaded.mcfg == bank.mcfg
    assert loaded.layout.paths == bank.layout.paths
    # the loaded bank serves identically
    eng = ServingEngine(loaded, chain)
    x = np.ones((2, bank.mcfg.in_dim), np.float32)
    ref = ServingEngine(bank, chain).forward(x, [0, 1])
    assert np.array_equal(np.asarray(eng.forward(x, [0, 1])),
                          np.asarray(ref))
    # tamper the saved weights: load refuses
    evil = tampered(loaded, 0)
    evil_path = str(tmp_path / "evil.npz")
    evil.save(evil_path)
    with pytest.raises(ProvenanceError):
        load_bank(evil_path, chain)
    # loading without a chain defers verification — the engine still refuses
    unverified = load_bank(evil_path)
    with pytest.raises(ProvenanceError):
        ServingEngine(unverified, chain)


# --------------------------------------------------------------------- #
# api entry point + observability
# --------------------------------------------------------------------- #

def test_api_serve_entry_point(result):
    fe = serve(result)
    assert isinstance(fe, ServeFrontend)
    x = np.zeros(result.sim.mcfg.in_dim, np.float32)
    rid = fe.submit(1, x)
    fe.drain()
    done = fe.take_completed()
    assert [c.req_id for c in done] == [rid]
    assert done[0].status == "ok" and done[0].cluster_id == 1
    # api.run left the release of test order unchanged: serve() published a
    # new head release, keep the module chain consistent for other tests
    result.sim.trainer.chain.blocks.pop()


def test_serve_records_validate_against_trace_schema(result, bank):
    rec = FlightRecorder(api.ObsSpec(enabled=True))
    sim = result.sim
    b = snapshot(result, obs=rec)
    eng = ServingEngine(b, sim.trainer.chain, obs=rec)
    fe = ServeFrontend(eng, ServeConfig(buckets=(2,), max_wait=0.1),
                       clock=VirtualClock(), obs=rec)
    x = np.zeros(bank.mcfg.in_dim, np.float32)
    fe.submit(0, x)
    fe.submit(1, x)
    fe.drain()
    names = {r["name"] for r in rec.records}
    assert {"serve.snapshot", "serve.verify", "serve.batch",
            "serve.flush"} <= names
    for r in rec.records:
        validate_record(r)
    assert rec.metrics.counters["serve.requests"] == 2
    assert rec.metrics.counters["serve.batches"] >= 1
    assert "serve.latency" in rec.metrics.summaries
    sim.trainer.chain.blocks.pop()      # drop the traced snapshot's release
    assert isinstance(fe.take_completed()[0], Completion)


def test_bank_types(bank):
    assert isinstance(bank, ModelBank)
    assert bank.nbytes == bank.data.size * 4
    tree = bank.model_pytree(0)
    flat = bank.layout.flatten(jax.tree.map(lambda p: p[None], tree))
    assert np.array_equal(np.asarray(flat[0]), np.asarray(bank.data[0]))

"""Batched model-fingerprint kernel: interpret-mode vs jnp oracle parity,
padding neutrality, digest sensitivity, and decision-parity of the
fingerprint-based commitment pipeline against the legacy `hash_params`
verification on tampered cohorts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.blockchain import Blockchain, Transaction, TxPool, hash_params
from repro.kernels.fingerprint import (
    cohort_digests,
    fingerprint_pallas,
    format_digest,
    poly_weights,
    stack_flatten_u32,
)
from repro.kernels.ref import fingerprint_ref


@pytest.mark.parametrize("m,n", [(4, 256), (8, 1024), (5, 131), (3, 2049),
                                 (17, 6500), (1, 128)])
def test_interpret_matches_ref_oracle(m, n, rng):
    """Pallas interpret mode == jnp oracle, bit-exact, aligned and not."""
    x = jnp.asarray(rng.integers(0, 2**32, size=(m, n), dtype=np.uint32))
    ref = np.asarray(fingerprint_ref(x, jnp.asarray(poly_weights(n))))
    pal = np.asarray(fingerprint_pallas(x, interpret=True))
    np.testing.assert_array_equal(ref, pal)


def test_non_aligned_padding_is_neutral(rng):
    """Zero-padding N to the block size must not change any digest: the
    padded columns multiply weights by mix(0) = 0."""
    x = rng.integers(0, 2**32, size=(4, 300), dtype=np.uint32)
    out = np.asarray(fingerprint_pallas(jnp.asarray(x), interpret=True,
                                        block_n=256))
    # manually pad to the next 256 multiple and compare the overlapping rows
    xp = np.pad(x, ((0, 0), (0, 512 - 300)))
    padded = np.asarray(fingerprint_ref(jnp.asarray(xp),
                                        jnp.asarray(poly_weights(512))))
    np.testing.assert_array_equal(out, padded)


def test_digest_sensitivity_and_length_binding():
    p = {"a": jnp.arange(12.0).reshape(3, 2, 2), "b": {"c": jnp.ones((3, 5))}}
    d = cohort_digests(p)
    assert len(set(d)) == 3                      # distinct rows -> distinct digests
    assert d == cohort_digests(p)                # deterministic
    p2 = {"a": jnp.asarray(p["a"]).at[1, 0, 0].add(1e-5), "b": p["b"]}
    d2 = cohort_digests(p2)
    assert d2[1] != d[1] and d2[0] == d[0] and d2[2] == d[2]
    # same values, zero-extended: the digest binds N, so no collision
    assert cohort_digests({"a": jnp.zeros((2, 4))}) != \
        cohort_digests({"a": jnp.zeros((2, 8))})


def test_pallas_pipeline_matches_default():
    """cohort_digests(use_pallas=True, interpret=True) == jnp default."""
    k = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(k, (6, 33, 7)),
         "b": jax.random.normal(k, (6, 19))}
    assert cohort_digests(p, use_pallas=True, interpret=True) == cohort_digests(p)


def test_stack_flatten_path_sorted_and_exact():
    """Leaf order is canonical (path-sorted) and the bit pattern is exact."""
    a = jnp.asarray([[1.5, -2.25]])
    b = jnp.asarray([[3.0]])
    f1 = np.asarray(stack_flatten_u32({"x": a, "y": b}))
    f2 = np.asarray(stack_flatten_u32({"y": b, "x": a}))
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(
        f1[0], np.array([1.5, -2.25, 3.0], np.float32).view(np.uint32))


def _verify_decisions_legacy(local_params, tamper):
    """The retired host-side pipeline: per-client hash_params + set-membership
    agg_hash (identity binding aside, tamper decisions should coincide)."""
    m = jax.tree.leaves(local_params)[0].shape[0]
    chain, pool = Blockchain(), TxPool()
    honest = []
    for slot in range(m):
        own = jax.tree.map(lambda x: x[slot], local_params)
        claimed = tamper.get(slot, own)
        pool.submit(Transaction("model_hash", slot, hash_params(claimed), 0))
        honest.append(hash_params(own))
    import json
    pool.submit(Transaction("agg_hash", 0, json.dumps(sorted(honest)), 0))
    return chain.verify_round(chain.pack_block(0, 0, pool), m)


def _verify_decisions_fingerprint(local_params, tamper):
    from repro.blockchain import AGG_COMMIT_KIND, RoundCommitments
    from repro.core.round import digest_of
    m = jax.tree.leaves(local_params)[0].shape[0]
    digests = cohort_digests(local_params)
    chain, pool = Blockchain(), TxPool()
    for slot in range(m):
        claimed = digest_of(tamper[slot]) if slot in tamper else digests[slot]
        pool.submit(Transaction("model_hash", slot, claimed, 0))
    commits = RoundCommitments(0, tuple(enumerate(digests)))
    pool.submit(Transaction(AGG_COMMIT_KIND, 0, commits.to_payload(), 0))
    return chain.verify_round(chain.pack_block(0, 0, pool), m)


def test_tamper_decisions_match_hash_params_pipeline():
    """Fingerprint commitments reproduce the hash_params-based verification
    decisions exactly — tampered clients rejected, honest accepted."""
    ks = jax.random.split(jax.random.PRNGKey(7), 8)
    local = {"w": jnp.stack([jax.random.normal(k, (5, 3)) for k in ks]),
             "b": jnp.stack([jax.random.normal(k, (4,)) for k in ks])}
    fake = {"w": jnp.zeros((5, 3)), "b": jnp.ones((4,))}
    tamper = {2: fake, 5: jax.tree.map(lambda x: x + 1.0, fake)}
    legacy = _verify_decisions_legacy(local, tamper)
    bound = _verify_decisions_fingerprint(local, tamper)
    expected = np.array([i not in tamper for i in range(8)])
    np.testing.assert_array_equal(legacy, expected)
    np.testing.assert_array_equal(bound, expected)


def test_format_digest_stable():
    assert format_digest(np.array([1, 2], np.uint32), 9) == \
        "000000010000000200000009"

"""PAA aggregation: FedAvg equivalence, personalization, weighted means."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import cluster_mean_params, paa_round
from repro.utils.tree import tree_stack


def _stacked_params(m, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), m)
    return tree_stack([
        {"w": jax.random.normal(k, (8, 4)), "b": jax.random.normal(k, (4,))}
        for k in ks])


def test_one_cluster_is_fedavg():
    sp = _stacked_params(6)
    labels = jnp.zeros((6,), jnp.int32)
    out = cluster_mean_params(sp, labels, 1)
    for leaf, src in zip(jax.tree.leaves(out), jax.tree.leaves(sp)):
        want = np.broadcast_to(np.mean(np.asarray(src), 0), leaf.shape)
        np.testing.assert_allclose(np.asarray(leaf), want, atol=1e-6)


def test_weighted_cluster_mean():
    sp = _stacked_params(4, seed=2)
    labels = jnp.asarray([0, 0, 1, 1])
    w = jnp.asarray([3.0, 1.0, 1.0, 1.0])
    out = cluster_mean_params(sp, labels, 2, weights=w)
    w_np = np.asarray(sp["w"])
    want0 = (3 * w_np[0] + w_np[1]) / 4
    np.testing.assert_allclose(np.asarray(out["w"][0]), want0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["w"][0]), np.asarray(out["w"][1]),
                               atol=1e-6)


def test_paa_round_clusters_similar_models_together():
    """Clients with similar representation maps land in the same cluster and
    share parameters afterwards."""
    m, d = 9, 16
    rng = np.random.default_rng(0)
    bases = rng.standard_normal((3, d, d)).astype(np.float32)
    params = []
    for i in range(m):
        w = bases[i // 3] + 0.01 * rng.standard_normal((d, d)).astype(np.float32)
        params.append({"w": jnp.asarray(w)})
    sp = tree_stack(params)

    def embed_fn(p, x):
        return jnp.tanh(x @ p["w"])

    probe = jnp.asarray(rng.standard_normal((12, d)).astype(np.float32))
    res = paa_round(embed_fn, sp, probe, n_clusters=3)
    labels = np.asarray(res.labels)
    # same-family clients share labels
    for fam in range(3):
        assert len(set(labels[fam * 3:(fam + 1) * 3].tolist())) == 1
    # and share aggregated params
    w = np.asarray(res.new_stacked_params["w"])
    np.testing.assert_allclose(w[0], w[1], atol=1e-6)
    # sizes sum to m
    assert int(np.asarray(res.cluster_sizes).sum()) == m

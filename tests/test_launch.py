"""Launcher machinery: fl-round target build, dryrun lower on a small mesh
(subprocess — device count must be set before jax initialises), flops model
consistency with the registry."""
import subprocess
import sys

import jax
import pytest

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.launch.flops import step_cost


def test_fl_target_builds_abstract():
    from repro.launch.fl_target import FLTargetConfig, stacked_param_specs
    cfg = FLTargetConfig(n_clients=8, in_dim=32, hidden=64, rep_dim=16)
    shapes = stacked_param_specs(cfg)
    assert shapes["w0"].shape == (8, 32, 64)


def test_make_client_mesh_shapes_and_device_guard():
    from repro.launch.mesh import CLIENT_AXIS, make_client_mesh
    n = len(jax.devices())
    mesh = make_client_mesh(n)
    assert mesh.axis_names == (CLIENT_AXIS,)
    assert mesh.shape[CLIENT_AXIS] == n
    with pytest.raises(ValueError, match="host_platform_device_count"):
        make_client_mesh(n + 1)


def test_platform_resolve_env_pure():
    """repro.launch.platform.resolve_env: returns only the vars that must
    change, from a raw spec dict / mesh section / MeshSpec-shaped object,
    without ever importing jax."""
    from repro.launch.platform import resolve_env

    # full spec dict, empty environment: shards force the CPU device count
    up = resolve_env({"mesh": {"shards": 8}}, environ={})
    assert up == {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"}

    # bare mesh section + platform/x64/extra flags
    up = resolve_env({"shards": 2, "platform": "cpu", "x64": True,
                      "xla_flags": ["--xla_cpu_multi_thread_eigen=false"]},
                     environ={})
    assert up["JAX_PLATFORMS"] == "cpu"
    assert up["JAX_ENABLE_X64"] == "1"
    assert up["XLA_FLAGS"].split() == [
        "--xla_cpu_multi_thread_eigen=false",
        "--xla_force_host_platform_device_count=2"]

    # idempotence: an environment that already matches needs no updates
    env = dict(up, JAX_PLATFORMS="cpu", JAX_ENABLE_X64="1")
    assert resolve_env({"shards": 2, "platform": "cpu", "x64": True,
                        "xla_flags": ["--xla_cpu_multi_thread_eigen=false"]},
                       environ=env) == {}

    # a larger already-forced count is never shrunk; a smaller one grows
    big = {"XLA_FLAGS": "--xla_force_host_platform_device_count=16"}
    assert resolve_env({"mesh": {"shards": 8}}, environ=big) == {}
    small = {"XLA_FLAGS": "--xla_force_host_platform_device_count=2"}
    up = resolve_env({"mesh": {"shards": 8}}, environ=small)
    assert up["XLA_FLAGS"] == "--xla_force_host_platform_device_count=8"

    # non-cpu platform: no forced host device count
    assert resolve_env({"mesh": {"shards": 8, "platform": "gpu"}},
                       environ={}) == {"JAX_PLATFORMS": "gpu"}

    # MeshSpec itself works as the section (attr access path)
    from repro.api import MeshSpec
    assert resolve_env(MeshSpec(shards=4), environ={}) == {
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}


def test_platform_bootstrap_subprocess_reexec(tmp_path):
    """End-to-end: bootstrap() after jax import re-execs once (the re-exec
    replays ``sys.argv``, so this must be a real script file), and the
    re-exec'd process sees the forced device count without looping."""
    import os
    script = tmp_path / "boot.py"
    script.write_text(
        "import jax\n"                           # jax initialised too early…
        "from repro.launch.platform import bootstrap\n"
        "bootstrap({'mesh': {'shards': 4}})\n"   # …so this re-execs
        "print('DEVS', len(jax.devices()))\n")
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(script)], text=True,
                         capture_output=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DEVS 4" in out.stdout


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_step_cost_defined_for_all_runnable_combos(arch):
    for shape_name, shape in SHAPES.items():
        ok, _ = shape_applicable(arch, shape_name)
        if not ok:
            continue
        c = step_cost(ARCHS[arch], shape)
        assert c.flops_total > 0 and c.hbm_bytes > 0
        assert 0 < c.model_flops / c.flops_total < 1.2, (arch, shape_name)


_DRYRUN_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES
from repro.launch import sharding as shd
from repro.launch.mesh import compat_make_mesh, use_mesh
from repro.launch.specs import batch_pspecs, train_batch_specs
from repro.models import lm
from repro.models.transformer import param_specs
from repro.optim import adamw
import dataclasses

# reduced arch on a 4x2 mini-mesh: the same machinery as production
cfg = dataclasses.replace(get_config("internvl2-2b").reduced(),
                          param_dtype="bfloat16")
mesh = compat_make_mesh((4, 2), ("data", "model"))
pshape = param_specs(cfg)
pspec = shd.param_pspecs(cfg, pshape, mesh)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
opt = adamw(1e-4)
oshape = jax.eval_shape(opt.init, pshape)
osh = ns(shd.opt_state_pspecs(oshape, pspec))
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=8)
batch = train_batch_specs(cfg, shape)
bsh = ns(batch_pspecs(cfg, batch, mesh))
with use_mesh(mesh):
    step = lm.make_train_step(cfg, opt)
    compiled = jax.jit(step, in_shardings=(ns(pspec), osh, bsh),
                       out_shardings=(NamedSharding(mesh, P()), ns(pspec), osh)
                       ).lower(pshape, oshape, batch).compile()
assert compiled.cost_analysis() is not None
print("OK")
"""


@pytest.mark.slow   # compiles a full reduced arch on an 8-device host mesh
def test_dryrun_machinery_on_mini_mesh():
    res = subprocess.run([sys.executable, "-c", _DRYRUN_SMOKE],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "OK" in res.stdout, res.stdout + res.stderr

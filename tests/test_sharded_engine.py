"""Mesh-sharded parameter arena + multi-device round engine.

The contract under test: with ``SimConfig(mesh_shards=8)`` the arena's
(n, N) matrix is row-sharded over a client-axis device mesh — each device
holds n/8 rows and the full matrix never materialises on one device — while
seeded replay (event log, block hashes, ledger balances, final accuracy)
stays BIT-identical to both the single-device engine and the legacy
``engine=False`` oracle, with the 1-compile-per-entry cache guarantee
intact.

Mesh tests need 8 devices: CI's mesh leg forces them with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single-device
machine the subprocess test below self-forces the flag so the contract is
still exercised by the default (slow) suite.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.arena import ParamArena, ShardedParamArena
from repro.sim import ClientPopulation, PopulationSpec, SimConfig, SimulatedFederation

N_DEV = len(jax.devices())
mesh8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _pop(n=60, seed=3, **kw):
    defaults = dict(n_clients=n, dataset="synth10", beta=0.3, n_batches=1,
                    batch_size=16, straggler_frac=0.2, straggler_slowdown=8.0,
                    dropout_rate=0.05, byzantine_frac=0.1, seed=seed)
    defaults.update(kw)
    return ClientPopulation.from_spec(PopulationSpec(**defaults))


def _sim(pop, *, engine=True, mesh_shards=1, **kw):
    defaults = dict(rounds=3, sample_frac=0.25, n_clusters=3, eval_every=2,
                    seed=3, engine=engine, mesh_shards=mesh_shards)
    defaults.update(kw)
    return SimulatedFederation(pop, SimConfig(**defaults))


def _block_hashes(sim):
    return [b.block_hash() for b in sim.trainer.chain.blocks]


def _assert_replay_identical(a, ra, b, rb, *, oracle=False):
    """Full replay identity.  ``oracle=True`` compares an engine run against
    the legacy ``engine=False`` driver, whose round DISPLAY metric comes
    from a dynamically-shaped eval — one ULP of slack there, exactly as
    ``test_strategy_parity`` pins it (the protocol state — event log,
    hashes, balances, final accuracy — stays bit-exact either way)."""
    assert ra.event_log == rb.event_log
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(ra.balances, rb.balances)
    assert ra.final_accuracy == rb.final_accuracy
    for x, y in zip(ra.history, rb.history):
        assert x.producer == y.producer
        assert x.reward_paid == y.reward_paid
        if oracle:
            assert x.accuracy == pytest.approx(y.accuracy, rel=1e-6,
                                               nan_ok=True)
        else:
            assert (x.accuracy == y.accuracy) or \
                (np.isnan(x.accuracy) and np.isnan(y.accuracy))


# --------------------------------------------------------------------------- #
# sharded arena unit behavior
# --------------------------------------------------------------------------- #

def test_mesh_shards_requires_engine_and_devices():
    pop = _pop(n=16)
    with pytest.raises(ValueError, match="engine"):
        _sim(pop, engine=False, mesh_shards=2)
    if N_DEV < 1000:
        with pytest.raises(ValueError, match="devices"):
            _sim(pop, mesh_shards=1000)


def test_mesh_shards_one_uses_plain_arena():
    """The default knob keeps the exact pre-mesh path: an unsharded arena
    (unsafe_buffer_pointer donation checks depend on it)."""
    sim = _sim(_pop(n=16), mesh_shards=1)
    assert type(sim.arena) is ParamArena


@mesh8
def test_sharded_arena_pads_and_roundtrips():
    """60 clients over 8 shards: rows pad to 64, each device holds 8 rows,
    and the pytree view drops the padding — bit-exact round trip."""
    from repro.launch.mesh import make_client_mesh
    pop = _pop(n=60)
    sim = _sim(pop, mesh_shards=8)
    arena = sim.arena
    assert isinstance(arena, ShardedParamArena)
    assert arena.n_clients == 60 and arena.n_padded == 64
    assert arena.per_device_bytes() * 8 == arena.data.nbytes
    assert {s.data.shape[0] for s in arena.data.addressable_shards} == {8}

    # bit-exact pytree round trip vs an unsharded arena of the same params
    ref = ParamArena.from_stacked(_sim(_pop(n=60), mesh_shards=1).params)
    np.testing.assert_array_equal(
        np.asarray(arena.data[:60]).view(np.uint32),
        np.asarray(ref.data).view(np.uint32))

    # uneven population over the mesh: 61 % 8 != 0 pads to 64 as well
    layout_tree = arena.as_pytree()
    arena61 = ShardedParamArena.from_stacked(
        jax.tree.map(lambda x: jnp.concatenate([x, x[:1]]), layout_tree),
        make_client_mesh(8))
    assert arena61.n_clients == 61 and arena61.n_padded == 64


@mesh8
def test_sharded_arena_never_materialises_on_one_device():
    """The headline memory claim: no single device ever holds the full
    (n, N) arena — shards stay at n_padded/8 rows across a round."""
    sim = _sim(_pop(n=64), mesh_shards=8)
    for r in range(2):
        sim.history.append(sim._run_sync_round(r))
    sim._finalize_history()
    shapes = {s.data.shape for s in sim.arena.data.addressable_shards}
    assert shapes == {(8, sim.arena.n_params)}


@mesh8
def test_sharded_arena_donation_reuses_every_shard():
    """Buffer donation must survive sharding: after warmup each device's
    shard buffer is updated in place, round after round."""
    pop = _pop(straggler_frac=0.0, dropout_rate=0.0)
    pop.availability[:] = 1.0
    sim = _sim(pop, mesh_shards=8, rounds=1, eval_every=0)
    sim.history.append(sim._run_sync_round(0))      # warmup (compile)
    ptrs = [s.data.unsafe_buffer_pointer()
            for s in sim.arena.data.addressable_shards]
    for r in range(1, 4):
        sim.history.append(sim._run_sync_round(r))
        now = [s.data.unsafe_buffer_pointer()
               for s in sim.arena.data.addressable_shards]
        assert now == ptrs


# --------------------------------------------------------------------------- #
# replay identity: forced-8-device mesh vs single-device engine vs oracle
# --------------------------------------------------------------------------- #

@mesh8
def test_sharded_replay_identical_sync_fast():
    """Compact 3-way sync replay (runs in the fast mesh CI leg): sharded
    mesh == single-device engine == legacy oracle, bit for bit."""
    pops = [_pop(n=40), _pop(n=40), _pop(n=40)]
    m = _sim(pops[0], mesh_shards=8)
    e = _sim(pops[1], mesh_shards=1)
    o = _sim(pops[2], engine=False)
    rm, re_, ro = m.run(), e.run(), o.run()
    _assert_replay_identical(m, rm, e, re_)
    _assert_replay_identical(m, rm, o, ro, oracle=True)
    assert any(not r.arrived.all() for r in rm.history), \
        "replay should cover rounds with missing arrivals"


@mesh8
@pytest.mark.slow
def test_sharded_replay_identical_sync_full():
    """Full sync replay with straggler/dropout/Byzantine dynamics and
    per-round eval across 5 rounds."""
    a = _sim(_pop(), mesh_shards=8, rounds=5, eval_every=1)
    b = _sim(_pop(), mesh_shards=1, rounds=5, eval_every=1)
    _assert_replay_identical(a, a.run(), b, b.run())


@mesh8
@pytest.mark.slow
def test_sharded_replay_identical_async():
    kw = dict(mode="async", buffer_size=6, concurrency=12, rounds=4)
    a = _sim(_pop(), mesh_shards=8, **kw)
    b = _sim(_pop(), mesh_shards=1, **kw)
    c = _sim(_pop(), engine=False, **kw)
    ra, rb, rc = a.run(), b.run(), c.run()
    _assert_replay_identical(a, ra, b, rb)
    _assert_replay_identical(a, ra, c, rc)
    assert any(r.staleness_mean > 0 for r in ra.history)


STRATEGIES = ("bfln", "fedavg", "fedprox", "fedproto", "fedhkd")


@mesh8
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_replay_per_strategy_sync(strategy):
    """Every registered strategy replays bit-identically under cohort
    sharding: its shard-local partial + deterministic combine must compose
    to the exact single-device aggregation (mesh8 == mesh1 == oracle)."""
    kw = dict(rounds=2, strategy=strategy)
    m = _sim(_pop(n=32), mesh_shards=8, **kw)
    e = _sim(_pop(n=32), mesh_shards=1, **kw)
    o = _sim(_pop(n=32), engine=False, **kw)
    rm, re_, ro = m.run(), e.run(), o.run()
    _assert_replay_identical(m, rm, e, re_)
    _assert_replay_identical(m, rm, o, ro, oracle=True)


@mesh8
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_sharded_replay_per_strategy_async(strategy):
    """FedBuff flushes under cohort sharding: the sharded async_step's
    local updates and fingerprints replay bit-identically per strategy."""
    kw = dict(mode="async", buffer_size=4, concurrency=8, rounds=2,
              strategy=strategy)
    a = _sim(_pop(n=32), mesh_shards=8, **kw)
    b = _sim(_pop(n=32), mesh_shards=1, **kw)
    _assert_replay_identical(a, a.run(), b, b.run())


@mesh8
def test_replicated_cohort_mode_still_bit_identical():
    """The ``mesh_cohort='replicated'`` escape hatch (pre-shard behaviour:
    whole cohort gathered to every device) keeps full replay identity."""
    a = _sim(_pop(n=40), mesh_shards=8, mesh_cohort="replicated")
    b = _sim(_pop(n=40), mesh_shards=1)
    assert a.engine.cohort_mode == "replicated"
    _assert_replay_identical(a, a.run(), b, b.run())


@mesh8
def test_sharded_empty_rounds_identical_and_blockless():
    """Nobody beats the deadline on the mesh either: no block minted, arena
    untouched, engine never compiled."""
    def make():
        pop = _pop(n=32, straggler_frac=0.0, dropout_rate=0.0)
        pop.latency.speed[:] = 1e9          # everyone misses every deadline
        return pop
    a = _sim(make(), mesh_shards=8, rounds=2, eval_every=0)
    b = _sim(make(), mesh_shards=1, rounds=2, eval_every=0)
    ra, rb = a.run(), b.run()
    assert ra.event_log == rb.event_log
    assert all(not r.arrived.any() for r in ra.history)
    assert len(a.trainer.chain.blocks) == 1          # genesis only
    assert _block_hashes(a) == _block_hashes(b)
    np.testing.assert_array_equal(ra.balances,
                                  np.full(32, a.cfg.initial_stake))
    assert a.engine.cache_sizes()["sync_step"] == 0


@mesh8
def test_sharded_zero_arrival_cluster_matches_single_device():
    """A cluster whose members all miss the deadline aggregates identically
    on the mesh: weight-zero mean, members keep their old (sharded) rows."""
    pop = _pop(n=40, straggler_frac=0.0, dropout_rate=0.0, byzantine_frac=0.0)
    k = 12
    cohort = np.arange(0, 40, 40 // k)[:k]
    cx, cy = pop.cohort_data(cohort)
    cohort_idx = jnp.asarray(cohort)

    # discover the round's labels (mask-independent), then craft an arrival
    # mask that leaves one whole cluster empty
    probe = _sim(pop, mesh_shards=8, rounds=1)
    _, probe_out = probe.engine.sync_step(
        probe.arena.data, cohort_idx, cx, cy, jnp.ones((k,), jnp.float32))
    labels = np.asarray(probe_out.labels)
    mask = labels != labels[0]
    assert mask.any() and not mask.all()

    a = _sim(pop, mesh_shards=8, rounds=1)
    b = _sim(pop, mesh_shards=1, rounds=1)
    arrived_w = jnp.asarray(mask, jnp.float32)
    da, oa = a.engine.sync_step(a.arena.data, cohort_idx, cx, cy, arrived_w)
    db, ob = b.engine.sync_step(b.arena.data, cohort_idx, cx, cy, arrived_w)
    np.testing.assert_array_equal(np.asarray(oa.labels), np.asarray(ob.labels))
    np.testing.assert_array_equal(
        np.asarray(oa.new_rows).view(np.uint32),
        np.asarray(ob.new_rows).view(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(oa.residues), np.asarray(ob.residues))
    # full arena parity (padding rows excluded)
    np.testing.assert_array_equal(
        np.asarray(da[: a.arena.n_clients]).view(np.uint32),
        np.asarray(db).view(np.uint32))


@mesh8
def test_sharded_cache_sizes_one_compile_per_entry():
    """The 1-compile-per-entry guarantee survives sharding: varying arrival
    counts never retrace any mesh-mode entry."""
    sim = _sim(_pop(straggler_frac=0.3), mesh_shards=8, rounds=5, eval_every=1)
    rep = sim.run()
    counts = {int(r.arrived.sum()) for r in rep.history}
    assert len(counts) > 1, "population should produce varying arrival counts"
    sizes = sim.engine.cache_sizes()
    assert sizes["sync_step"] == 1, sizes
    assert sizes["eval_cohort"] == 1, sizes
    assert sizes["eval_population"] == 1, sizes


# --------------------------------------------------------------------------- #
# single-device environments: self-forcing subprocess replay gate
# --------------------------------------------------------------------------- #

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_cpu_multi_thread_eigen=false")
import numpy as np
from repro.sim import (ClientPopulation, PopulationSpec, SimConfig,
                       SimulatedFederation)

def pop():
    return ClientPopulation.from_spec(PopulationSpec(
        n_clients=40, dataset="synth10", beta=0.3, n_batches=1, batch_size=16,
        straggler_frac=0.2, straggler_slowdown=8.0, dropout_rate=0.05,
        byzantine_frac=0.1, seed=3))

def run(shards):
    cfg = SimConfig(rounds=3, sample_frac=0.25, n_clusters=3, eval_every=2,
                    seed=3, engine=True, mesh_shards=shards)
    sim = SimulatedFederation(pop(), cfg)
    return sim, sim.run()

a, ra = run(8)
b, rb = run(1)
assert isinstance(a.arena.per_device_bytes(), int)
assert a.arena.per_device_bytes() * 8 == a.arena.data.nbytes
assert ra.event_log == rb.event_log
assert [x.block_hash() for x in a.trainer.chain.blocks] == \
       [x.block_hash() for x in b.trainer.chain.blocks]
assert np.array_equal(ra.balances, rb.balances)
assert ra.final_accuracy == rb.final_accuracy
sizes = a.engine.cache_sizes()
assert sizes["sync_step"] == 1 and sizes["eval_cohort"] == 1, sizes
print("SHARDED_REPLAY_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(N_DEV >= 8, reason="covered in-process by the mesh tests")
def test_sharded_replay_via_forced_devices_subprocess():
    """On a single-device machine, force an 8-device CPU platform in a
    subprocess (XLA_FLAGS must be set before jax initialises) and assert the
    sharded-vs-single-device replay gate there."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED_REPLAY_OK" in out.stdout
